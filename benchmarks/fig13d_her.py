"""Fig. 13(d): Hierarchical ER-Mapping on multi-WSC systems.

Flat ER rings spanning wafers pay the border repeatedly; HER decouples the
all-reduce into intra-wafer reduce-scatter + inter-wafer all-gather.
"""

from benchmarks.common import comm_us, row, wsc_system
from repro.core.simulator import simulate_iteration
from repro.core.workloads import DEEPSEEK_V3, QWEN3_235B


def run():
    rows = []
    for model in (DEEPSEEK_V3, QWEN3_235B):
        for wafers, dp, tp in ((2, 8, 16), (4, 8, 32)):
            base = comm_us(
                simulate_iteration(
                    model,
                    wsc_system(8, 8, dp, tp, "baseline", n_wafers=wafers),
                    256,
                    tp,
                )
            )
            er = comm_us(
                simulate_iteration(
                    model, wsc_system(8, 8, dp, tp, "her", n_wafers=wafers), 256, tp
                )
            )
            her = comm_us(
                simulate_iteration(
                    model,
                    wsc_system(8, 8, dp, tp, "her", n_wafers=wafers, hier=True),
                    256,
                    tp,
                )
            )
            rows.append(
                row(
                    f"fig13d/{model.name}/{wafers}wafers",
                    her,
                    f"er_gain={1 - er / base:+.0%};her_gain={1 - her / base:+.0%}",
                )
            )
    return rows
