"""Fig. 13(a): WSC-over-DGX communication advantage vs token count.

6x6 wafer vs 4-node DGX (32 GPUs) and 8x8 wafer vs 8-node DGX, sweeping
tokens per TP group; reports WSC gain and the additional ER-Mapping gain.
"""

from benchmarks.common import comm_us, dgx_system, row, wsc_system
from repro.core.simulator import simulate_iteration
from repro.core.workloads import QWEN3_235B


def run():
    rows = []
    for wafer, dgx_n, dp, tp in ((6, 32, 6, 6), (8, 64, 8, 8)):
        for tokens in (32, 64, 128, 256, 512, 1024):
            dgx = comm_us(
                simulate_iteration(QWEN3_235B, dgx_system(dgx_n), tokens, 8)
            )
            base = comm_us(
                simulate_iteration(
                    QWEN3_235B, wsc_system(wafer, wafer, dp, tp, "baseline"),
                    tokens, tp,
                )
            )
            er = comm_us(
                simulate_iteration(
                    QWEN3_235B, wsc_system(wafer, wafer, dp, tp, "er"), tokens, tp
                )
            )
            rows.append(
                row(
                    f"fig13a/{wafer}x{wafer}/tokens{tokens}",
                    er,
                    f"wsc_gain={1 - base / dgx:+.0%};er_gain={1 - er / dgx:+.0%}",
                )
            )
    return rows
