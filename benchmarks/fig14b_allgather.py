"""Fig. 14(b): justification for retaining the all-gather phase.

Dropping AG halves the all-reduce but forces all-to-all fetches from
scattered reduce-scatter owners: longer paths, more congestion. Retaining
AG should come out ahead overall (paper: +17% average).
"""

from benchmarks.common import row, wsc_system
from repro.core import comm_model as cm
from repro.core.hardware import WSC
from repro.core.workloads import DEEPSEEK_V3, QWEN3_235B


def run():
    rows = []
    for model in (DEEPSEEK_V3, QWEN3_235B):
        for r, c, dp, tp in ((6, 6, 6, 6), (8, 8, 8, 8)):
            sys_ = wsc_system(r, c, dp, tp, "er")
            m = sys_.mapping
            b = 256 * model.token_bytes
            wl = cm.A2AWorkload(256, model.token_bytes, model.topk)
            with_ag = (
                cm.mesh_allreduce(m, WSC, b, retain_ag=True).time
                + cm.mesh_alltoall(m, WSC, wl, retain_ag=True).time
            )
            no_ag = (
                cm.mesh_allreduce(m, WSC, b, retain_ag=False).time
                + cm.mesh_alltoall(m, WSC, wl, retain_ag=False).time
            )
            rows.append(
                row(
                    f"fig14b/{model.name}/{r}x{c}",
                    with_ag * 1e6,
                    f"no_ag_us={no_ag * 1e6:.1f};retain_gain={1 - with_ag / no_ag:+.0%}",
                )
            )
    return rows
