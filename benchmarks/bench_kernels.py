"""Kernel-path benchmark: dispatch + expert-FFN + combine (einsum vs padded
vs ragged vs fused-gather vs fused-compact), plus dense-vs-paged decode
attention KV-byte accounting.

Each shape cell drives the full MoE expert hot path *including token
dispatch and the combine leg* (both HBM round-trips the fused paths exist
to remove) — every path ends in the per-token weighted combine so outputs
are directly comparable:

* ``einsum_padded_dispatch``  — ``bucket_dispatch`` into ``(G, C, d)``
  buffers + the XLA einsum FFN + ``bucket_combine`` (the pre-kernel
  reference);
* ``gmm_padded_dispatch``     — ``bucket_dispatch`` + the padded Pallas
  kernels (``gmm_dual_act`` + ``gmm``): every capacity row hits the MXU;
* ``gmm_ragged_padded_dispatch`` — ``bucket_dispatch`` + the count-aware
  kernels: row-tiles past each bucket's fill skip the MXU, but the padded
  buffers are still written/read through HBM on both legs;
* ``gmm_gather_fused_dispatch``  — ``dispatch_metadata`` + the fused gather
  kernels (``gmm_dual_act_gather`` + ``gmm_ragged``): token rows stay in a
  flat compacted array and the kernel prologue gathers them via
  scalar-prefetched per-bucket offsets — the ``(G, C, d)`` *input* buffer
  never exists, but the FFN output is still bucket-padded and the combine
  reads it;
* ``gmm_compact_fused_combine`` — the gather prologue **plus the
  ``gmm_scatter`` epilogue**: the down-projection writes result tiles back
  at the same per-bucket offsets, so neither the padded input nor the
  padded output buffer exists; ``combine_from_rows`` gathers each kept
  copy through the dispatch metadata — but the ``(G, C, F)`` *hidden*
  tensor between the two kernels still round-trips HBM;
* ``gmm_fused_ffn_combine`` — **one kernel for all three matmuls**
  (``gmm_fused_ffn``): gather prologue, SwiGLU hidden tiles held in VMEM
  accumulators, down-projection, scatter epilogue. The padded hidden
  tensor never exists — its HBM-byte column is exactly zero.

Besides wall-clock, each row reports the FLOP accounting (``padded_gflop``
= what a capacity-padded pass must execute, ``achieved_gflop`` = useful
work at the measured routing, ``exec_gflop`` = what the path actually
runs at tile granularity), ``dispatch_hbm_mb`` — the bytes the dispatch
stage moves through HBM (padded: write + read of ``G*C*d``; fused: a
row-granular write of the ``R = sum(counts)`` compacted rows + a
tile-granular gather-DMA read, ``sum(ceil(count/bm)*bm)`` rows — the same
ceil-tile convention as ``exec_gflop``), ``combine_hbm_mb``, the
mirror accounting for the combine leg (padded paths write + read the
``G*C*d`` FFN output; the compact path's scatter epilogue writes
tile-granular rows and the metadata combine gathers the ``R`` live rows),
and ``hidden_hbm_mb`` — the bytes the ``(G, C, F)`` hidden tensor between
the SwiGLU front half and the down-projection moves (write + read for
every two-kernel path; **0** for ``gmm_fused_ffn_combine``, where the
hidden tile never leaves VMEM). ``utilization`` = achieved/executed FLOPs.

Shape cells cover balanced routing (every bucket full — the fused paths
must not lose here) and zipf-skewed routing (fig. 6 imbalance — where
tile-skipping plus the smaller dispatch *and* combine footprints win).

Usage::

    PYTHONPATH=src python benchmarks/bench_kernels.py [--out BENCH_kernels.json]
    PYTHONPATH=src python benchmarks/bench_kernels.py --smoke   # CI gate
    PYTHONPATH=src python benchmarks/bench_kernels.py --check BENCH_kernels.json

``--smoke`` runs one tiny FFN cell + one tiny decode cell + one tiny
stepped-migration cell + one tiny chunked-admission cell + one tiny
chunked-EP-dispatch cell with 2 iterations (interpret mode on CPU) and
exits non-zero on any parity failure — a kernel-dispatch, paged-decode,
sliced-copy, prefill-lane or chunk-pipeline regression fails the gate
even when the full parity suite isn't run.

``--check BASELINE.json`` recomputes every **deterministic** column (shape
metadata, FLOP accounting, per-leg HBM-byte accounting — not wall-clock,
not backend) from the same seeded routing draws and fails with a readable
diff if any drifts from the committed baseline — a PR that silently
re-pads a leg (or re-materializes the hidden tensor) turns CI red without
running a single kernel.

On CPU the Pallas paths execute in interpret mode (kernel *semantics*, not
kernel speed) — wall-clock comparisons are only meaningful on TPU, and the
JSON records backend + interpret so numbers aren't misread. The FLOP and
dispatch-byte accounting is backend-independent.
"""

from __future__ import annotations

import argparse
import json
import math
import platform
import sys
import time
import zlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_decode.ops import flash_decode_op, flash_decode_paged_op
from repro.kernels.flash_decode.ref import decode_ref
from repro.kernels.gmm.gmm import _tile, gmm, gmm_dual_act
from repro.kernels.gmm.ops import (
    expert_ffn_fused,
    expert_ffn_gather,
    expert_ffn_gather_compact,
    expert_ffn_ragged,
)
from repro.kernels.gmm.ref import expert_ffn_ref
from repro.kernels.registry import default_interpret, expert_ffn_from_rows
from repro.parallel.collectives import (
    bucket_combine,
    bucket_dispatch,
    combine_from_rows,
    dispatch_metadata,
    kept_counts,
)

# (name, G, C, D, F, balanced) — G buckets of capacity C, d_model D, expert
# hidden F. Mirrors smoke-to-midsize EP cells (slots x capacity after
# dispatch); balanced cells fill every bucket, skewed cells draw zipf counts.
SHAPES = [
    ("smoke_4x64", 4, 64, 64, 128, False),
    ("balanced_8x128", 8, 128, 128, 256, True),
    ("ep_16x128", 16, 128, 128, 512, False),
    ("skewed_32x64", 32, 64, 128, 256, False),
]
SMOKE_SHAPES = [("smoke_4x16", 4, 16, 16, 32, False)]

BM = 128  # row-tile the ragged kernels mask at (see kernels/gmm/ragged.py)

# Decode cells: (name, B, max_seq, lengths, K, H, hd, page_size). Dense
# flash-decode streams the whole (B, max_seq) cache and masks; paged decode
# walks only each request's live pages — `kv_hbm_mb` is the bandwidth story.
DECODE_SHAPES = [
    # hd/page multiples of 128 so the cells stay compiled-eligible on TPU
    # (can_flash_decode / can_flash_decode_paged gates).
    ("decode_short_balanced", 4, 1024, [256, 256, 256, 256], 2, 8, 128, 128),
    ("decode_long_balanced", 4, 1024, [1024, 1024, 1024, 1024], 2, 8, 128, 128),
    ("decode_ragged", 4, 2048, [128, 256, 512, 1024], 2, 8, 128, 128),
]
DECODE_SMOKE_SHAPES = [("decode_smoke", 2, 64, [20, 48], 2, 4, 16, 16)]

# Live stepped migration cells: (name, L, n_slots, D, F, n_slices, n_tok).
# One cell = one in-flight expert migration sliced over n_slices decode
# ticks (the MigrationDriver's per-tick _copy_row_slice on all three slot
# tensors) riding a decode-step-sized expert FFN. The accounting columns
# (slice/expert bytes, tick counts) are deterministic and CI-gated; the
# wall columns — including migration_exposed_ms, the per-tick cost the
# decode step cannot hide = (step + slice) − step — are not.
MIGRATION_SHAPES = [
    ("mig_smoke_4x64", 2, 4, 64, 128, 4, 64),
    ("mig_ep_8x128", 4, 8, 128, 256, 4, 128),
    ("mig_finegrain_8x128", 4, 8, 128, 256, 8, 128),
]
MIGRATION_SMOKE_SHAPES = [("mig_smoke", 2, 4, 16, 32, 4, 16)]

# Chunked-admission interleave cells: (name, model, B, chunk, prompt_len,
# page_size, max_seq). One cell = the fused two-lane decode step
# (runtime/serve.py with ServeConfig(prefill_chunk=C)) admitting a
# prompt_len prompt one C-token chunk per tick while a B-slot decode batch
# rides the same program. The deterministic columns — `ttft_ticks` (chunk
# ticks to the first token) and `chunk_hbm_mb` (KV bytes the prefill lane
# moves over the whole admission) — are CI-gated; the wall columns,
# including chunk_exposed_ms = wall(decode + live chunk) − wall(decode +
# no-op chunk), are not.
PREFILL_SHAPES = [
    ("prefill_interleave_c8", "llama3.2-1b", 3, 8, 40, 8, 64),
    ("prefill_interleave_c16", "llama3.2-1b", 3, 16, 48, 8, 64),
]
PREFILL_SMOKE_SHAPES = [("prefill_smoke", "llama3.2-1b", 2, 8, 16, 8, 32)]

# Chunked EP dispatch cells: (name, EP, SPD, CAP, D, F, chunk_counts,
# balanced). One cell = one EP step's expert hot path — EP ranks x SPD
# expert groups per rank at capacity CAP — run single-shot (ep_chunks=1)
# and chunked (each K in chunk_counts): the per-chunk fused row-FFN over
# K contiguous slices of the rank-compacted row layout, exactly the
# per-chunk `expert_ffn_from_rows` calls the pipelined
# `ep_moe_shardmap` fused branch issues between its all_to_all legs.
# Deterministic gated columns per K: per-chunk dispatch/combine HBM
# bytes (same ceil-tile convention as the FFN cells — per-chunk offsets
# keep BOTH legs compact, so the K lists sum to the single-shot
# numbers), per-chunk exchange wire bytes (the statically shaped
# all_to_all buffer splits exactly K ways), and `exposed_comm_ms` — the
# analytic pipeline schedule's wall(step) − wall(overlapped ideal):
# with D_i/B_i the dispatch/combine leg times and C_i the chunk-i
# compute time, chunk i's compute slot must cover chunk i−1's combine
# and chunk i+1's dispatch, so
#   exposed(K) = D_0 + B_{K-1} + sum_i max(0, B_{i-1} + D_{i+1} - C_i)
# (absent terms at the boundaries). K=1 degenerates to D + B — the two
# synchronous walls — and every K>1 is strictly below it (asserted).
# The model uses the fixed EP_WIRE_GBPS / EP_MODEL_TFLOPS constants so
# the column is seed-deterministic and --check-gated; `wall_ms` per K
# is measured (interpret semantics off-TPU) and NOT gated.
EP_CHUNK_SHAPES = [
    ("epchunk_balanced_8x64", 4, 2, 64, 64, 128, (1, 2), True),
    ("epchunk_skewed_16x64", 4, 4, 64, 128, 256, (1, 2, 4), False),
    ("epchunk_skewed_32x32", 8, 4, 32, 128, 256, (1, 2, 4), False),
]
EP_CHUNK_SMOKE_SHAPES = [("epchunk_smoke", 2, 2, 16, 16, 32, (1, 2), False)]

# Fixed analytic-model constants for the exposed-comm schedule: a
# mid-range per-device all_to_all leg bandwidth and MXU throughput.
# Deliberately NOT measured — the exposed_comm_ms column is a
# deterministic schedule property (what the pipeline hides at a given
# comm:compute ratio), not a backend benchmark; changing these changes
# the committed baseline.
EP_WIRE_GBPS = 40.0
EP_MODEL_TFLOPS = 20.0


def _skewed_counts(g: int, c: int, seed: int) -> np.ndarray:
    """Zipf-ish routing skew: a few hot experts near capacity, a long tail
    (incl. empties) — the fig. 6 imbalance regime."""
    rng = np.random.default_rng(seed)
    raw = rng.zipf(1.5, size=g).astype(np.float64)
    counts = np.floor(c * raw / raw.max()).astype(np.int64)
    counts[rng.permutation(g)[: max(g // 8, 1)]] = 0  # idle slots
    return np.clip(counts, 0, c)


def _ids_from_counts(counts: np.ndarray) -> np.ndarray:
    """A token stream whose per-bucket histogram is exactly ``counts``,
    in a seeded random order so dispatch never sees pre-sorted input."""
    ids = np.concatenate([np.full(c, g, np.int32) for g, c in enumerate(counts)])
    rng = np.random.default_rng(int(counts.sum()))
    return rng.permutation(ids)


def ffn_cell_accounting(name, g, c, d, f, balanced):
    """Deterministic columns of one FFN shape cell — seeded routing draw,
    FLOP model, and per-leg HBM-byte model. No kernels run; this is what
    ``--check`` recomputes against the committed baseline."""
    counts = (
        np.full(g, c, np.int64) if balanced else _skewed_counts(g, c, seed=g * c)
    )
    n_tok = int(counts.sum())
    flop_per_row = 6 * d * f  # 3 matmuls, 2 flop/MAC
    padded_gf = g * c * flop_per_row / 1e9
    achieved_gf = n_tok * flop_per_row / 1e9
    # The kernels' actual row tile: the largest divisor of the capacity
    # <= BM (min(BM, c) agrees only when that happens to divide c).
    bm = _tile(c, BM)
    ragged_rows = sum(math.ceil(cnt / bm) * bm for cnt in counts)
    ragged_exec_gf = ragged_rows * flop_per_row / 1e9
    row_bytes = d * np.dtype(np.float32).itemsize
    hidden_row_bytes = f * np.dtype(np.float32).itemsize
    # Padded legs: scatter out + read in of the full (G, C, ·) buffer.
    padded_leg_mb = 2 * g * c * row_bytes / 1e6
    # Fused legs are half row-granular (XLA scatter/gather of the
    # compacted rows), half tile-granular (the kernel's dynamic-offset
    # DMAs move whole (bm, ·) tiles, padding included — same ceil-tile
    # convention as exec_gflop): dispatch writes n_tok rows and the
    # gather prologue reads ragged_rows; the scatter epilogue writes
    # ragged_rows and the combine gathers n_tok.
    fused_dispatch_mb = (n_tok + ragged_rows) * row_bytes / 1e6
    compact_combine_mb = (ragged_rows + n_tok) * row_bytes / 1e6
    # Hidden leg: every two-kernel path writes the (G, C, F) SwiGLU output
    # and the down-projection reads it back (the Pallas pipeline moves all
    # blocks of a BlockSpec-driven operand, dead tiles included, so this
    # leg is full-size even for the ragged kernels). The single-kernel
    # fused path keeps the hidden tile in VMEM: exactly zero.
    hidden_mb = 2 * g * c * hidden_row_bytes / 1e6

    def acc(exec_gf, dispatch_mb, combine_mb, hidden):
        return {
            "exec_gflop": round(exec_gf, 4),
            "utilization": round(achieved_gf / exec_gf, 4) if exec_gf else 1.0,
            "dispatch_hbm_mb": round(dispatch_mb, 4),
            "combine_hbm_mb": round(combine_mb, 4),
            "hidden_hbm_mb": round(hidden, 4),
        }

    meta = {
        "shape": name,
        "G": g,
        "C": c,
        "D": d,
        "F": f,
        "routing": "balanced" if balanced else "skewed",
        "tokens_routed": n_tok,
        "tokens_padded": g * c,
        "group_sizes": counts.tolist(),
        "padded_gflop": round(padded_gf, 4),
        "achieved_gflop": round(achieved_gf, 4),
    }
    paths = {
        "einsum_padded_dispatch": acc(
            padded_gf, padded_leg_mb, padded_leg_mb, hidden_mb
        ),
        "gmm_padded_dispatch": acc(
            padded_gf, padded_leg_mb, padded_leg_mb, hidden_mb
        ),
        "gmm_ragged_padded_dispatch": acc(
            ragged_exec_gf, padded_leg_mb, padded_leg_mb, hidden_mb
        ),
        "gmm_gather_fused_dispatch": acc(
            ragged_exec_gf, fused_dispatch_mb, padded_leg_mb, hidden_mb
        ),
        "gmm_compact_fused_combine": acc(
            ragged_exec_gf, fused_dispatch_mb, compact_combine_mb, hidden_mb
        ),
        "gmm_fused_ffn_combine": acc(
            ragged_exec_gf, fused_dispatch_mb, compact_combine_mb, 0.0
        ),
    }
    return counts, meta, paths


def decode_cell_accounting(name, b, max_seq, lengths, kv, h, hd, bs):
    """Deterministic columns of one decode cell (KV HBM-byte model)."""
    nb = -(-max_seq // bs)
    row_bytes = 2 * kv * hd * np.dtype(np.float32).itemsize  # k + v
    dense_mb = b * nb * bs * row_bytes / 1e6
    live_pages = sum(-(-l // bs) for l in lengths)
    paged_mb = live_pages * bs * row_bytes / 1e6
    meta = {
        "shape": name,
        "B": b,
        "max_seq": max_seq,
        "page_size": bs,
        "lengths": list(lengths),
        "tokens_live": int(sum(lengths)),
        "tokens_streamed_dense": b * nb * bs,
        "tokens_streamed_paged": live_pages * bs,
    }
    paths = {
        "flash_decode_dense_masked": {"kv_hbm_mb": round(dense_mb, 4)},
        "flash_decode_paged": {"kv_hbm_mb": round(paged_mb, 4)},
    }
    ratio = round(dense_mb / paged_mb, 3)
    return meta, paths, ratio


def migration_cell_accounting(name, layers, s, d, f, n_slices, n_tok):
    """Deterministic columns of one stepped-migration cell: the byte/tick
    schedule the MigrationDriver produces for one expert move. Gated by
    ``--check``; the wall columns are not."""
    itemsize = np.dtype(np.float32).itemsize
    # Rows axis is axis 2 of every slot tensor: d for w_gate/w_up, f for
    # w_down — the driver chunks each tensor independently.
    chunks = {
        "w_gate": (-(-d // n_slices), f),
        "w_up": (-(-d // n_slices), f),
        "w_down": (-(-f // n_slices), d),
    }
    expert_bytes = layers * (2 * d * f + f * d) * itemsize
    slice_bytes = sum(
        layers * rows * cols * itemsize for rows, cols in chunks.values()
    )
    return {
        "shape": name,
        "L": layers,
        "n_slots": s,
        "D": d,
        "F": f,
        "tokens_per_step": n_tok,
        "n_slices": n_slices,
        "slice_rows": {k: rows for k, (rows, _) in chunks.items()},
        "expert_mb": round(expert_bytes / 1e6, 4),
        "slice_mb": round(slice_bytes / 1e6, 4),
        # one commit tick after the last slice tick (the atomic table swap
        # happens at the next step boundary).
        "ticks_to_commit": n_slices + 1,
    }


def prefill_cell_accounting(name, model, b, chunk, prompt_len, bs, max_seq):
    """Deterministic columns of one chunked-admission cell: the tick and
    KV-byte schedule the decode step's prefill lane pays to admit one
    prompt. Gated by ``--check``; the wall columns are not."""
    from repro.configs import get_config, smoke

    cfg = smoke(get_config(model))
    kv_row_bytes = 2 * cfg.n_kv_heads * cfg.head_dim_ * np.dtype(np.float32).itemsize
    ticks = -(-prompt_len // chunk)
    # Every tick writes the full padded chunk (padding rows land on the
    # write-off page — still a write) and the lane's attention gathers the
    # request's whole capacity table (max_seq rows of k + v), per layer.
    rows_written = ticks * chunk
    rows_streamed = ticks * max_seq
    return {
        "shape": name,
        "model": model,
        "B": b,
        "chunk": chunk,
        "prompt_len": prompt_len,
        "page_size": bs,
        "max_seq": max_seq,
        "L": cfg.n_layers,
        "kv_heads": cfg.n_kv_heads,
        "head_dim": cfg.head_dim_,
        # first token lands on the final chunk's tick; live decode slots
        # never stall (they share the one fused program).
        "ttft_ticks": ticks,
        "decode_stall_ticks": 0,
        "chunk_rows_written": rows_written,
        "chunk_rows_streamed": rows_streamed,
        "chunk_hbm_mb": round(
            cfg.n_layers * (rows_written + rows_streamed) * kv_row_bytes / 1e6, 4
        ),
    }


def ep_chunk_cell_accounting(name, ep, spd, cap, d, f, chunk_counts, balanced):
    """Deterministic columns of one chunked-EP cell: seeded routing draw,
    per-chunk HBM/wire-byte model, and the analytic ``exposed_comm_ms``
    pipeline schedule. Gated by ``--check``; the wall columns are not.
    Raises if any chunked schedule fails to beat the single-shot one —
    the overlap property itself is part of the gate."""
    g = ep * spd
    counts = (
        np.full(g, cap, np.int64) if balanced else _skewed_counts(g, cap, seed=g * cap)
    )
    n_tok = int(counts.sum())
    row_bytes = d * np.dtype(np.float32).itemsize
    flop_per_row = 6 * d * f
    bm = _tile(cap, BM)
    # One all_to_all leg moves the full statically shaped exchange buffer:
    # EP * SPD buckets of CAP rows per device. Chunking splits it exactly
    # K ways (the per-chunk buffers are (EP, SPD/K * CAP, D)).
    wire_total = g * cap * row_bytes

    def leg_ms(nbytes):
        return nbytes / (EP_WIRE_GBPS * 1e9) * 1e3

    def compute_ms(nflop):
        return nflop / (EP_MODEL_TFLOPS * 1e12) * 1e3

    per_k = {}
    exposed_by_k = {}
    for kk in chunk_counts:
        assert g % kk == 0, f"{name}: ep_chunks={kk} does not divide {g} groups"
        gpc = g // kk
        t_leg = leg_ms(wire_total / kk)
        disp, comb, exec_gf, comp = [], [], [], []
        for cc in range(kk):
            cnts = counts[cc * gpc : (cc + 1) * gpc]
            tok_c = int(cnts.sum())
            ragged_c = sum(math.ceil(cnt / bm) * bm for cnt in cnts)
            disp.append(round((tok_c + ragged_c) * row_bytes / 1e6, 4))
            comb.append(round((ragged_c + tok_c) * row_bytes / 1e6, 4))
            exec_gf.append(round(ragged_c * flop_per_row / 1e9, 4))
            comp.append(compute_ms(ragged_c * flop_per_row))
        # Pipeline schedule: chunk i's compute slot must cover chunk i-1's
        # combine and chunk i+1's dispatch; the first dispatch and last
        # combine have nothing to hide behind.
        exposed = t_leg + t_leg
        for i in range(kk):
            net = (t_leg if i > 0 else 0.0) + (t_leg if i < kk - 1 else 0.0)
            exposed += max(0.0, net - comp[i])
        exposed_by_k[kk] = exposed
        per_k[str(kk)] = {
            "groups_per_chunk": gpc,
            "wire_mb_per_chunk": round(wire_total / kk / 1e6, 4),
            "exec_gflop": exec_gf,
            "dispatch_hbm_mb": disp,
            "combine_hbm_mb": comb,
            "exposed_comm_ms": round(exposed, 6),
        }
    for kk, exp in exposed_by_k.items():
        if kk > 1 and not exp < exposed_by_k[1]:
            raise AssertionError(
                f"{name}: exposed_comm_ms(K={kk})={exp:.6f} is not strictly "
                f"below the single-shot baseline {exposed_by_k[1]:.6f} — the "
                "chunked schedule stopped hiding the all_to_all legs"
            )
    meta = {
        "shape": name,
        "EP": ep,
        "SPD": spd,
        "CAP": cap,
        "D": d,
        "F": f,
        "routing": "balanced" if balanced else "skewed",
        "tokens_routed": n_tok,
        "tokens_padded": g * cap,
        "group_sizes": counts.tolist(),
        "wire_mb_per_leg": round(wire_total / 1e6, 4),
    }
    return counts, meta, per_k


def _time(fn, *args, iters: int = 20, warmup: int = 3) -> float:
    """Best-of-N wall time: the minimum is the standard noise-robust
    estimator on shared/virtualized hosts (medians here swing 2-3x with
    CPU steal; the floor is what the code costs)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.min(times))


def run(iters: int = 20, smoke: bool = False) -> list[dict]:
    interpret = default_interpret()
    dtype = jnp.float32
    rows = []
    for name, g, c, d, f, balanced in SMOKE_SHAPES if smoke else SHAPES:
        ks = jax.random.split(jax.random.PRNGKey(zlib.crc32(name.encode())), 4)
        counts, meta, path_acc = ffn_cell_accounting(name, g, c, d, f, balanced)
        n_tok = int(counts.sum())
        ids = jnp.asarray(_ids_from_counts(counts))[:, None]        # (n, 1)
        xt = jax.random.normal(ks[0], (n_tok, d), dtype)            # token stream
        wg = jax.random.normal(ks[1], (g, d, f), dtype) * 0.1
        wu = jax.random.normal(ks[2], (g, d, f), dtype) * 0.1
        wd = jax.random.normal(ks[3], (g, f, d), dtype) * 0.1

        wt = jnp.ones(ids.shape, dtype)  # router weights (k = 1)

        @jax.jit
        def einsum_fn(xt, ids, wg, wu, wd):
            bufs, slots, keep = bucket_dispatch(xt, ids, g, c)
            y = expert_ffn_ref(bufs, wg, wu, wd)
            return bucket_combine(y, ids, slots, keep, wt)

        @jax.jit
        def padded_fn(xt, ids, wg, wu, wd):
            bufs, slots, keep = bucket_dispatch(xt, ids, g, c)
            h = gmm_dual_act(bufs, wg, wu, interpret=interpret)
            return bucket_combine(gmm(h, wd, interpret=interpret), ids, slots, keep, wt)

        @jax.jit
        def ragged_fn(xt, ids, wg, wu, wd):
            bufs, slots, keep = bucket_dispatch(xt, ids, g, c)
            gs = kept_counts(ids, keep, g)
            y = expert_ffn_ragged(bufs, wg, wu, wd, gs, interpret=interpret)
            return bucket_combine(y, ids, slots, keep, wt)

        @jax.jit
        def fused_fn(xt, ids, wg, wu, wd):
            row_ids, offsets, gs, slots, keep = dispatch_metadata(ids, g, c)
            y = expert_ffn_gather(
                xt[row_ids], wg, wu, wd, offsets, gs,
                capacity=c, interpret=interpret,
            )
            return bucket_combine(y, ids, slots, keep, wt)

        @jax.jit
        def compact_fn(xt, ids, wg, wu, wd):
            row_ids, offsets, gs, slots, keep = dispatch_metadata(ids, g, c)
            y = expert_ffn_gather_compact(
                xt[row_ids], wg, wu, wd, offsets, gs,
                capacity=c, interpret=interpret,
            )
            return combine_from_rows(y, offsets[ids] + slots, keep, wt)

        @jax.jit
        def fused_ffn_fn(xt, ids, wg, wu, wd):
            row_ids, offsets, gs, slots, keep = dispatch_metadata(ids, g, c)
            y = expert_ffn_fused(
                xt[row_ids], wg, wu, wd, offsets, gs,
                capacity=c, interpret=interpret,
            )
            return combine_from_rows(y, offsets[ids] + slots, keep, wt)

        # Cross-check all paths before timing — the outputs are per-token
        # combined results, so padded-vs-compact divergence on *either* leg
        # (dispatch or combine) fails here.
        ref = np.asarray(einsum_fn(xt, ids, wg, wu, wd))
        for label, fn in (
            ("ragged", ragged_fn),
            ("fused", fused_fn),
            ("compact", compact_fn),
            ("fused_ffn", fused_ffn_fn),
        ):
            np.testing.assert_allclose(
                np.asarray(fn(xt, ids, wg, wu, wd)), ref,
                rtol=2e-4, atol=2e-4, err_msg=f"{name}:{label} parity",
            )

        walls = {
            "einsum_padded_dispatch": _time(einsum_fn, xt, ids, wg, wu, wd, iters=iters),
            "gmm_padded_dispatch": _time(padded_fn, xt, ids, wg, wu, wd, iters=iters),
            "gmm_ragged_padded_dispatch": _time(ragged_fn, xt, ids, wg, wu, wd, iters=iters),
            "gmm_gather_fused_dispatch": _time(fused_fn, xt, ids, wg, wu, wd, iters=iters),
            "gmm_compact_fused_combine": _time(compact_fn, xt, ids, wg, wu, wd, iters=iters),
            "gmm_fused_ffn_combine": _time(fused_ffn_fn, xt, ids, wg, wu, wd, iters=iters),
        }
        rows.append(
            {
                **meta,
                "paths": {
                    pname: {"wall_ms": round(walls[pname] * 1e3, 3), **acc}
                    for pname, acc in path_acc.items()
                },
            }
        )
    return rows


def run_decode(iters: int = 20, smoke: bool = False) -> list[dict]:
    """Dense vs paged decode attention: parity + KV HBM-byte accounting.

    Bytes model (fp32, k + v): dense reads ``B * max_seq`` cache rows per
    step regardless of context; paged reads ``sum_b ceil(len_b / page) *
    page`` rows (the dead-block clamp elides everything past each request's
    live pages). Wall-clock is interpret-mode semantics off-TPU.
    """
    interpret = default_interpret()
    rows = []
    for name, b, max_seq, lengths, kv, h, hd, bs in (
        DECODE_SMOKE_SHAPES if smoke else DECODE_SHAPES
    ):
        ks = jax.random.split(jax.random.PRNGKey(zlib.crc32(name.encode())), 3)
        nb = -(-max_seq // bs)
        q = jax.random.normal(ks[0], (b, h, hd))
        k = jax.random.normal(ks[1], (b, nb * bs, kv, hd))
        v = jax.random.normal(ks[2], (b, nb * bs, kv, hd))
        ln = jnp.asarray(lengths, jnp.int32)
        valid = (jnp.arange(nb * bs)[None, :] < ln[:, None]).astype(jnp.int32)
        pool_k = k.reshape(b * nb, bs, kv, hd)
        pool_v = v.reshape(b * nb, bs, kv, hd)
        tables = jnp.arange(b * nb, dtype=jnp.int32).reshape(b, nb)

        dense_fn = lambda q, k, v, m: flash_decode_op(q, k, v, m, interpret=interpret)
        paged_fn = lambda q, pk, pv, t, l: flash_decode_paged_op(
            q, pk, pv, t, l, interpret=interpret
        )

        ref = np.asarray(decode_ref(q, k, v, valid))
        np.testing.assert_allclose(
            np.asarray(dense_fn(q, k, v, valid)), ref,
            rtol=2e-4, atol=2e-4, err_msg=f"{name}:dense parity",
        )
        np.testing.assert_allclose(
            np.asarray(paged_fn(q, pool_k, pool_v, tables, ln)), ref,
            rtol=2e-4, atol=2e-4, err_msg=f"{name}:paged parity",
        )

        meta, path_acc, ratio = decode_cell_accounting(
            name, b, max_seq, lengths, kv, h, hd, bs
        )
        walls = {
            "flash_decode_dense_masked": _time(dense_fn, q, k, v, valid, iters=iters),
            "flash_decode_paged": _time(
                paged_fn, q, pool_k, pool_v, tables, ln, iters=iters
            ),
        }
        rows.append(
            {
                **meta,
                "paths": {
                    pname: {"wall_ms": round(walls[pname] * 1e3, 3), **acc}
                    for pname, acc in path_acc.items()
                },
                "kv_bytes_ratio_dense_over_paged": ratio,
            }
        )
    return rows


def run_migration(iters: int = 20, smoke: bool = False) -> list[dict]:
    """Stepped-migration overlap cells: per-tick weight-slice copy riding a
    decode-step-sized expert FFN.

    ``migration_exposed_ms`` = wall(step + slice copies) − wall(step): the
    per-tick migration cost the decode compute does *not* hide. On TPU the
    copy overlaps the step's MXU work and this approaches 0; interpret/CPU
    numbers are semantics-only, like every other wall column here."""
    dtype = jnp.float32
    rows = []
    for name, layers, s, d, f, n_slices, n_tok in (
        MIGRATION_SMOKE_SHAPES if smoke else MIGRATION_SHAPES
    ):
        ks = jax.random.split(jax.random.PRNGKey(zlib.crc32(name.encode())), 4)
        wg = jax.random.normal(ks[0], (layers, s, d, f), dtype) * 0.1
        wu = jax.random.normal(ks[1], (layers, s, d, f), dtype) * 0.1
        wd = jax.random.normal(ks[2], (layers, s, f, d), dtype) * 0.1
        x = jax.random.normal(ks[3], (n_tok, d), dtype)
        meta = migration_cell_accounting(name, layers, s, d, f, n_slices, n_tok)
        src, dst = 0, s - 1
        chunks = {"w_gate": -(-d // n_slices), "w_down": -(-f // n_slices)}
        chunks["w_up"] = chunks["w_gate"]

        def ffn(x, wg, wu, wd):
            # decode-step stand-in: the batch's tokens through one slot's
            # SwiGLU FFN per layer (what one EP rank computes per tick).
            h = jnp.einsum("td,ldf->ltf", x, wg[:, src])
            u = jnp.einsum("td,ldf->ltf", x, wu[:, src])
            return jnp.einsum("ltf,lfd->ltd", jax.nn.silu(h) * u, wd[:, src])

        def one_slice(i, wg, wu, wd):
            # Mirrors migration_driver._copy_row_slice (undonated here so
            # the timed function can be re-invoked on the same buffers).
            out = []
            for w, rows_ in ((wg, chunks["w_gate"]), (wu, chunks["w_up"]),
                             (wd, chunks["w_down"])):
                total = w.shape[2]
                lo = max(0, min(i * rows_, total - rows_))
                blk = jax.lax.dynamic_slice(
                    w, (0, src, lo, 0), (w.shape[0], 1, rows_, w.shape[3])
                )
                out.append(jax.lax.dynamic_update_slice(w, blk, (0, dst, lo, 0)))
            return tuple(out)

        step_fn = jax.jit(ffn)
        step_plus_slice_fn = jax.jit(
            lambda x, wg, wu, wd: (ffn(x, wg, wu, wd), one_slice(0, wg, wu, wd))
        )

        # Parity: n_slices slice copies must land the whole expert exactly.
        cg, cu, cd = wg, wu, wd
        for i in range(n_slices):
            cg, cu, cd = jax.jit(lambda g, u, dn, i=i: one_slice(i, g, u, dn))(
                cg, cu, cd
            )
        for full, copied, label in ((wg, cg, "w_gate"), (wu, cu, "w_up"),
                                    (wd, cd, "w_down")):
            np.testing.assert_array_equal(
                np.asarray(copied[:, dst]), np.asarray(full[:, src]),
                err_msg=f"{name}:{label} sliced copy != whole expert",
            )

        step_ms = _time(step_fn, x, wg, wu, wd, iters=iters) * 1e3
        both_ms = _time(step_plus_slice_fn, x, wg, wu, wd, iters=iters) * 1e3
        rows.append(
            {
                **meta,
                "step_wall_ms": round(step_ms, 3),
                "step_plus_slice_wall_ms": round(both_ms, 3),
                "migration_exposed_ms": round(max(0.0, both_ms - step_ms), 3),
            }
        )
    return rows


def run_prefill(iters: int = 20, smoke_mode: bool = False) -> list[dict]:
    """Chunked-admission interleave cells: the fused two-lane decode step
    with a live prefill chunk vs the no-op chunk.

    Parity first: the decode lane's logits must be bitwise identical
    whether the prefill lane is off (``chunk=None``), idling (the no-op
    chunk) or mid-chunk — the lane must be invisible to its batchmates.
    ``chunk_exposed_ms`` = wall(decode + live chunk) − wall(decode + no-op
    chunk): the per-tick cost of interleaving admission, which on TPU the
    step's existing compute largely hides."""
    from repro.configs import get_config, smoke
    from repro.models import transformer as T
    from repro.parallel.ctx import ParallelCtx

    ctx = ParallelCtx()
    rows = []
    for name, model, b, chunk, prompt_len, bs, max_seq in (
        PREFILL_SMOKE_SHAPES if smoke_mode else PREFILL_SHAPES
    ):
        meta = prefill_cell_accounting(name, model, b, chunk, prompt_len, bs, max_seq)
        cfg = smoke(get_config(model))
        params = T.init_params(jax.random.PRNGKey(zlib.crc32(name.encode())), cfg)
        cache = T.init_cache(cfg, b, max_seq, paged=True, page_size=bs)
        nb = -(-max_seq // bs)
        token = jnp.zeros((b, 1), jnp.int32)
        rng = np.random.default_rng(zlib.crc32(name.encode()))
        buf = np.zeros(chunk, np.int32)
        buf[:] = rng.integers(0, cfg.vocab_size, size=chunk)
        live_chunk = {
            "tokens": jnp.asarray(buf[None, :]),
            "table": jnp.arange(nb, dtype=jnp.int32),
            "start": jnp.zeros((), jnp.int32),
            "length": jnp.asarray(chunk, jnp.int32),
        }
        trash = cache["layers"]["pool_k"].shape[1] - 1
        noop_chunk = {
            "tokens": jnp.zeros((1, chunk), jnp.int32),
            "table": jnp.full((nb,), trash, jnp.int32),
            "start": jnp.zeros((), jnp.int32),
            "length": jnp.zeros((), jnp.int32),
        }

        @jax.jit
        def lane_off(token, cache):
            return T.decode_step(params, token, cache, cfg, ctx)[0]

        @jax.jit
        def fused(token, cache, chunk_op):
            return T.decode_step(
                params, token, cache, cfg, ctx, chunk=chunk_op
            )[0]

        ref = np.asarray(lane_off(token, cache))
        for label, op in (("noop", noop_chunk), ("live", live_chunk)):
            np.testing.assert_array_equal(
                np.asarray(fused(token, cache, op)), ref,
                err_msg=f"{name}: chunk lane ({label}) leaked into decode lane",
            )

        decode_ms = _time(fused, token, cache, noop_chunk, iters=iters) * 1e3
        both_ms = _time(fused, token, cache, live_chunk, iters=iters) * 1e3
        rows.append(
            {
                **meta,
                "decode_wall_ms": round(decode_ms, 3),
                "decode_plus_chunk_wall_ms": round(both_ms, 3),
                "chunk_exposed_ms": round(max(0.0, both_ms - decode_ms), 3),
            }
        )
    return rows


def run_ep_chunk(iters: int = 20, smoke: bool = False) -> list[dict]:
    """Chunked EP dispatch cells: the per-chunk fused row-FFN schedule the
    pipelined ``ep_moe_shardmap`` runs between its all_to_all legs.

    Parity first, and it is *bitwise*: the chunked path slices the
    per-bucket offsets/counts of ONE global ``dispatch_metadata`` call, so
    every bucket's rows, keep mask, and FP combine order are unchanged —
    ``ep_chunks`` must be a pure schedule knob. ``wall_ms`` per K is the
    measured chunked FFN (interpret semantics off-TPU, not gated);
    ``exposed_comm_ms`` is the gated analytic schedule column."""
    dtype = jnp.float32
    rows = []
    for name, ep, spd, cap, d, f, chunk_counts, balanced in (
        EP_CHUNK_SMOKE_SHAPES if smoke else EP_CHUNK_SHAPES
    ):
        g = ep * spd
        counts, meta, per_k = ep_chunk_cell_accounting(
            name, ep, spd, cap, d, f, chunk_counts, balanced
        )
        n_tok = int(counts.sum())
        ks = jax.random.split(jax.random.PRNGKey(zlib.crc32(name.encode())), 4)
        ids = jnp.asarray(_ids_from_counts(counts))[:, None]
        xt = jax.random.normal(ks[0], (n_tok, d), dtype)
        wg = jax.random.normal(ks[1], (g, d, f), dtype) * 0.1
        wu = jax.random.normal(ks[2], (g, d, f), dtype) * 0.1
        wd = jax.random.normal(ks[3], (g, f, d), dtype) * 0.1
        wt = jnp.ones(ids.shape, dtype)

        def make_fn(kk):
            gpc = g // kk

            @jax.jit
            def fn(xt, ids, wg, wu, wd):
                row_ids, offsets, gs, slots, keep = dispatch_metadata(ids, g, cap)
                rows_in = xt[row_ids]

                def chunk_ffn(cc):
                    ws = slice(cc * gpc, (cc + 1) * gpc)
                    return expert_ffn_from_rows(
                        rows_in, wg[ws], wu[ws], wd[ws], offsets[ws], gs[ws],
                        capacity=cap, groups_per_weight=1, enabled=True,
                        compact_out=True, fused=True,
                    )

                y = chunk_ffn(0)
                if kk > 1:
                    # Rows outside a chunk's buckets are unspecified in its
                    # output — select each row from its owner chunk (same
                    # merge as the chunked moe_esp fused branch).
                    r_idx = jnp.arange(rows_in.shape[0], dtype=jnp.int32)
                    owner = jnp.searchsorted(offsets, r_idx, side="right") - 1
                    owner_c = jnp.clip(owner, 0, g - 1) // gpc
                    for cc in range(1, kk):
                        y = jnp.where((owner_c == cc)[:, None], chunk_ffn(cc), y)
                return combine_from_rows(y, offsets[ids] + slots, keep, wt)

            return fn

        fns = {kk: make_fn(kk) for kk in chunk_counts}
        base = np.asarray(fns[1](xt, ids, wg, wu, wd))
        for kk in chunk_counts:
            if kk == 1:
                continue
            np.testing.assert_array_equal(
                np.asarray(fns[kk](xt, ids, wg, wu, wd)), base,
                err_msg=f"{name}: ep_chunks={kk} is not bit-identical to "
                "the single-shot path",
            )

        chunks_out = {}
        for kk in chunk_counts:
            wall = _time(fns[kk], xt, ids, wg, wu, wd, iters=iters)
            chunks_out[str(kk)] = {
                "wall_ms": round(wall * 1e3, 3),
                **per_k[str(kk)],
            }
        rows.append({**meta, "chunks": chunks_out})
    return rows


# ---------------------------------------------------------------------------
# baseline regression gate (--check)
# ---------------------------------------------------------------------------

def check_baseline(baseline_path: str) -> list[str]:
    """Recompute every deterministic column from the same seeded draws and
    diff against the committed baseline. Returns human-readable failure
    lines (empty == green). Wall-clock, backend, and version fields are
    deliberately ignored — only the accounting the fused kernels exist to
    improve is gated."""
    with open(baseline_path) as fh:
        base = json.load(fh)
    failures: list[str] = []

    def cmp(cell: str, key: str, want, got) -> None:
        if want != got:
            failures.append(
                f"{cell}.{key}: baseline {want!r} != recomputed {got!r}"
            )

    base_shapes = {r.get("shape"): r for r in base.get("shapes", [])}
    expected = []
    for name, g, c, d, f, balanced in SHAPES:
        expected.append(name)
        _, meta, path_acc = ffn_cell_accounting(name, g, c, d, f, balanced)
        row = base_shapes.get(name)
        if row is None:
            failures.append(f"shapes[{name}]: missing from baseline")
            continue
        for key, val in meta.items():
            cmp(f"shapes[{name}]", key, row.get(key), val)
        for pname, acc in path_acc.items():
            prow = (row.get("paths") or {}).get(pname)
            if prow is None:
                failures.append(f"shapes[{name}].paths.{pname}: missing from baseline")
                continue
            for key, val in acc.items():
                cmp(f"shapes[{name}].paths.{pname}", key, prow.get(key), val)
    for name in set(base_shapes) - set(expected):
        failures.append(f"shapes[{name}]: in baseline but no longer benchmarked")

    base_dec = {r.get("shape"): r for r in base.get("decode_shapes", [])}
    expected = []
    for name, b, max_seq, lengths, kv, h, hd, bs in DECODE_SHAPES:
        expected.append(name)
        meta, path_acc, ratio = decode_cell_accounting(
            name, b, max_seq, lengths, kv, h, hd, bs
        )
        row = base_dec.get(name)
        if row is None:
            failures.append(f"decode_shapes[{name}]: missing from baseline")
            continue
        for key, val in meta.items():
            cmp(f"decode_shapes[{name}]", key, row.get(key), val)
        cmp(
            f"decode_shapes[{name}]", "kv_bytes_ratio_dense_over_paged",
            row.get("kv_bytes_ratio_dense_over_paged"), ratio,
        )
        for pname, acc in path_acc.items():
            prow = (row.get("paths") or {}).get(pname)
            if prow is None:
                failures.append(
                    f"decode_shapes[{name}].paths.{pname}: missing from baseline"
                )
                continue
            for key, val in acc.items():
                cmp(f"decode_shapes[{name}].paths.{pname}", key, prow.get(key), val)
    for name in set(base_dec) - set(expected):
        failures.append(f"decode_shapes[{name}]: in baseline but no longer benchmarked")

    base_mig = {r.get("shape"): r for r in base.get("migration_shapes", [])}
    expected = []
    for name, layers, s, d, f, n_slices, n_tok in MIGRATION_SHAPES:
        expected.append(name)
        meta = migration_cell_accounting(name, layers, s, d, f, n_slices, n_tok)
        row = base_mig.get(name)
        if row is None:
            failures.append(f"migration_shapes[{name}]: missing from baseline")
            continue
        for key, val in meta.items():
            cmp(f"migration_shapes[{name}]", key, row.get(key), val)
    for name in set(base_mig) - set(expected):
        failures.append(
            f"migration_shapes[{name}]: in baseline but no longer benchmarked"
        )

    base_pf = {r.get("shape"): r for r in base.get("prefill_shapes", [])}
    expected = []
    for name, model, b, chunk, prompt_len, bs, max_seq in PREFILL_SHAPES:
        expected.append(name)
        meta = prefill_cell_accounting(name, model, b, chunk, prompt_len, bs, max_seq)
        row = base_pf.get(name)
        if row is None:
            failures.append(f"prefill_shapes[{name}]: missing from baseline")
            continue
        for key, val in meta.items():
            cmp(f"prefill_shapes[{name}]", key, row.get(key), val)
    for name in set(base_pf) - set(expected):
        failures.append(
            f"prefill_shapes[{name}]: in baseline but no longer benchmarked"
        )

    base_ec = {r.get("shape"): r for r in base.get("ep_chunk_shapes", [])}
    expected = []
    for name, ep, spd, cap, d, f, chunk_counts, balanced in EP_CHUNK_SHAPES:
        expected.append(name)
        _, meta, per_k = ep_chunk_cell_accounting(
            name, ep, spd, cap, d, f, chunk_counts, balanced
        )
        row = base_ec.get(name)
        if row is None:
            failures.append(f"ep_chunk_shapes[{name}]: missing from baseline")
            continue
        for key, val in meta.items():
            cmp(f"ep_chunk_shapes[{name}]", key, row.get(key), val)
        base_chunks = row.get("chunks") or {}
        for kk, acc in per_k.items():
            crow = base_chunks.get(kk)
            if crow is None:
                failures.append(
                    f"ep_chunk_shapes[{name}].chunks[{kk}]: missing from baseline"
                )
                continue
            for key, val in acc.items():
                cmp(f"ep_chunk_shapes[{name}].chunks[{kk}]", key, crow.get(key), val)
        for kk in set(base_chunks) - set(per_k):
            failures.append(
                f"ep_chunk_shapes[{name}].chunks[{kk}]: in baseline but no "
                "longer benchmarked"
            )
    for name in set(base_ec) - set(expected):
        failures.append(
            f"ep_chunk_shapes[{name}]: in baseline but no longer benchmarked"
        )
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_kernels.json")
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="tiny FFN + decode cells, 2 iters: fast kernel regression gate",
    )
    ap.add_argument(
        "--check",
        metavar="BASELINE_JSON",
        help="recompute the deterministic columns (FLOP + HBM-byte "
        "accounting, not wall-clock) and fail on any drift from the "
        "committed baseline",
    )
    args = ap.parse_args()

    if args.check:
        failures = check_baseline(args.check)
        if failures:
            print(
                f"BENCH BASELINE DRIFT vs {args.check} "
                f"({len(failures)} mismatches):",
                file=sys.stderr,
            )
            for line in failures:
                print(f"  {line}", file=sys.stderr)
            print(
                "If the change is intentional, regenerate the baseline: "
                "PYTHONPATH=src python benchmarks/bench_kernels.py --out "
                "BENCH_kernels.json",
                file=sys.stderr,
            )
            raise SystemExit(1)
        print(f"BENCH BASELINE OK ({args.check}: deterministic columns match)")
        return

    iters = 2 if args.smoke else args.iters
    try:
        rows = run(iters=iters, smoke=args.smoke)
        decode_rows = run_decode(iters=iters, smoke=args.smoke)
        migration_rows = run_migration(iters=iters, smoke=args.smoke)
        prefill_rows = run_prefill(iters=iters, smoke_mode=args.smoke)
        ep_chunk_rows = run_ep_chunk(iters=iters, smoke=args.smoke)
    except AssertionError as e:  # parity failure must fail the gate loudly
        print(f"KERNEL PARITY FAILURE: {e}", file=sys.stderr)
        raise SystemExit(1)
    doc = {
        "bench": "kernels_expert_ffn_dispatch",
        "backend": jax.default_backend(),
        "interpret": default_interpret(),
        "jax": jax.__version__,
        "host": platform.machine(),
        "smoke": args.smoke,
        "note": (
            "wall_ms on non-TPU backends runs the Pallas paths in interpret "
            "mode (semantics, not speed); FLOP and byte accounting is "
            "backend-independent. utilization = achieved/executed FLOPs; "
            "dispatch_hbm_mb / combine_hbm_mb / hidden_hbm_mb = HBM bytes "
            "each leg moves; fused-path DMA sides are counted at ceil-tile "
            "granularity (the kernels move whole bm-row tiles), matching "
            "exec_gflop (the fused gather path never materializes the "
            "padded input buckets; the compact path's gmm_scatter epilogue "
            "never materializes the padded FFN output either, and "
            "combine_from_rows reads only live rows; gmm_fused_ffn_combine "
            "runs all three matmuls in ONE kernel with the (G, C, F) "
            "SwiGLU hidden tile resident in VMEM, so its hidden_hbm_mb is "
            "exactly 0 where every two-kernel path pays the full padded "
            "write + read). All paths end in the per-token combine, so "
            "parity covers both legs. This bench drives the local/ESP-style "
            "dispatch; the EP all_to_all path keeps statically-sized "
            "exchange buffers on both legs (equal splits), where the "
            "fusion instead removes the receive-side repack + padded FFN "
            "input/output. decode_shapes compare dense masked flash-decode "
            "(streams B*max_seq KV rows/step) against the paged "
            "block-table kernel (streams only live pages): kv_hbm_mb "
            "tracks context length, not max_seq. migration_shapes measure "
            "live stepped expert migration: one per-tick weight-row slice "
            "copy (dynamic_slice/dynamic_update_slice per tensor, the same "
            "program runtime.migration_driver issues) dispatched alongside a "
            "decode-step-sized expert FFN; migration_exposed_ms = "
            "wall(step + slice) - wall(step) is the per-tick cost decode "
            "compute does not hide, and slice_mb / expert_mb / "
            "ticks_to_commit are the deterministic accounting. "
            "prefill_shapes measure the chunked-admission prefill lane "
            "(ServeConfig(prefill_chunk=C)): the fused two-lane decode "
            "step with a live chunk vs the no-op chunk; ttft_ticks, "
            "decode_stall_ticks and chunk_hbm_mb (KV bytes the lane "
            "writes + streams over one admission) are deterministic, and "
            "chunk_exposed_ms = wall(decode + live chunk) - wall(decode + "
            "no-op chunk) is the per-tick interleave cost. ep_chunk_shapes "
            "measure the chunked EP dispatch pipeline "
            "(ServeConfig(ep_chunks=K)): per-K bitwise parity of the "
            "chunked fused row-FFN against the single-shot path, per-chunk "
            "dispatch/combine HBM bytes (the K lists sum to the "
            "single-shot columns — per-chunk offset slices keep both legs "
            "compact), per-chunk exchange wire bytes, and exposed_comm_ms "
            "— the analytic schedule's wall(step) - wall(overlapped "
            "ideal) at the fixed EP_WIRE_GBPS/EP_MODEL_TFLOPS model point "
            "(K=1 = the two synchronous all_to_all walls; every K>1 must "
            "be strictly below it, asserted at generation AND re-checked "
            "by --check). The deterministic columns are CI-gated: "
            "bench_kernels.py --check BENCH_kernels.json recomputes them "
            "and fails on drift."
        ),
        "shapes": rows,
        "decode_shapes": decode_rows,
        "migration_shapes": migration_rows,
        "prefill_shapes": prefill_rows,
        "ep_chunk_shapes": ep_chunk_rows,
    }
    if args.smoke:
        print(json.dumps(doc, indent=2))
        print("BENCH SMOKE OK")
        return
    with open(args.out, "w") as fh:
        json.dump(doc, fh, indent=2)
        fh.write("\n")
    print(json.dumps(doc, indent=2))


if __name__ == "__main__":
    main()
