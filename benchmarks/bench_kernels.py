"""Kernel-path benchmark: einsum vs padded-GMM vs ragged-GMM expert FFN.

Measures, per shape cell, the full grouped SwiGLU FFN (three matmuls):

* ``einsum``      — the pre-kernel reference path (XLA-compiled einsums over
  the padded ``(G, C, D)`` buckets);
* ``gmm_padded``  — the Pallas grouped-matmul kernels over the same padded
  buckets (``gmm_dual_act`` + ``gmm``);
* ``gmm_ragged``  — the count-aware kernels (``gmm_dual_act_ragged`` +
  ``gmm_ragged``): row-tiles past each group's token count skip the MXU.

Besides wall-clock, each row reports the FLOP accounting that motivates the
ragged kernel: ``padded_gflop`` is what a capacity-padded pass must execute
(``6*G*C*D*F``), ``achieved_gflop`` is the useful work at the measured
routing skew (``6*sum(counts)*D*F``), and ``ragged_exec_gflop`` is what the
ragged kernel actually runs (tile granularity: ``6*sum(ceil(c/bm)*bm)*D*F``).
``utilization`` = achieved/executed — 1.0 for ragged up to tile rounding,
``sum(counts)/(G*C)`` for the padded paths.

Usage::

    PYTHONPATH=src python benchmarks/bench_kernels.py [--out BENCH_kernels.json]

On CPU the Pallas paths execute in interpret mode (kernel *semantics*, not
kernel speed) — wall-clock comparisons are only meaningful on TPU, and the
JSON records backend + interpret so numbers aren't misread. The FLOP
accounting is backend-independent.
"""

from __future__ import annotations

import argparse
import json
import math
import platform
import time
import zlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.gmm.gmm import gmm, gmm_dual_act
from repro.kernels.gmm.ops import expert_ffn_ragged
from repro.kernels.gmm.ref import expert_ffn_ref
from repro.kernels.registry import default_interpret

# (name, G, C, D, F) — G buckets of capacity C, d_model D, expert hidden F.
# Mirrors smoke-to-midsize EP cells (slots x capacity after dispatch).
SHAPES = [
    ("smoke_4x64", 4, 64, 64, 128),
    ("ep_8x128", 8, 128, 128, 256),
    ("ep_16x128", 16, 128, 128, 512),
    ("skewed_32x64", 32, 64, 128, 256),
]

BM = 128  # row-tile the ragged kernel masks at (see kernels/gmm/ragged.py)


def _skewed_counts(g: int, c: int, seed: int) -> np.ndarray:
    """Zipf-ish routing skew: a few hot experts near capacity, a long tail
    (incl. empties) — the fig. 6 imbalance regime."""
    rng = np.random.default_rng(seed)
    raw = rng.zipf(1.5, size=g).astype(np.float64)
    counts = np.floor(c * raw / raw.max()).astype(np.int64)
    counts[rng.permutation(g)[: max(g // 8, 1)]] = 0  # idle slots
    return np.clip(counts, 0, c)


def _time(fn, *args, iters: int = 20, warmup: int = 3) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def run(iters: int = 20) -> list[dict]:
    interpret = default_interpret()
    dtype = jnp.float32
    rows = []
    for name, g, c, d, f in SHAPES:
        ks = jax.random.split(jax.random.PRNGKey(zlib.crc32(name.encode())), 4)
        x = jax.random.normal(ks[0], (g, c, d), dtype)
        wg = jax.random.normal(ks[1], (g, d, f), dtype) * 0.1
        wu = jax.random.normal(ks[2], (g, d, f), dtype) * 0.1
        wd = jax.random.normal(ks[3], (g, f, d), dtype) * 0.1
        counts = _skewed_counts(g, c, seed=g * c)
        gs = jnp.asarray(counts, jnp.int32)
        # Zero rows past each count, as bucket_dispatch produces them.
        x = x * (jnp.arange(c)[None, :, None] < gs[:, None, None])

        einsum_fn = jax.jit(expert_ffn_ref)

        @jax.jit
        def padded_fn(x, wg, wu, wd):
            h = gmm_dual_act(x, wg, wu, interpret=interpret)
            return gmm(h, wd, interpret=interpret)

        ragged_fn = jax.jit(
            lambda x, wg, wu, wd, gs: expert_ffn_ragged(
                x, wg, wu, wd, gs, interpret=interpret
            )
        )

        # Cross-check before timing.
        ref = np.asarray(einsum_fn(x, wg, wu, wd))
        np.testing.assert_allclose(
            np.asarray(ragged_fn(x, wg, wu, wd, gs)), ref, rtol=2e-4, atol=2e-4
        )

        flop_per_row = 6 * d * f  # 3 matmuls, 2 flop/MAC
        padded_gf = g * c * flop_per_row / 1e9
        achieved_gf = int(counts.sum()) * flop_per_row / 1e9
        bm = min(BM, c)
        ragged_rows = sum(math.ceil(cnt / bm) * bm for cnt in counts)
        ragged_exec_gf = ragged_rows * flop_per_row / 1e9

        t_e = _time(einsum_fn, x, wg, wu, wd, iters=iters)
        t_p = _time(padded_fn, x, wg, wu, wd, iters=iters)
        t_r = _time(ragged_fn, x, wg, wu, wd, gs, iters=iters)

        rows.append(
            {
                "shape": name,
                "G": g,
                "C": c,
                "D": d,
                "F": f,
                "tokens_routed": int(counts.sum()),
                "tokens_padded": g * c,
                "group_sizes": counts.tolist(),
                "padded_gflop": round(padded_gf, 4),
                "achieved_gflop": round(achieved_gf, 4),
                "paths": {
                    "einsum": {
                        "wall_ms": round(t_e * 1e3, 3),
                        "exec_gflop": round(padded_gf, 4),
                        "utilization": round(achieved_gf / padded_gf, 4),
                    },
                    "gmm_padded": {
                        "wall_ms": round(t_p * 1e3, 3),
                        "exec_gflop": round(padded_gf, 4),
                        "utilization": round(achieved_gf / padded_gf, 4),
                    },
                    "gmm_ragged": {
                        "wall_ms": round(t_r * 1e3, 3),
                        "exec_gflop": round(ragged_exec_gf, 4),
                        "utilization": round(
                            achieved_gf / ragged_exec_gf, 4
                        ) if ragged_exec_gf else 1.0,
                        "flop_vs_padded": round(
                            ragged_exec_gf / padded_gf, 4
                        ),
                    },
                },
            }
        )
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_kernels.json")
    ap.add_argument("--iters", type=int, default=20)
    args = ap.parse_args()

    rows = run(iters=args.iters)
    doc = {
        "bench": "kernels_expert_ffn",
        "backend": jax.default_backend(),
        "interpret": default_interpret(),
        "jax": jax.__version__,
        "host": platform.machine(),
        "note": (
            "wall_ms on non-TPU backends runs the Pallas paths in interpret "
            "mode (semantics, not speed); FLOP accounting is backend-"
            "independent. utilization = achieved/executed FLOPs."
        ),
        "shapes": rows,
    }
    with open(args.out, "w") as fh:
        json.dump(doc, fh, indent=2)
        fh.write("\n")
    print(json.dumps(doc, indent=2))


if __name__ == "__main__":
    main()
