"""Kernel-path benchmark: dispatch + expert-FFN + combine (einsum vs padded
vs ragged vs fused-gather vs fused-compact), plus dense-vs-paged decode
attention KV-byte accounting.

Each shape cell drives the full MoE expert hot path *including token
dispatch and the combine leg* (both HBM round-trips the fused paths exist
to remove) — every path ends in the per-token weighted combine so outputs
are directly comparable:

* ``einsum_padded_dispatch``  — ``bucket_dispatch`` into ``(G, C, d)``
  buffers + the XLA einsum FFN + ``bucket_combine`` (the pre-kernel
  reference);
* ``gmm_padded_dispatch``     — ``bucket_dispatch`` + the padded Pallas
  kernels (``gmm_dual_act`` + ``gmm``): every capacity row hits the MXU;
* ``gmm_ragged_padded_dispatch`` — ``bucket_dispatch`` + the count-aware
  kernels: row-tiles past each bucket's fill skip the MXU, but the padded
  buffers are still written/read through HBM on both legs;
* ``gmm_gather_fused_dispatch``  — ``dispatch_metadata`` + the fused gather
  kernels (``gmm_dual_act_gather`` + ``gmm_ragged``): token rows stay in a
  flat compacted array and the kernel prologue gathers them via
  scalar-prefetched per-bucket offsets — the ``(G, C, d)`` *input* buffer
  never exists, but the FFN output is still bucket-padded and the combine
  reads it;
* ``gmm_compact_fused_combine`` — the gather prologue **plus the
  ``gmm_scatter`` epilogue**: the down-projection writes result tiles back
  at the same per-bucket offsets, so neither the padded input nor the
  padded output buffer exists; ``combine_from_rows`` gathers each kept
  copy through the dispatch metadata.

Besides wall-clock, each row reports the FLOP accounting (``padded_gflop``
= what a capacity-padded pass must execute, ``achieved_gflop`` = useful
work at the measured routing, ``exec_gflop`` = what the path actually
runs at tile granularity), ``dispatch_hbm_mb`` — the bytes the dispatch
stage moves through HBM (padded: write + read of ``G*C*d``; fused: a
row-granular write of the ``R = sum(counts)`` compacted rows + a
tile-granular gather-DMA read, ``sum(ceil(count/bm)*bm)`` rows — the same
ceil-tile convention as ``exec_gflop``) — and ``combine_hbm_mb``, the
mirror accounting for the combine leg (padded paths write + read the
``G*C*d`` FFN output; the compact path's scatter epilogue writes
tile-granular rows and the metadata combine gathers the ``R`` live rows).
``utilization`` = achieved/executed FLOPs.

Shape cells cover balanced routing (every bucket full — the fused paths
must not lose here) and zipf-skewed routing (fig. 6 imbalance — where
tile-skipping plus the smaller dispatch *and* combine footprints win).

Usage::

    PYTHONPATH=src python benchmarks/bench_kernels.py [--out BENCH_kernels.json]
    PYTHONPATH=src python benchmarks/bench_kernels.py --smoke   # CI gate

``--smoke`` runs one tiny FFN cell + one tiny decode cell with 2
iterations (interpret mode on CPU) and exits non-zero on any parity
failure — a kernel-dispatch or paged-decode regression fails the gate
even when the full parity suite isn't run.

On CPU the Pallas paths execute in interpret mode (kernel *semantics*, not
kernel speed) — wall-clock comparisons are only meaningful on TPU, and the
JSON records backend + interpret so numbers aren't misread. The FLOP and
dispatch-byte accounting is backend-independent.
"""

from __future__ import annotations

import argparse
import json
import math
import platform
import sys
import time
import zlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_decode.ops import flash_decode_op, flash_decode_paged_op
from repro.kernels.flash_decode.ref import decode_ref
from repro.kernels.gmm.gmm import gmm, gmm_dual_act
from repro.kernels.gmm.ops import (
    expert_ffn_gather,
    expert_ffn_gather_compact,
    expert_ffn_ragged,
)
from repro.kernels.gmm.ref import expert_ffn_ref
from repro.kernels.registry import default_interpret
from repro.parallel.collectives import (
    bucket_combine,
    bucket_dispatch,
    combine_from_rows,
    dispatch_metadata,
    kept_counts,
)

# (name, G, C, D, F, balanced) — G buckets of capacity C, d_model D, expert
# hidden F. Mirrors smoke-to-midsize EP cells (slots x capacity after
# dispatch); balanced cells fill every bucket, skewed cells draw zipf counts.
SHAPES = [
    ("smoke_4x64", 4, 64, 64, 128, False),
    ("balanced_8x128", 8, 128, 128, 256, True),
    ("ep_16x128", 16, 128, 128, 512, False),
    ("skewed_32x64", 32, 64, 128, 256, False),
]
SMOKE_SHAPES = [("smoke_4x16", 4, 16, 16, 32, False)]

BM = 128  # row-tile the ragged kernels mask at (see kernels/gmm/ragged.py)

# Decode cells: (name, B, max_seq, lengths, K, H, hd, page_size). Dense
# flash-decode streams the whole (B, max_seq) cache and masks; paged decode
# walks only each request's live pages — `kv_hbm_mb` is the bandwidth story.
DECODE_SHAPES = [
    # hd/page multiples of 128 so the cells stay compiled-eligible on TPU
    # (can_flash_decode / can_flash_decode_paged gates).
    ("decode_short_balanced", 4, 1024, [256, 256, 256, 256], 2, 8, 128, 128),
    ("decode_long_balanced", 4, 1024, [1024, 1024, 1024, 1024], 2, 8, 128, 128),
    ("decode_ragged", 4, 2048, [128, 256, 512, 1024], 2, 8, 128, 128),
]
DECODE_SMOKE_SHAPES = [("decode_smoke", 2, 64, [20, 48], 2, 4, 16, 16)]


def _skewed_counts(g: int, c: int, seed: int) -> np.ndarray:
    """Zipf-ish routing skew: a few hot experts near capacity, a long tail
    (incl. empties) — the fig. 6 imbalance regime."""
    rng = np.random.default_rng(seed)
    raw = rng.zipf(1.5, size=g).astype(np.float64)
    counts = np.floor(c * raw / raw.max()).astype(np.int64)
    counts[rng.permutation(g)[: max(g // 8, 1)]] = 0  # idle slots
    return np.clip(counts, 0, c)


def _ids_from_counts(counts: np.ndarray) -> np.ndarray:
    """A token stream whose per-bucket histogram is exactly ``counts``,
    in a seeded random order so dispatch never sees pre-sorted input."""
    ids = np.concatenate([np.full(c, g, np.int32) for g, c in enumerate(counts)])
    rng = np.random.default_rng(int(counts.sum()))
    return rng.permutation(ids)


def _time(fn, *args, iters: int = 20, warmup: int = 3) -> float:
    """Best-of-N wall time: the minimum is the standard noise-robust
    estimator on shared/virtualized hosts (medians here swing 2-3x with
    CPU steal; the floor is what the code costs)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.min(times))


def run(iters: int = 20, smoke: bool = False) -> list[dict]:
    interpret = default_interpret()
    dtype = jnp.float32
    rows = []
    for name, g, c, d, f, balanced in SMOKE_SHAPES if smoke else SHAPES:
        ks = jax.random.split(jax.random.PRNGKey(zlib.crc32(name.encode())), 4)
        counts = (
            np.full(g, c, np.int64) if balanced else _skewed_counts(g, c, seed=g * c)
        )
        n_tok = int(counts.sum())
        ids = jnp.asarray(_ids_from_counts(counts))[:, None]        # (n, 1)
        xt = jax.random.normal(ks[0], (n_tok, d), dtype)            # token stream
        wg = jax.random.normal(ks[1], (g, d, f), dtype) * 0.1
        wu = jax.random.normal(ks[2], (g, d, f), dtype) * 0.1
        wd = jax.random.normal(ks[3], (g, f, d), dtype) * 0.1

        wt = jnp.ones(ids.shape, dtype)  # router weights (k = 1)

        @jax.jit
        def einsum_fn(xt, ids, wg, wu, wd):
            bufs, slots, keep = bucket_dispatch(xt, ids, g, c)
            y = expert_ffn_ref(bufs, wg, wu, wd)
            return bucket_combine(y, ids, slots, keep, wt)

        @jax.jit
        def padded_fn(xt, ids, wg, wu, wd):
            bufs, slots, keep = bucket_dispatch(xt, ids, g, c)
            h = gmm_dual_act(bufs, wg, wu, interpret=interpret)
            return bucket_combine(gmm(h, wd, interpret=interpret), ids, slots, keep, wt)

        @jax.jit
        def ragged_fn(xt, ids, wg, wu, wd):
            bufs, slots, keep = bucket_dispatch(xt, ids, g, c)
            gs = kept_counts(ids, keep, g)
            y = expert_ffn_ragged(bufs, wg, wu, wd, gs, interpret=interpret)
            return bucket_combine(y, ids, slots, keep, wt)

        @jax.jit
        def fused_fn(xt, ids, wg, wu, wd):
            row_ids, offsets, gs, slots, keep = dispatch_metadata(ids, g, c)
            y = expert_ffn_gather(
                xt[row_ids], wg, wu, wd, offsets, gs,
                capacity=c, interpret=interpret,
            )
            return bucket_combine(y, ids, slots, keep, wt)

        @jax.jit
        def compact_fn(xt, ids, wg, wu, wd):
            row_ids, offsets, gs, slots, keep = dispatch_metadata(ids, g, c)
            y = expert_ffn_gather_compact(
                xt[row_ids], wg, wu, wd, offsets, gs,
                capacity=c, interpret=interpret,
            )
            return combine_from_rows(y, offsets[ids] + slots, keep, wt)

        # Cross-check all paths before timing — the outputs are per-token
        # combined results, so padded-vs-compact divergence on *either* leg
        # (dispatch or combine) fails here.
        ref = np.asarray(einsum_fn(xt, ids, wg, wu, wd))
        for label, fn in (
            ("ragged", ragged_fn),
            ("fused", fused_fn),
            ("compact", compact_fn),
        ):
            np.testing.assert_allclose(
                np.asarray(fn(xt, ids, wg, wu, wd)), ref,
                rtol=2e-4, atol=2e-4, err_msg=f"{name}:{label} parity",
            )

        flop_per_row = 6 * d * f  # 3 matmuls, 2 flop/MAC
        padded_gf = g * c * flop_per_row / 1e9
        achieved_gf = n_tok * flop_per_row / 1e9
        bm = min(BM, c)
        ragged_rows = sum(math.ceil(cnt / bm) * bm for cnt in counts)
        ragged_exec_gf = ragged_rows * flop_per_row / 1e9
        row_bytes = d * np.dtype(np.float32).itemsize
        padded_dispatch_mb = 2 * g * c * row_bytes / 1e6   # scatter out + read in
        # Fused legs are half row-granular (XLA scatter/gather of the
        # compacted rows), half tile-granular (the kernel's dynamic-offset
        # DMAs move whole (bm, ·) tiles, padding included — same ceil-tile
        # convention as exec_gflop): dispatch writes n_tok rows and the
        # gather prologue reads ragged_rows; the scatter epilogue writes
        # ragged_rows and the combine gathers n_tok.
        fused_dispatch_mb = (n_tok + ragged_rows) * row_bytes / 1e6
        padded_combine_mb = 2 * g * c * row_bytes / 1e6
        compact_combine_mb = (ragged_rows + n_tok) * row_bytes / 1e6

        t_e = _time(einsum_fn, xt, ids, wg, wu, wd, iters=iters)
        t_p = _time(padded_fn, xt, ids, wg, wu, wd, iters=iters)
        t_r = _time(ragged_fn, xt, ids, wg, wu, wd, iters=iters)
        t_f = _time(fused_fn, xt, ids, wg, wu, wd, iters=iters)
        t_c = _time(compact_fn, xt, ids, wg, wu, wd, iters=iters)

        def _path(t, exec_gf, dispatch_mb, combine_mb):
            return {
                "wall_ms": round(t * 1e3, 3),
                "exec_gflop": round(exec_gf, 4),
                "utilization": round(achieved_gf / exec_gf, 4) if exec_gf else 1.0,
                "dispatch_hbm_mb": round(dispatch_mb, 4),
                "combine_hbm_mb": round(combine_mb, 4),
            }

        rows.append(
            {
                "shape": name,
                "G": g,
                "C": c,
                "D": d,
                "F": f,
                "routing": "balanced" if balanced else "skewed",
                "tokens_routed": n_tok,
                "tokens_padded": g * c,
                "group_sizes": counts.tolist(),
                "padded_gflop": round(padded_gf, 4),
                "achieved_gflop": round(achieved_gf, 4),
                "paths": {
                    "einsum_padded_dispatch": _path(
                        t_e, padded_gf, padded_dispatch_mb, padded_combine_mb
                    ),
                    "gmm_padded_dispatch": _path(
                        t_p, padded_gf, padded_dispatch_mb, padded_combine_mb
                    ),
                    "gmm_ragged_padded_dispatch": _path(
                        t_r, ragged_exec_gf, padded_dispatch_mb, padded_combine_mb
                    ),
                    "gmm_gather_fused_dispatch": _path(
                        t_f, ragged_exec_gf, fused_dispatch_mb, padded_combine_mb
                    ),
                    "gmm_compact_fused_combine": _path(
                        t_c, ragged_exec_gf, fused_dispatch_mb, compact_combine_mb
                    ),
                },
            }
        )
    return rows


def run_decode(iters: int = 20, smoke: bool = False) -> list[dict]:
    """Dense vs paged decode attention: parity + KV HBM-byte accounting.

    Bytes model (fp32, k + v): dense reads ``B * max_seq`` cache rows per
    step regardless of context; paged reads ``sum_b ceil(len_b / page) *
    page`` rows (the dead-block clamp elides everything past each request's
    live pages). Wall-clock is interpret-mode semantics off-TPU.
    """
    interpret = default_interpret()
    rows = []
    for name, b, max_seq, lengths, kv, h, hd, bs in (
        DECODE_SMOKE_SHAPES if smoke else DECODE_SHAPES
    ):
        ks = jax.random.split(jax.random.PRNGKey(zlib.crc32(name.encode())), 3)
        nb = -(-max_seq // bs)
        q = jax.random.normal(ks[0], (b, h, hd))
        k = jax.random.normal(ks[1], (b, nb * bs, kv, hd))
        v = jax.random.normal(ks[2], (b, nb * bs, kv, hd))
        ln = jnp.asarray(lengths, jnp.int32)
        valid = (jnp.arange(nb * bs)[None, :] < ln[:, None]).astype(jnp.int32)
        pool_k = k.reshape(b * nb, bs, kv, hd)
        pool_v = v.reshape(b * nb, bs, kv, hd)
        tables = jnp.arange(b * nb, dtype=jnp.int32).reshape(b, nb)

        dense_fn = lambda q, k, v, m: flash_decode_op(q, k, v, m, interpret=interpret)
        paged_fn = lambda q, pk, pv, t, l: flash_decode_paged_op(
            q, pk, pv, t, l, interpret=interpret
        )

        ref = np.asarray(decode_ref(q, k, v, valid))
        np.testing.assert_allclose(
            np.asarray(dense_fn(q, k, v, valid)), ref,
            rtol=2e-4, atol=2e-4, err_msg=f"{name}:dense parity",
        )
        np.testing.assert_allclose(
            np.asarray(paged_fn(q, pool_k, pool_v, tables, ln)), ref,
            rtol=2e-4, atol=2e-4, err_msg=f"{name}:paged parity",
        )

        row_bytes = 2 * kv * hd * np.dtype(np.float32).itemsize  # k + v
        dense_mb = b * nb * bs * row_bytes / 1e6
        live_pages = sum(-(-l // bs) for l in lengths)
        paged_mb = live_pages * bs * row_bytes / 1e6

        t_d = _time(dense_fn, q, k, v, valid, iters=iters)
        t_p = _time(paged_fn, q, pool_k, pool_v, tables, ln, iters=iters)
        rows.append(
            {
                "shape": name,
                "B": b,
                "max_seq": max_seq,
                "page_size": bs,
                "lengths": list(lengths),
                "tokens_live": int(sum(lengths)),
                "tokens_streamed_dense": b * nb * bs,
                "tokens_streamed_paged": live_pages * bs,
                "paths": {
                    "flash_decode_dense_masked": {
                        "wall_ms": round(t_d * 1e3, 3),
                        "kv_hbm_mb": round(dense_mb, 4),
                    },
                    "flash_decode_paged": {
                        "wall_ms": round(t_p * 1e3, 3),
                        "kv_hbm_mb": round(paged_mb, 4),
                    },
                },
                "kv_bytes_ratio_dense_over_paged": round(dense_mb / paged_mb, 3),
            }
        )
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_kernels.json")
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="tiny FFN + decode cells, 2 iters: fast kernel regression gate",
    )
    args = ap.parse_args()

    iters = 2 if args.smoke else args.iters
    try:
        rows = run(iters=iters, smoke=args.smoke)
        decode_rows = run_decode(iters=iters, smoke=args.smoke)
    except AssertionError as e:  # parity failure must fail the gate loudly
        print(f"KERNEL PARITY FAILURE: {e}", file=sys.stderr)
        raise SystemExit(1)
    doc = {
        "bench": "kernels_expert_ffn_dispatch",
        "backend": jax.default_backend(),
        "interpret": default_interpret(),
        "jax": jax.__version__,
        "host": platform.machine(),
        "smoke": args.smoke,
        "note": (
            "wall_ms on non-TPU backends runs the Pallas paths in interpret "
            "mode (semantics, not speed); FLOP and byte accounting is "
            "backend-independent. utilization = achieved/executed FLOPs; "
            "dispatch_hbm_mb / combine_hbm_mb = HBM bytes each leg moves; "
            "fused-path DMA sides are counted at ceil-tile granularity "
            "(the kernels move whole bm-row tiles), matching exec_gflop "
            "(the fused gather path never materializes the padded input "
            "buckets; the compact path's gmm_scatter epilogue never "
            "materializes the padded FFN output either, and "
            "combine_from_rows reads only live rows). All paths end in the "
            "per-token combine, so parity covers both legs. This bench "
            "drives the local/ESP-style dispatch; the EP all_to_all path "
            "keeps statically-sized exchange buffers on both legs (equal "
            "splits), where the fusion instead removes the receive-side "
            "repack + padded FFN input/output. decode_shapes compare "
            "dense masked flash-decode (streams B*max_seq KV rows/step) "
            "against the paged block-table kernel (streams only live "
            "pages): kv_hbm_mb tracks context length, not max_seq."
        ),
        "shapes": rows,
        "decode_shapes": decode_rows,
    }
    if args.smoke:
        print(json.dumps(doc, indent=2))
        print("BENCH SMOKE OK")
        return
    with open(args.out, "w") as fh:
        json.dump(doc, fh, indent=2)
        fh.write("\n")
    print(json.dumps(doc, indent=2))


if __name__ == "__main__":
    main()
