"""Fig. 15: run-time device-load traces under the four balancing regimes.

Reports the steady-state peak/mean device load, migration counts and
exposed interruption time over a mixed-scenario trace (8x8 WSC,
DeepSeek-V3)."""

from benchmarks.common import row, wsc_system
from repro.core.simulator import run_serving_trace
from repro.core.traces import mixed_scenario_trace
from repro.core.workloads import DEEPSEEK_V3


def run():
    rows = []
    sys_ = wsc_system(8, 8, 8, 8, "er")
    trace = mixed_scenario_trace(256, 8192, 150, period=75, seed=0)
    for bal in ("none", "greedy", "topo", "topo_ni"):
        res = run_serving_trace(
            DEEPSEEK_V3, sys_, trace, 256, 8, balancer=bal, alpha=1.0
        )
        tail = res.peak_over_mean[-30:]
        rows.append(
            row(
                f"fig15/{bal}",
                float(res.iteration_times.mean() * 1e6),
                f"peak_over_mean={tail.mean():.2f};migs={res.migrations};"
                f"exposed_ms={res.exposed_overhead * 1e3:.2f}",
            )
        )
    return rows
