"""Fig. 6: all-to-all latency surges with scale while all-reduce stays flat.

Sweeps WSC scale from a single 4x4 to a 2-wafer 8x8 system and reports the
two collectives' latencies for a fixed per-group token count.
"""

from benchmarks.common import row, wsc_system
from repro.core.simulator import simulate_iteration
from repro.core.workloads import DEEPSEEK_V3


def run():
    rows = []
    cases = [
        ("4x4", 4, 4, 4, 4, 1),
        ("6x6", 6, 6, 6, 6, 1),
        ("8x8", 8, 8, 8, 8, 1),
        ("2x(8x8)", 8, 8, 8, 16, 2),
    ]
    for name, r, c, dp, tp, wafers in cases:
        sys_ = wsc_system(r, c, dp, tp, "baseline", n_wafers=wafers)
        bd = simulate_iteration(DEEPSEEK_V3, sys_, 256, tp)
        ar, a2a = bd.allreduce * 1e6, bd.alltoall * 1e6
        rows.append(
            row(f"fig06/{name}/allreduce", ar, f"ratio_a2a_over_ar={a2a / ar:.2f}")
        )
        rows.append(row(f"fig06/{name}/alltoall", a2a, f"devices={r * c * wafers}"))
    return rows
