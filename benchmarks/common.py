"""Shared helpers for the paper-figure benchmarks."""

from __future__ import annotations

from repro.core.er_mapping import (
    baseline_mapping,
    er_mapping,
    hierarchical_er_mapping,
)
from repro.core.hardware import DGX, NVL72, WSC
from repro.core.simulator import ClusterSystem, WSCSystem
from repro.core.topology import MeshTopology


def wsc_system(rows, cols, dp, tp, mapping="er", n_wafers=1, hier=False):
    topo = MeshTopology(rows, cols, n_wafers)
    ctor = {
        "baseline": baseline_mapping,
        "er": er_mapping,
        "her": hierarchical_er_mapping,
    }[mapping]
    return WSCSystem(WSC, ctor(topo, dp, tp), hierarchical=hier)


def dgx_system(n_devices, tp=8):
    return ClusterSystem(DGX, n_devices, tp=tp)


def nvl72_system(tp=8):
    return ClusterSystem(NVL72, 72, tp=tp)


def row(name: str, us: float, derived: str) -> dict:
    return {"name": name, "us_per_call": round(us, 3), "derived": derived}


def comm_us(bd) -> float:
    """Communication latency of one iteration (µs)."""
    return (bd.allreduce + bd.alltoall) * 1e6
