"""Fig. 14(a): ESP (expert-sharding parallelism) for few-large-expert
models (DBRX 16e, Mixtral 8e) — all-to-all is eliminated; the EP-group
all-reduce dominates; ER still helps but less."""

from benchmarks.common import comm_us, dgx_system, row, wsc_system
from repro.core.simulator import simulate_iteration
from repro.core.workloads import DBRX, MIXTRAL_8X22B


def run():
    rows = []
    for model in (DBRX, MIXTRAL_8X22B):
        dgx = comm_us(simulate_iteration(model, dgx_system(32), 256, 8))
        base = comm_us(
            simulate_iteration(model, wsc_system(6, 6, 6, 6, "baseline"), 256, 6)
        )
        er = comm_us(
            simulate_iteration(model, wsc_system(6, 6, 6, 6, "er"), 256, 6)
        )
        rows.append(
            row(
                f"fig14a/{model.name}",
                er,
                f"wsc_vs_dgx={1 - base / dgx:+.0%};er_vs_base={1 - er / base:+.0%}",
            )
        )
    return rows
