"""Fig. 16: balancing strategies across serving scenarios.

Single-scenario (math-only: ratios stabilize, few migrations) vs mixed
(cyclic drift: continuous rebalancing). Reports mean iteration time,
MoE-compute reduction vs no balancing, and exposed migration overhead.
"""

from benchmarks.common import row, wsc_system
from repro.core.simulator import run_serving_trace
from repro.core.traces import mixed_scenario_trace, single_scenario_trace
from repro.core.workloads import DEEPSEEK_V3


def run():
    rows = []
    sys_ = wsc_system(8, 8, 8, 8, "er")
    scenarios = {
        "math_only": single_scenario_trace(256, 8192, 120, "math", seed=0),
        "mixed": mixed_scenario_trace(256, 8192, 120, period=60, seed=0),
    }
    for sname, trace in scenarios.items():
        base = run_serving_trace(
            DEEPSEEK_V3, sys_, trace, 256, 8, balancer="none"
        )
        moe_base = base.breakdown_last.moe_compute
        for bal in ("greedy", "topo", "topo_ni"):
            res = run_serving_trace(
                DEEPSEEK_V3, sys_, trace, 256, 8, balancer=bal, alpha=1.0
            )
            moe_gain = 1 - res.breakdown_last.moe_compute / moe_base
            rows.append(
                row(
                    f"fig16/{sname}/{bal}",
                    float(res.iteration_times.mean() * 1e6),
                    f"moe_compute_gain={moe_gain:+.0%};"
                    f"exposed_ms={res.exposed_overhead * 1e3:.2f};"
                    f"migs={res.migrations}",
                )
            )
    return rows
