"""Fig. 13(c): ER-Mapping gain across WSC scales and TP degrees (Qwen3)."""

from benchmarks.common import comm_us, row, wsc_system
from repro.core.simulator import simulate_iteration
from repro.core.workloads import QWEN3_235B


def run():
    rows = []
    cases = [
        (4, 4, 4, 4), (4, 4, 2, 8),
        (6, 6, 6, 6), (6, 6, 4, 9), (6, 6, 9, 4),
        (8, 8, 8, 8), (8, 8, 4, 16), (8, 8, 16, 4),
    ]
    for r, c, dp, tp in cases:
        base = comm_us(
            simulate_iteration(
                QWEN3_235B, wsc_system(r, c, dp, tp, "baseline"), 256, tp
            )
        )
        er = comm_us(
            simulate_iteration(QWEN3_235B, wsc_system(r, c, dp, tp, "er"), 256, tp)
        )
        rows.append(
            row(
                f"fig13c/{r}x{c}/dp{dp}xtp{tp}",
                er,
                f"er_gain={1 - er / base:+.0%}",
            )
        )
    return rows
