# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
import importlib
import sys
import time

MODULES = [
    "fig06_comm_imbalance",
    "fig13a_token_count",
    "fig13b_models",
    "fig13c_scale_parallelism",
    "fig13d_her",
    "fig14a_esp",
    "fig14b_allgather",
    "fig15_load_traces",
    "fig16_balancers",
    "fig17_nvl72",
    "roofline",
]


def main() -> None:
    only = sys.argv[1:] or None
    print("name,us_per_call,derived")
    for modname in MODULES:
        if only and not any(o in modname for o in only):
            continue
        mod = importlib.import_module(f"benchmarks.{modname}")
        t0 = time.time()
        try:
            rows = mod.run()
        except Exception as e:  # keep the harness running
            print(f"{modname},-1,ERROR:{type(e).__name__}:{e}")
            continue
        for r in rows:
            print(f"{r['name']},{r['us_per_call']},{r['derived']}")
        print(
            f"# {modname}: {len(rows)} rows in {time.time() - t0:.1f}s",
            file=sys.stderr,
        )


if __name__ == "__main__":
    main()
