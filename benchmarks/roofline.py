"""§Roofline: three-term analysis per (arch x shape x mesh) from dry-run
artifacts (results/dryrun/*.json).

Terms (seconds, per step):
  compute    = HLO_FLOPs_dev / peak_FLOPs_chip
  memory     = HLO_bytes_dev / HBM_bw_chip
  collective = collective_bytes_dev / ICI_link_bw_chip

The compiled module is the per-device SPMD program, so cost-analysis values
are already per-chip (equivalent to the spec's "/ chips" on global values).
Loop-body undercounting is corrected by the dry-run's unrolled layer probes:
per-unit costs = probe2 - probe1, total = probe1 + (units-1) * per_unit.

MODEL_FLOPS uses 6*N*D for training (N = params; active params for MoE) and
2*N_active*D for inference; the ratio against HLO FLOPs exposes
remat/replication waste. Roofline fraction = ideal time at peak compute /
max(term) — the score we hillclimb in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import glob
import json
import os

from repro.configs import SHAPES, get_config

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link (1 link conservative)

SUGGEST = {
    "compute": "raise MXU utilization: fuse ops/pack GQA heads; reduce "
               "replicated compute on the model axis",
    "memory": "cut HBM traffic: fuse attention (flash), avoid materialized "
              "score/hidden tensors, bf16 end-to-end",
    "collective": "re-place collectives: ER tile locality, fewer/larger "
                  "fused all-reduces, overlap with compute",
}


def _extrapolate(rec: dict, key: str) -> float:
    full = rec.get(key) or 0.0
    p1, p2 = rec.get("probe1"), rec.get("probe2")
    units = rec.get("units", 1)
    if not p1 or not p2:
        return float(full)

    def get(p):
        if key == "collective_total":
            return (p.get("collectives") or {}).get("total", 0.0)
        return p.get(key) or 0.0

    per_unit = max(get(p2) - get(p1), 0.0)
    return float(get(p1) + (units - 1) * per_unit)


def _model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # decode: one token/seq


def _useful_bytes(arch: str, shape_name: str, n_dev: int) -> float:
    """Analytic floor on per-device HBM traffic for one step (bf16 params/
    activations, fp32 optimizer moments). This anchors the roofline's
    operational intensity — the HLO ``bytes accessed`` from the CPU-lowered
    module overestimates TPU traffic (no TPU-style fusion), so the
    *fraction* is computed against this floor while the raw HLO terms stay
    in the table for hillclimbing."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    n_total = cfg.param_count()
    d = cfg.d_model
    model_shard = 16 if cfg.block_pattern != "xlstm" else 1

    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        # params: bf16 fwd read + bwd read, fp32 m/v read+write, param write
        param_traffic = n_total * (2 + 2 + 16 + 4) / model_shard
        act_traffic = 3 * cfg.n_layers * tokens * d * 2 / n_dev
        return param_traffic + act_traffic
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        param_traffic = 2 * n_active / model_shard
        act_traffic = 2 * cfg.n_layers * tokens * d * 2 / n_dev
        return param_traffic + act_traffic
    # decode: active params + KV/state cache stream through once
    kv_len = min(shape.seq_len, cfg.sliding_window or shape.seq_len)
    if cfg.block_pattern in ("zamba", "xlstm"):
        kv_len = 1  # O(1) recurrent state
    kv_layers = (
        cfg.n_layers // max(cfg.attn_every, 1)
        if cfg.block_pattern == "zamba"
        else cfg.n_layers
    )
    cache = (
        2 * kv_layers * shape.global_batch * kv_len
        * cfg.n_kv_heads * cfg.head_dim_ * 2
    )
    return 2 * n_active / model_shard + cache / n_dev


def analyze_record(rec: dict) -> dict | None:
    if rec.get("status") != "OK":
        return None
    n_dev = rec["n_devices"]
    flops_dev = _extrapolate(rec, "flops")
    bytes_dev = _extrapolate(rec, "bytes_accessed")
    coll_rec = dict(rec.get("collectives") or {})
    # extrapolate total collective bytes through the probes
    rec2 = dict(rec)
    rec2["collective_total"] = coll_rec.get("total", 0.0)
    coll_dev = _extrapolate(
        {**rec2, "probe1": rec.get("probe1"), "probe2": rec.get("probe2")},
        "collective_total",
    )
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_collective = coll_dev / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_collective}
    dominant = max(terms, key=terms.get)
    t_step = max(terms.values())
    model_flops = _model_flops(rec["arch"], rec["shape"])
    hlo_total = flops_dev * n_dev
    # Classic roofline: achieved useful FLOP/s per chip vs the attainable
    # rate at the workload's operational intensity (useful FLOPs / analytic
    # minimum HBM bytes) — bandwidth-bound cells get a fair ceiling.
    useful_bytes = _useful_bytes(rec["arch"], rec["shape"], n_dev)
    oi = model_flops / n_dev / max(useful_bytes, 1.0)
    attainable = min(PEAK_FLOPS, oi * HBM_BW)
    achieved = model_flops / n_dev / t_step if t_step else 0.0
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "dominant": dominant,
        "t_step_s": t_step,
        "model_flops": model_flops,
        "hlo_flops_total": hlo_total,
        "useful_ratio": model_flops / hlo_total if hlo_total else 0.0,
        "oi": oi,
        "roofline_fraction": achieved / attainable if attainable else 0.0,
        "suggestion": SUGGEST[dominant],
        "hbm_per_device_gb": (
            rec.get("argument_size_in_bytes", 0) + rec.get("temp_size_in_bytes", 0)
        )
        / 1e9,
    }


def load_all(dirname: str = "results/dryrun") -> list[dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(f) as fh:
            rec = json.load(fh)
        a = analyze_record(rec)
        if a:
            out.append(a)
        elif rec.get("status", "").startswith("SKIP"):
            out.append(
                {
                    "arch": rec["arch"],
                    "shape": rec["shape"],
                    "mesh": rec["mesh"],
                    "dominant": "SKIP",
                }
            )
    return out


def write_markdown(rows: list[dict], path: str = "results/roofline.md"):
    lines = [
        "| arch | shape | mesh | compute (ms) | memory (ms) | collective (ms) "
        "| dominant | useful FLOP ratio | roofline frac | HBM/dev (GB) |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["dominant"] == "SKIP":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | "
                f"SKIP (full attention) | — | — | — |"
            )
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute_s'] * 1e3:.2f} | {r['t_memory_s'] * 1e3:.2f} "
            f"| {r['t_collective_s'] * 1e3:.2f} | {r['dominant']} "
            f"| {r['useful_ratio']:.2f} | {r['roofline_fraction']:.2f} "
            f"| {r['hbm_per_device_gb']:.1f} |"
        )
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")
    return path


def run():
    rows = load_all()
    if rows:
        write_markdown(rows)
    # Paper-faithful baseline table (pre-hillclimb sweep), kept separately
    # so the reproduction and the beyond-paper gains are both visible.
    # NOTE: baseline JSONs predate the 2x all-reduce wire weighting, so
    # their collective column understates AR-heavy cells by up to 2x.
    base = load_all("results/dryrun_baseline")
    if base:
        write_markdown(base, "results/roofline_baseline.md")
    out = []
    for r in rows:
        if r["dominant"] == "SKIP":
            continue
        out.append(
            {
                "name": f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
                "us_per_call": round(r["t_step_s"] * 1e6, 1),
                "derived": (
                    f"dominant={r['dominant']};frac={r['roofline_fraction']:.2f};"
                    f"useful={r['useful_ratio']:.2f}"
                ),
            }
        )
    return out
