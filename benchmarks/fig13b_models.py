"""Fig. 13(b): ER-Mapping communication gains across the five MoE models
(6x6 WSC vs 4-node DGX; balanced loads, 256 tokens per group)."""

from benchmarks.common import comm_us, dgx_system, row, wsc_system
from repro.core.simulator import simulate_iteration
from repro.core.workloads import PAPER_MODELS


def run():
    rows = []
    for name, model in PAPER_MODELS.items():
        dgx = comm_us(simulate_iteration(model, dgx_system(32), 256, 8))
        base = comm_us(
            simulate_iteration(model, wsc_system(6, 6, 6, 6, "baseline"), 256, 6)
        )
        er = comm_us(
            simulate_iteration(model, wsc_system(6, 6, 6, 6, "er"), 256, 6)
        )
        rows.append(
            row(
                f"fig13b/{name}",
                er,
                f"wsc_vs_dgx={1 - base / dgx:+.0%};er_vs_base={1 - er / base:+.0%}",
            )
        )
    return rows
