"""Fig. 17: multi-WSC cluster (4x 8x8 wafers = 256 devices) vs NVL72.

The paper's headline ablation ladder: baseline mapping -> +ER -> +HER ->
+topology-aware balancing -> +non-invasive balancing; compared against
NVL72 per-device MoE performance (EP=72, NVMe-hidden migration).
"""

from benchmarks.common import nvl72_system, row, wsc_system
from repro.core.simulator import run_serving_trace
from repro.core.traces import mixed_scenario_trace
from repro.core.workloads import DEEPSEEK_V3


def _perf_per_device(res, n_devices, tokens_iter):
    """Tokens/s/device over the trace."""
    return tokens_iter / res.iteration_times.mean() / n_devices


def run():
    rows = []
    model = DEEPSEEK_V3
    trace = mixed_scenario_trace(model.n_experts, 8192, 80, period=40, seed=0)
    tokens_iter = 256 * 8  # dp * tokens_per_group

    nvl = run_serving_trace(
        model, nvl72_system(tp=8), trace, 256, 8, balancer="greedy", alpha=1.0
    )
    nvl_perf = _perf_per_device(nvl, 72, 256 * 9)
    rows.append(
        row("fig17/nvl72+balancing", float(nvl.iteration_times.mean() * 1e6),
            f"per_device_tok_s={nvl_perf:.0f}")
    )

    ladder = [
        ("baseline", dict(mapping="her", hier=False), "none"),
        ("+er", dict(mapping="her", hier=False), "none"),
        ("+her", dict(mapping="her", hier=True), "none"),
        ("+topo_balance", dict(mapping="her", hier=True), "topo"),
        ("+ni_balance", dict(mapping="her", hier=True), "topo_ni"),
    ]
    for i, (name, kw, bal) in enumerate(ladder):
        mapping = "baseline" if name == "baseline" else kw["mapping"]
        sys_ = wsc_system(8, 8, 8, 32, mapping, n_wafers=4, hier=kw["hier"])
        res = run_serving_trace(
            model, sys_, trace, 256, 32, balancer=bal, alpha=1.0
        )
        perf = _perf_per_device(res, 256, tokens_iter * 4)
        rows.append(
            row(
                f"fig17/wsc/{name}",
                float(res.iteration_times.mean() * 1e6),
                f"per_device_tok_s={perf:.0f};vs_nvl72={perf / nvl_perf - 1:+.0%}",
            )
        )
    return rows
