"""Quickstart: the three layers of the framework in one script.

1. The paper's core — map a WSC mesh with ER-Mapping, compare collectives.
2. The model zoo — forward an assigned architecture (smoke scale).
3. The serving loop — batched generation with the NI-Balancer plumbing.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke
from repro.core.comm_model import A2AWorkload, mesh_allreduce, mesh_alltoall
from repro.core.er_mapping import baseline_mapping, er_mapping
from repro.core.ftd import ftd_stats
from repro.core.hardware import WSC
from repro.core.topology import MeshTopology
from repro.models import transformer as T
from repro.parallel.ctx import NO_MESH
from repro.runtime.serve import ServeConfig, Server

# --- 1. ER-Mapping on a 4x4 wafer ------------------------------------------
topo = MeshTopology(4, 4)
for name, mapping in (
    ("baseline", baseline_mapping(topo, 4, 4)),
    ("er", er_mapping(topo, 4, 4)),
):
    stats = ftd_stats(mapping)
    ar = mesh_allreduce(mapping, WSC, 256 * 8192)
    a2a = mesh_alltoall(mapping, WSC, A2AWorkload(256, 8192, 8))
    print(
        f"[core] {name:8s} FTD hops={stats.avg_hops:.2f} "
        f"intersections={stats.n_intersecting_pairs}  "
        f"allreduce={ar.time * 1e6:.2f}us  alltoall={a2a.time * 1e6:.2f}us"
    )

# --- 2. model zoo -----------------------------------------------------------
cfg = smoke(get_config("mixtral-8x22b"))
params = T.init_params(jax.random.PRNGKey(0), cfg)
tokens = jnp.ones((2, 16), jnp.int32)
logits, aux = T.forward(params, tokens, cfg)
print(f"[model] {cfg.name} smoke forward -> {logits.shape}, aux={float(aux['loss']):.3f}")

# --- 3. serving --------------------------------------------------------------
server = Server(cfg, NO_MESH, params, ServeConfig(max_seq=64, batch=2))
out = server.generate(jnp.ones((2, 8), jnp.int32), 8)
print(f"[serve] generated {out.shape} tokens: {out[0].tolist()}")
