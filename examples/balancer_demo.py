"""NI-Balancer walkthrough on the analytical evaluator: watch the load trace
drift, the Eq. 2 trigger fire, Algorithm 1 plan migrations, and the
Local/Global steps drain over cold links — zero exposed latency.

Run:  PYTHONPATH=src python examples/balancer_demo.py
"""

from repro.core.comm_model import A2AWorkload, link_heatmaps
from repro.core.er_mapping import er_mapping
from repro.core.hardware import WSC
from repro.core.migration import decompose
from repro.core.simulator import WSCSystem, run_serving_trace
from repro.core.topology import MeshTopology
from repro.core.traces import mixed_scenario_trace
from repro.core.workloads import DEEPSEEK_V3

topo = MeshTopology(4, 4)
mapping = er_mapping(topo, 4, 4)
sys_ = WSCSystem(WSC, mapping)

# 1. hot/cold links are complementary (the NI-Balancer's opportunity)
ar, a2a = link_heatmaps(mapping, WSC, 256 * 8192 * 2, A2AWorkload(256, 8192, 8))
print(f"links idle during all-to-all: {(a2a == 0).sum()}/{topo.n_links}")

# 2. decompose one long migration into Local -> Global -> Local steps
mig = (0, mapping.ftds[0][0], mapping.ftds[3][3])
steps = decompose(mig, mapping, 42e6)
print("migration steps:", [(s.kind, s.src, s.dst) for s in steps])

# 3. the full serving loop, all four policies
trace = mixed_scenario_trace(256, 2048, 100, period=50, seed=0)
for bal in ("none", "greedy", "topo", "topo_ni"):
    res = run_serving_trace(DEEPSEEK_V3, sys_, trace, 256, 4, balancer=bal, alpha=1.0)
    print(
        f"{bal:8s} iter={res.iteration_times.mean() * 1e3:.2f}ms  "
        f"peak/mean={res.peak_over_mean[-20:].mean():.2f}  "
        f"migrations={res.migrations}  exposed={res.exposed_overhead * 1e3:.2f}ms"
    )
