"""Continuous-batching serving with admission control, preemption and a
seeded fault-injection schedule — all on a single process: virtual EP runs
the NI-Balancer (replicas, migration, evacuation) over slot rows without a
device mesh.

  PYTHONPATH=src python examples/continuous_serving.py

Five ragged requests share a 3-slot batch over a deliberately undersized
page pool while the fault plan kills a (virtual) device, reports a
straggler, squeezes the pool and poisons one step's logits. Every request
still finishes, and its tokens are bit-identical to a sequential
fault-free run — the determinism contract docs/serving.md describes.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke
from repro.models import transformer as T
from repro.parallel.ctx import ParallelCtx
from repro.runtime.faults import FaultPlan
from repro.runtime.scheduler import FINISHED, RequestScheduler
from repro.runtime.serve import ServeConfig, Server

cfg = dataclasses.replace(
    smoke(get_config("dbrx-132b")), n_experts=4, experts_per_token=2
)
params = T.init_params(jax.random.PRNGKey(0), cfg)
# capacity_factor high enough that routing never drops a copy — the
# precondition for bit-exact replay (docs/serving.md, "Determinism").
ctx = ParallelCtx(capacity_factor=8.0)

rng = np.random.default_rng(0)
prompts = [
    rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
    for n in (5, 11, 3, 8, 13)
]
MAX_NEW = 8


def make_server(batch, pool_pages, prefill_chunk=None):
    return Server(
        cfg, ctx, jax.tree.map(jnp.copy, params),
        ServeConfig(max_seq=64, batch=batch, paged=True, page_size=8,
                    pool_pages=pool_pages, slots_per_device=3, virtual_ep=4,
                    alpha=0.1, prefill_chunk=prefill_chunk),
    )


print("sequential fault-free reference...")
ref = []
for p in prompts:
    sched = RequestScheduler(make_server(batch=1, pool_pages=64))
    req = sched.submit(p, max_new_tokens=MAX_NEW)
    sched.run()
    ref.append(list(req.tokens_out))

print("chaos run: 3 slots, 10-page pool, chunked admission, faults...")
plan = FaultPlan.chaos(seed=14, n_steps=12, n_devices=4, pressure_pages=5,
                       nan_slots=(0,))
for f in plan:
    print(f"  step {f.step:>2}: {f.kind}")
# prefill_chunk=8: admission rides the decode step's prefill lane, one
# 8-token chunk per tick — live slots keep emitting while prompts load.
sched = RequestScheduler(
    make_server(batch=3, pool_pages=10, prefill_chunk=8), faults=plan
)
reqs = [
    sched.submit(p, max_new_tokens=MAX_NEW, arrival=i)
    for i, p in enumerate(prompts)
]
sched.run()

for step, kind, detail in sched.events:
    print(f"  step {step:>2}: {kind} {detail}")
ok = True
for i, r in enumerate(reqs):
    match = list(r.tokens_out) == ref[i]
    ok &= r.state == FINISHED and match
    print(
        f"request {r.rid}: {r.state}, {len(r.tokens_out)} tokens, "
        f"{r.preemptions} preemption(s), parity={'OK' if match else 'FAIL'}"
    )
stats = sched.stats()
print(
    f"serving stats: max_ttft={stats['max_ttft_ticks']} ticks, "
    f"max_stall={stats['max_stall_ticks']} ticks, "
    f"queue_depth={stats['queue_depth']}, "
    f"prefill_backlog={stats['prefill_backlog']} tokens"
)
for rid, s in stats["per_request"].items():
    print(
        f"  request {rid}: ttft={s['ttft_ticks']} ticks, "
        f"stall={s['max_stall_ticks']}, tokens={s['n_tokens']}, "
        f"preemptions={s['preemptions']}"
    )
print(
    f"{'PARITY HELD' if ok else 'PARITY BROKEN'} under "
    f"{len(plan)} faults, {sched.n_preempted} preemption(s), "
    f"{sched.server.migrations} migration(s)"
)
