"""End-to-end training driver: a ~100M-parameter llama-family model trained
for a few hundred steps on the synthetic Markov corpus, with checkpointing
and restart drills along the way.

Run:  PYTHONPATH=src python examples/train_tiny_lm.py [--steps 300]
(about 100M params; on CPU expect ~1-2 s/step at batch 8 x 256.)
"""

import argparse
import dataclasses

import jax

from repro.configs import get_config
from repro.parallel.ctx import NO_MESH
from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.data import DataConfig, SyntheticLM
from repro.runtime.elastic import StepTimer
from repro.runtime.optimizer import AdamWConfig
from repro.runtime.train import init_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_tiny_lm")
    args = ap.parse_args()

    # ~100M params: llama3.2-1b narrowed to 8 layers x 768 wide, 8k vocab.
    cfg = dataclasses.replace(
        get_config("llama3.2-1b"),
        n_layers=8,
        d_model=768,
        n_heads=12,
        n_kv_heads=4,
        head_dim=64,
        d_ff=2048,
        vocab_size=8192,
        tie_embeddings=False,
    )
    print(f"model: {cfg.param_count() / 1e6:.1f}M params")

    opt = AdamWConfig(lr=1e-3, warmup_steps=30, total_steps=args.steps)
    step_fn = jax.jit(make_train_step(cfg, NO_MESH, opt), donate_argnums=(0,))
    data = SyntheticLM(DataConfig(cfg.vocab_size, args.batch, args.seq))
    mgr = CheckpointManager(args.ckpt, keep=2)

    state = init_state(jax.random.PRNGKey(0), cfg)
    start = 0
    if mgr.latest() is not None:
        state, meta = mgr.restore(state)
        start = meta["data_step"]
        print(f"resumed from step {start}")

    timer = StepTimer()
    for step in range(start, args.steps):
        with timer:
            state, met = step_fn(state, data.batch_at(step))
            jax.block_until_ready(met["loss"])
        if step % 20 == 0 or step == args.steps - 1:
            print(
                f"step {step:4d}  loss {float(met['loss']):.4f}  "
                f"lr {float(met['lr']):.2e}  {timer.last:.2f}s/step"
            )
        if (step + 1) % 100 == 0:
            mgr.async_save(step + 1, state, extra={"data_step": step + 1})
    mgr.wait()
    mgr.save(args.steps, state, extra={"data_step": args.steps})
    print(f"done; checkpoints in {args.ckpt}")


if __name__ == "__main__":
    main()
