"""Batched MoE serving with expert parallelism and the NI-Balancer active.

Needs multiple devices for real EP — run with forced host devices:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python examples/serve_moe.py
"""

import dataclasses
import time

import jax

from repro.configs import get_config, smoke
from repro.core.topology import MeshTopology
from repro.models import transformer as T
from repro.parallel.ctx import ParallelCtx
from repro.runtime.data import request_stream
from repro.runtime.elastic import drill_failure
from repro.runtime.serve import ServeConfig, Server

n_dev = len(jax.devices())
if n_dev >= 8:
    from repro.launch.mesh import make_mesh_compat

    mesh = make_mesh_compat((n_dev // 4, 4), ("data", "model"))
    ctx = ParallelCtx(mesh=mesh, capacity_factor=4.0)
    topo = MeshTopology(2, 2)
    dist = lambda a, b: topo.hops(topo.coord(a), topo.coord(b))
else:
    print(f"only {n_dev} device(s) — running the dense fallback")
    mesh, ctx, dist = None, ParallelCtx(), None

cfg = dataclasses.replace(
    smoke(get_config("dbrx-132b")), n_experts=8, experts_per_token=2
)
params = T.init_params(jax.random.PRNGKey(0), cfg)


class _null:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


with (mesh if mesh is not None else _null()):
    server = Server(
        cfg, ctx, params,
        ServeConfig(max_seq=128, batch=4, slots_per_device=3, alpha=0.3),
        distance=dist,
    )
    for i, prompt in zip(range(3), request_stream(cfg.vocab_size, 4, 12)):
        t0 = time.time()
        out = server.generate(prompt, 24)
        dt = time.time() - t0
        print(
            f"batch {i}: {out.shape} in {dt:.2f}s "
            f"({4 * 24 / dt:.1f} tok/s), migrations={server.migrations}"
        )
    if server.state is not None:
        print("failure drill:", drill_failure(server, device=1))
