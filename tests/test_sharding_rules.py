"""Sharding policy tests (pure: eval_shape only, no device math)."""

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.models import transformer as T
from repro.parallel.ctx import ParallelCtx
from repro.parallel.sharding import cache_specs, param_spec, params_specs


class _FakeMesh:
    shape = {"data": 16, "model": 16}


CTX = ParallelCtx(mesh=None)  # only n_model matters through param_spec


def test_core_param_rules():
    cfg = get_config("qwen2-72b")
    assert param_spec("embed", (152064, 8192), cfg, 16) == P("model", None)
    assert param_spec("lm_head", (8192, 152064), cfg, 16) == P(None, "model")
    assert param_spec("layers/attn/wq", (80, 8192, 8192), cfg, 16) == P(
        None, None, "model"
    )
    assert param_spec("layers/attn/wo", (80, 8192, 8192), cfg, 16) == P(
        None, "model", None
    )
    # non-divisible dims degrade to replication, never error
    assert param_spec("layers/attn/wk", (80, 8192, 1000), cfg, 16) == P(
        None, None, None
    )


def test_moe_param_rules():
    dbrx = get_config("dbrx-132b")
    # EP regime: slot rows sharded (16 % 16 == 0)
    assert param_spec("layers/moe/w_gate", (40, 16, 6144, 10752), dbrx, 16) == P(
        None, "model", None, None
    )
    mix = get_config("mixtral-8x22b")
    # ESP regime: hidden dim sharded (8 experts don't divide 16)
    assert param_spec("layers/moe/w_gate", (56, 8, 6144, 16384), mix, 16) == P(
        None, None, None, "model"
    )
    assert param_spec("layers/moe/w_down", (56, 8, 16384, 6144), mix, 16) == P(
        None, None, "model", None
    )
    assert param_spec("layers/moe/router", (56, 6144, 8), mix, 16) == P(
        None, None, None
    )


def test_xlstm_stays_replicated():
    cfg = get_config("xlstm-350m")
    assert param_spec("units/m/w_qkv", (6, 3, 1024, 3072), cfg, 16) == P(
        None, None, None, None
    )


def test_full_tree_specs_match_structure():
    """Every param leaf gets a spec of matching rank, for every arch."""
    from repro.configs import ARCHS

    ctx = ParallelCtx()
    object.__setattr__(ctx, "mesh", None)
    for arch in ARCHS:
        cfg = get_config(arch)
        shapes = jax.eval_shape(
            lambda k: T.init_params(k, cfg, jnp.bfloat16), jax.random.PRNGKey(0)
        )
        specs = params_specs(cfg, shapes, ctx)
        flat_s = jax.tree.leaves(shapes)
        flat_p = jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, P)
        )
        assert len(flat_s) == len(flat_p)
        for sh, sp in zip(flat_s, flat_p):
            assert len(sp) == len(sh.shape), (arch, sh.shape, sp)


def test_cache_specs_match_structure():
    ctx = ParallelCtx()
    for arch in ("llama3.2-1b", "zamba2-1.2b", "xlstm-350m", "seamless-m4t-medium"):
        cfg = get_config(arch)
        cache = jax.eval_shape(lambda: T.init_cache(cfg, 8, 64, jnp.bfloat16))
        specs = cache_specs(cfg, cache, ctx, batch=8)
        flat_c = jax.tree.leaves(cache)
        flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        assert len(flat_c) == len(flat_s), arch


def test_cache_specs_paged_structure():
    """Paged cache leaves get specs too: pool kv-heads on the model axis,
    pool page dim replicated (dynamic ownership), tables/lengths on batch."""
    ctx = ParallelCtx()
    cfg = get_config("llama3.2-1b")
    cache = jax.eval_shape(
        lambda: T.init_cache(cfg, 8, 64, jnp.bfloat16, paged=True, page_size=16)
    )
    specs = cache_specs(cfg, cache, ctx, batch=8)
    layers = specs["layers"]
    assert set(layers) == {"pool_k", "pool_v", "tables", "lengths"}
    for name in ("pool_k", "pool_v"):
        sp = layers[name]
        assert len(sp) == 5 and sp[1] is None, (name, sp)  # page dim replicated
    for name, sh in (("tables", cache["layers"]["tables"]),
                     ("lengths", cache["layers"]["lengths"])):
        assert len(layers[name]) == len(sh.shape), name
