"""Fused dispatch-gather/scatter GMM paths + EP capacity/placement bugfixes.

Covers:
* ``gmm_gather`` / ``gmm_dual_act_gather`` parity vs the gather oracles and
  vs the padded ragged kernels over the same buckets (the fused prologue
  must be a pure layout change, not a math change);
* ``gmm_scatter`` (compact combine leg): the scatter epilogue's live rows
  vs the padded-then-compacted oracle, the partial-tile spill overwrite
  contract, ``compact_out`` FFN parity + gradients, and the metadata-driven
  ``combine_from_rows`` vs ``bucket_combine`` (NaN-poisoned gap rows must
  never leak — balanced and heavily skewed routing, with capacity drops);
* ``gmm_fused_ffn`` (fully-fused single-kernel FFN): bit-closeness to the
  gather+scatter two-kernel composition and the einsum oracle on live rows
  (balanced, skewed with capacity drops, decode shapes, NaN-poisoned
  dropped rows), gradient parity through the custom_vjp, the
  fused-requires-compact contract, and the VMEM-bound fallback;
* ``validate_ep_token_split``: the prefill floor-truncation guard
  (non-divisible ``b*s`` used to under-size ``bucket_capacity`` or die
  inside shard_map with an opaque spec error);
* ``dispatch_metadata`` consistency with ``bucket_dispatch`` (same slots/
  keep/counts; rebuilding padded buffers from the metadata reproduces the
  scattered buffers bit-for-bit);
* the decode ownership sentinel (``total_slots + 1``) vs the dispatch trash
  row (``n_buckets``) off-by-one interplay — sentinels must never alias the
  trash row, leak into counts, or reach the combine;
* capacity **ceiling** regression: perfectly balanced routing at
  ``capacity_factor == 1.0`` drops zero copies (floor truncation used to);
* ``tiled_placement`` consistency: every default replica slot of expert e
  holds expert e's weight row under the ``jnp.tile`` slot expansion
  ``moe_ep`` uses for non-divisible ``n_rows / ep``;
* end-to-end MoE parity (EP and ESP, prefill and decode shapes) with the
  fused path on vs the reference paths, plus gradients through the fused
  ``custom_vjp``.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke
from repro.kernels import registry
from repro.kernels.gmm.ops import (
    expert_ffn_fused,
    expert_ffn_gather,
    expert_ffn_gather_compact,
    expert_ffn_ragged,
    gmm_gather_op,
    gmm_scatter_op,
)
from repro.kernels.gmm.ragged import gmm_dual_act_gather
from repro.kernels.gmm.ref import (
    expert_ffn_compact_ref,
    expert_ffn_fused_ref,
    expert_ffn_gather_ref,
    gather_buckets_ref,
    gmm_ragged_ref,
    gmm_ref,
    scatter_rows_ref,
)
from repro.models.moe import moe_dense, moe_ep, moe_esp, moe_init
from repro.parallel.collectives import (
    bucket_capacity,
    bucket_combine,
    bucket_dispatch,
    combine_from_rows,
    dispatch_metadata,
    kept_counts,
    tiled_placement,
    validate_ep_token_split,
)
from repro.parallel.ctx import ParallelCtx

RNG = jax.random.PRNGKey(0)

CTX_ON = ParallelCtx(capacity_factor=8.0, use_kernels=True)
CTX_OFF = ParallelCtx(capacity_factor=8.0, use_kernels=False)


def _segments(counts, pad_between=0):
    """Random flat rows with bucket-contiguous segments; returns
    (rows, offsets) with ``pad_between`` junk rows between segments."""
    counts = np.asarray(counts)
    offsets = np.zeros(len(counts), np.int32)
    pos = 0
    for i, c in enumerate(counts):
        offsets[i] = pos
        pos += int(c) + pad_between
    return pos, jnp.asarray(offsets, jnp.int32)


# ---------------------------------------------------------------------------
# gather kernels vs oracles and vs the padded ragged kernels
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "g,cap,d,f,counts",
    [
        (4, 16, 8, 12, [3, 0, 16, 5]),          # zero group, full group
        (3, 96, 64, 160, [1, 95, 40]),          # non-128 C/D/F
        (2, 128, 128, 256, [128, 17]),          # MXU-native tiles
        (5, 24, 48, 40, [24, 0, 0, 7, 2]),      # multiple empty groups
    ],
)
def test_gmm_gather_matches_ref(g, cap, d, f, counts):
    r, offsets = _segments(counts)
    ks = jax.random.split(RNG, 2)
    x = jax.random.normal(ks[0], (max(r, 1), d))
    w = jax.random.normal(ks[1], (g, d, f)) * 0.1
    gs = jnp.asarray(counts, jnp.int32)
    out = gmm_gather_op(x, w, offsets, gs, capacity=cap)
    buckets = gather_buckets_ref(x, offsets, gs, cap)
    ref = gmm_ragged_ref(buckets, w, gs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)
    # Rows past each group's count are exactly zero.
    outn = np.asarray(out)
    for gi, cnt in enumerate(counts):
        assert (outn[gi, cnt:] == 0).all()


def test_gmm_gather_noncontiguous_segments():
    """Offsets need not tile the array: junk rows between segments (and
    NaNs in them) must never reach the output — the prologue only gathers
    addressed rows, dead tiles skip the DMA entirely."""
    g, cap, d, f = 3, 16, 8, 12
    counts = [5, 0, 9]
    r, offsets = _segments(counts, pad_between=3)
    ks = jax.random.split(RNG, 2)
    x = jax.random.normal(ks[0], (r, d))
    # Poison every row not inside a live segment.
    live = np.zeros(r, bool)
    for off, cnt in zip(np.asarray(offsets), counts):
        live[off : off + cnt] = True
    x = jnp.where(jnp.asarray(live)[:, None], x, jnp.nan)
    w = jax.random.normal(ks[1], (g, d, f)) * 0.1
    gs = jnp.asarray(counts, jnp.int32)
    out = np.asarray(gmm_gather_op(x, w, offsets, gs, capacity=cap))
    # NaN rows CAN be touched by a partial tile over-read, but only the
    # masked tail — kept rows must be finite and exact.
    ref = np.asarray(
        gmm_ragged_ref(
            gather_buckets_ref(jnp.nan_to_num(x), offsets, gs, cap), w, gs
        )
    )
    for gi, cnt in enumerate(counts):
        assert np.isfinite(out[gi, :cnt]).all()
        np.testing.assert_allclose(out[gi, :cnt], ref[gi, :cnt], rtol=1e-5, atol=1e-5)


def test_gmm_gather_segment_at_array_end():
    """The last segment's partial tile over-reads past the end of the flat
    array — the wrapper's row padding must absorb it (regression for the
    clamped-DMA tile shift)."""
    g, cap, d, f = 2, 128, 16, 24
    counts = [100, 129 - 100]  # second segment ends exactly at R
    r, offsets = _segments(counts)
    assert r == 129  # deliberately not a multiple of any tile size
    ks = jax.random.split(RNG, 2)
    x = jax.random.normal(ks[0], (r, d))
    w = jax.random.normal(ks[1], (g, d, f)) * 0.1
    gs = jnp.asarray(counts, jnp.int32)
    out = gmm_gather_op(x, w, offsets, gs, capacity=cap)
    ref = gmm_ragged_ref(gather_buckets_ref(x, offsets, gs, cap), w, gs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("gpw", [2, 3])
def test_gmm_gather_groups_per_weight(gpw):
    gw, cap, d, f = 2, 16, 24, 20
    g = gw * gpw
    counts = [(3 * i) % (cap + 1) for i in range(g)]
    r, offsets = _segments(counts)
    ks = jax.random.split(RNG, 2)
    x = jax.random.normal(ks[0], (r, d))
    w = jax.random.normal(ks[1], (gw, d, f)) * 0.1
    gs = jnp.asarray(counts, jnp.int32)
    out = gmm_gather_op(x, w, offsets, gs, capacity=cap, groups_per_weight=gpw)
    buckets = gather_buckets_ref(x, offsets, gs, cap)
    ref = gmm_ragged_ref(buckets, w, gs, groups_per_weight=gpw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_dual_act_gather_matches_ref():
    g, cap, d, f = 4, 32, 16, 24
    counts = [0, 32, 5, 19]
    r, offsets = _segments(counts)
    ks = jax.random.split(RNG, 3)
    x = jax.random.normal(ks[0], (r, d))
    wg = jax.random.normal(ks[1], (g, d, f)) * 0.1
    wu = jax.random.normal(ks[2], (g, d, f)) * 0.1
    gs = jnp.asarray(counts, jnp.int32)
    out = gmm_dual_act_gather(x, wg, wu, offsets, gs, capacity=cap, interpret=True)
    buckets = gather_buckets_ref(x, offsets, gs, cap)
    mask = (jnp.arange(cap)[None, :] < gs[:, None])[..., None]
    ref = (jax.nn.silu(gmm_ref(buckets, wg)) * gmm_ref(buckets, wu)) * mask
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_expert_ffn_gather_matches_padded_ragged_and_einsum():
    """The fused path must agree with BOTH the padded ragged kernel over the
    materialized buckets AND the pure einsum reference."""
    gw, gpw, cap, d, f = 2, 2, 16, 8, 12
    counts = [7, 0, 16, 2]
    r, offsets = _segments(counts)
    ks = jax.random.split(RNG, 4)
    x = jax.random.normal(ks[0], (r, d))
    wg = jax.random.normal(ks[1], (gw, d, f)) * 0.1
    wu = jax.random.normal(ks[2], (gw, d, f)) * 0.1
    wd = jax.random.normal(ks[3], (gw, f, d)) * 0.1
    gs = jnp.asarray(counts, jnp.int32)
    fused = expert_ffn_gather(
        x, wg, wu, wd, offsets, gs, capacity=cap, groups_per_weight=gpw
    )
    buckets = gather_buckets_ref(x, offsets, gs, cap)
    padded = expert_ffn_ragged(buckets, wg, wu, wd, gs, groups_per_weight=gpw)
    einsum = expert_ffn_gather_ref(x, wg, wu, wd, offsets, gs, cap, gpw)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(padded), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(einsum), rtol=1e-5, atol=1e-5)


def test_expert_ffn_from_rows_grad_matches_ref():
    """Kernel forward + reference backward (custom_vjp) through the fused
    gather — gradients must flow back onto the flat rows and the weights."""
    g, cap, d, f = 3, 16, 8, 12
    counts = [4, 16, 0]
    r, offsets = _segments(counts)
    ks = jax.random.split(RNG, 4)
    x = jax.random.normal(ks[0], (r, d))
    wg = jax.random.normal(ks[1], (g, d, f)) * 0.1
    wu = jax.random.normal(ks[2], (g, d, f)) * 0.1
    wd = jax.random.normal(ks[3], (g, f, d)) * 0.1
    gs = jnp.asarray(counts, jnp.int32)

    def loss(fn, x, wg, wu, wd):
        return (fn(x, wg, wu, wd) ** 2).sum()

    kern = lambda *a: registry.expert_ffn_from_rows(
        *a, offsets, gs, capacity=cap, enabled=True
    )
    ref = lambda *a: expert_ffn_gather_ref(*a, offsets, gs, cap)
    gk = jax.grad(loss, argnums=(1, 2, 3, 4))(kern, x, wg, wu, wd)
    gr = jax.grad(loss, argnums=(1, 2, 3, 4))(ref, x, wg, wu, wd)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# scatter-epilogue kernel (compact combine leg)
# ---------------------------------------------------------------------------

def _live_rows(counts, offsets, r):
    live = np.zeros(r, bool)
    for off, cnt in zip(np.asarray(offsets), np.asarray(counts)):
        live[off : off + cnt] = True
    return live


@pytest.mark.parametrize(
    "g,cap,d,f,counts",
    [
        (4, 16, 8, 12, [3, 0, 16, 5]),          # zero group, full group
        (3, 96, 64, 160, [1, 95, 40]),          # non-128 C/D/F
        (2, 128, 128, 256, [128, 17]),          # MXU-native tiles
        (5, 24, 48, 40, [24, 0, 0, 7, 2]),      # multiple empty groups
    ],
)
def test_gmm_scatter_matches_ref(g, cap, d, f, counts):
    """The scatter epilogue compacts the down-projection back to flat rows
    at the per-bucket offsets — live rows must match the padded ragged
    matmul scattered by the reference."""
    r, offsets = _segments(counts)
    ks = jax.random.split(RNG, 2)
    x = jax.random.normal(ks[0], (g, cap, d))
    w = jax.random.normal(ks[1], (g, d, f)) * 0.1
    gs = jnp.asarray(counts, jnp.int32)
    out_rows = max(r, 1)
    out = np.asarray(gmm_scatter_op(x, w, offsets, gs, out_rows=out_rows))
    ref = np.asarray(
        scatter_rows_ref(gmm_ragged_ref(x, w, gs), offsets, gs, out_rows)
    )
    live = _live_rows(counts, offsets, out_rows)
    np.testing.assert_allclose(out[live], ref[live], rtol=1e-5, atol=1e-5)


def test_gmm_scatter_partial_tile_spill_is_overwritten():
    """A partial tile's bm-row store spills masked zeros past its bucket's
    segment into the *next* bucket's rows; grid-ordered stores must
    overwrite the spill with the later bucket's real rows (the
    overlap-overwrite contract)."""
    g, cap, d, f = 3, 16, 8, 12
    counts = [5, 3, 7]  # contiguous, none a multiple of the 16-row tile
    r, offsets = _segments(counts)
    ks = jax.random.split(RNG, 2)
    x = jax.random.normal(ks[0], (g, cap, d))
    w = jax.random.normal(ks[1], (g, d, f)) * 0.1
    gs = jnp.asarray(counts, jnp.int32)
    out = np.asarray(gmm_scatter_op(x, w, offsets, gs, out_rows=r))
    ref = np.asarray(scatter_rows_ref(gmm_ragged_ref(x, w, gs), offsets, gs, r))
    live = _live_rows(counts, offsets, r)
    assert live.all()  # contiguous segments tile the array fully
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("gpw", [2, 3])
def test_gmm_scatter_groups_per_weight(gpw):
    gw, cap, d, f = 2, 16, 24, 20
    g = gw * gpw
    counts = [(3 * i) % (cap + 1) for i in range(g)]
    r, offsets = _segments(counts)
    ks = jax.random.split(RNG, 2)
    x = jax.random.normal(ks[0], (g, cap, d))
    w = jax.random.normal(ks[1], (gw, d, f)) * 0.1
    gs = jnp.asarray(counts, jnp.int32)
    out = np.asarray(
        gmm_scatter_op(x, w, offsets, gs, out_rows=r, groups_per_weight=gpw)
    )
    ref = np.asarray(
        scatter_rows_ref(
            gmm_ragged_ref(x, w, gs, groups_per_weight=gpw), offsets, gs, r
        )
    )
    live = _live_rows(counts, offsets, r)
    np.testing.assert_allclose(out[live], ref[live], rtol=1e-5, atol=1e-5)


def test_expert_ffn_compact_matches_padded_live_rows():
    """compact_out must be a pure layout change: live rows equal the padded
    gather path's bucket rows (and the pure-jnp compact oracle)."""
    gw, gpw, cap, d, f = 2, 2, 16, 8, 12
    counts = [7, 0, 16, 2]
    r, offsets = _segments(counts)
    ks = jax.random.split(RNG, 4)
    x = jax.random.normal(ks[0], (r, d))
    wg = jax.random.normal(ks[1], (gw, d, f)) * 0.1
    wu = jax.random.normal(ks[2], (gw, d, f)) * 0.1
    wd = jax.random.normal(ks[3], (gw, f, d)) * 0.1
    gs = jnp.asarray(counts, jnp.int32)
    compact = np.asarray(
        expert_ffn_gather_compact(
            x, wg, wu, wd, offsets, gs, capacity=cap, groups_per_weight=gpw
        )
    )
    padded = np.asarray(
        expert_ffn_gather(
            x, wg, wu, wd, offsets, gs, capacity=cap, groups_per_weight=gpw
        )
    )
    oracle = np.asarray(
        expert_ffn_compact_ref(x, wg, wu, wd, offsets, gs, cap, gpw)
    )
    for gi, cnt in enumerate(counts):
        off = int(np.asarray(offsets)[gi])
        np.testing.assert_allclose(
            compact[off : off + cnt], padded[gi, :cnt], rtol=1e-5, atol=1e-5
        )
        np.testing.assert_allclose(
            compact[off : off + cnt], oracle[off : off + cnt],
            rtol=1e-5, atol=1e-5,
        )


def test_expert_ffn_compact_grad_matches_ref():
    """Kernel forward + reference backward (custom_vjp) through the compact
    scatter epilogue — gradients flow back onto the flat rows/weights."""
    g, cap, d, f = 3, 16, 8, 12
    counts = [4, 16, 0]
    r, offsets = _segments(counts)
    ks = jax.random.split(RNG, 4)
    x = jax.random.normal(ks[0], (r, d))
    wg = jax.random.normal(ks[1], (g, d, f)) * 0.1
    wu = jax.random.normal(ks[2], (g, d, f)) * 0.1
    wd = jax.random.normal(ks[3], (g, f, d)) * 0.1
    gs = jnp.asarray(counts, jnp.int32)
    live = jnp.asarray(_live_rows(counts, offsets, r))[:, None]

    def loss(fn, x, wg, wu, wd):
        # Square only live rows: gap rows are unspecified kernel output.
        return ((fn(x, wg, wu, wd) * live) ** 2).sum()

    kern = lambda *a: registry.expert_ffn_from_rows(
        *a, offsets, gs, capacity=cap, enabled=True, compact_out=True
    )
    ref = lambda *a: expert_ffn_compact_ref(*a, offsets, gs, cap)
    gk = jax.grad(loss, argnums=(1, 2, 3, 4))(kern, x, wg, wu, wd)
    gr = jax.grad(loss, argnums=(1, 2, 3, 4))(ref, x, wg, wu, wd)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# fully-fused single-kernel FFN (gmm_fused_ffn: VMEM-resident hidden tile)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "g,cap,d,f,counts",
    [
        (4, 16, 8, 12, [16, 16, 16, 16]),       # balanced: every bucket full
        (4, 16, 8, 12, [3, 0, 16, 5]),          # skewed: zero + full groups
        (3, 96, 64, 160, [1, 95, 40]),          # non-128 C/D/F, partial tiles
        (2, 128, 128, 256, [128, 17]),          # MXU-native tiles
        (6, 8, 8, 12, [8, 8, 3, 0, 1, 2]),      # decode-ish: tiny capacity
    ],
    ids=["balanced", "skewed", "partial_tiles", "mxu_native", "decode"],
)
def test_gmm_fused_ffn_matches_pair_and_oracle(g, cap, d, f, counts):
    """The single-kernel fused FFN must be bit-close to the gather+scatter
    two-kernel composition AND the pure-jnp oracle on every live row — the
    VMEM-resident hidden tile is an execution-strategy change only."""
    r, offsets = _segments(counts)
    ks = jax.random.split(RNG, 4)
    x = jax.random.normal(ks[0], (max(r, 1), d))
    wg = jax.random.normal(ks[1], (g, d, f)) * 0.1
    wu = jax.random.normal(ks[2], (g, d, f)) * 0.1
    wd = jax.random.normal(ks[3], (g, f, d)) * 0.1
    gs = jnp.asarray(counts, jnp.int32)
    fused = np.asarray(
        expert_ffn_fused(x, wg, wu, wd, offsets, gs, capacity=cap)
    )
    pair = np.asarray(
        expert_ffn_gather_compact(x, wg, wu, wd, offsets, gs, capacity=cap)
    )
    oracle = np.asarray(expert_ffn_fused_ref(x, wg, wu, wd, offsets, gs, cap))
    for gi, cnt in enumerate(counts):
        off = int(np.asarray(offsets)[gi])
        np.testing.assert_allclose(
            fused[off : off + cnt], pair[off : off + cnt], rtol=1e-5, atol=1e-5
        )
        np.testing.assert_allclose(
            fused[off : off + cnt], oracle[off : off + cnt], rtol=1e-5, atol=1e-5
        )


@pytest.mark.parametrize("gpw", [2, 3])
def test_gmm_fused_ffn_groups_per_weight(gpw):
    """EP layout: gpw consecutive buckets (per-source-rank raggedness) share
    one weight row through all three fused matmuls."""
    gw, cap, d, f = 2, 16, 24, 20
    g = gw * gpw
    counts = [(3 * i) % (cap + 1) for i in range(g)]
    r, offsets = _segments(counts)
    ks = jax.random.split(RNG, 4)
    x = jax.random.normal(ks[0], (r, d))
    wg = jax.random.normal(ks[1], (gw, d, f)) * 0.1
    wu = jax.random.normal(ks[2], (gw, d, f)) * 0.1
    wd = jax.random.normal(ks[3], (gw, f, d)) * 0.1
    gs = jnp.asarray(counts, jnp.int32)
    fused = np.asarray(
        expert_ffn_fused(
            x, wg, wu, wd, offsets, gs, capacity=cap, groups_per_weight=gpw
        )
    )
    oracle = np.asarray(
        expert_ffn_fused_ref(x, wg, wu, wd, offsets, gs, cap, gpw)
    )
    live = _live_rows(counts, offsets, r)
    np.testing.assert_allclose(fused[live], oracle[live], rtol=1e-5, atol=1e-5)


def test_gmm_fused_ffn_nan_poisoned_gap_rows():
    """Junk rows between segments (dropped copies' would-be rows) may hold
    NaN; the fused kernel's gather prologue only addresses live segments, a
    partial tile's over-read of a NaN row must stay confined to masked tail
    rows, and every live output row stays finite and exact."""
    g, cap, d, f = 3, 16, 8, 12
    counts = [5, 0, 9]
    r, offsets = _segments(counts, pad_between=3)
    ks = jax.random.split(RNG, 4)
    x = jax.random.normal(ks[0], (r, d))
    live = _live_rows(counts, offsets, r)
    x = jnp.where(jnp.asarray(live)[:, None], x, jnp.nan)
    wg = jax.random.normal(ks[1], (g, d, f)) * 0.1
    wu = jax.random.normal(ks[2], (g, d, f)) * 0.1
    wd = jax.random.normal(ks[3], (g, f, d)) * 0.1
    gs = jnp.asarray(counts, jnp.int32)
    out = np.asarray(expert_ffn_fused(x, wg, wu, wd, offsets, gs, capacity=cap))
    ref = np.asarray(
        expert_ffn_fused_ref(
            jnp.nan_to_num(x), wg, wu, wd, offsets, gs, cap
        )
    )
    assert np.isfinite(out[live]).all(), "NaN gap rows leaked into live rows"
    np.testing.assert_allclose(out[live], ref[live], rtol=1e-5, atol=1e-5)


def test_expert_ffn_from_rows_fused_grad_matches_ref():
    """Kernel forward + reference backward (custom_vjp) through the fully-
    fused kernel — gradients flow back onto the flat rows and all three
    weight stacks exactly as through the compact oracle."""
    g, cap, d, f = 3, 16, 8, 12
    counts = [4, 16, 0]
    r, offsets = _segments(counts)
    ks = jax.random.split(RNG, 4)
    x = jax.random.normal(ks[0], (r, d))
    wg = jax.random.normal(ks[1], (g, d, f)) * 0.1
    wu = jax.random.normal(ks[2], (g, d, f)) * 0.1
    wd = jax.random.normal(ks[3], (g, f, d)) * 0.1
    gs = jnp.asarray(counts, jnp.int32)
    live = jnp.asarray(_live_rows(counts, offsets, r))[:, None]

    def loss(fn, x, wg, wu, wd):
        # Square only live rows: gap rows are unspecified kernel output.
        return ((fn(x, wg, wu, wd) * live) ** 2).sum()

    kern = lambda *a: registry.expert_ffn_from_rows(
        *a, offsets, gs, capacity=cap, enabled=True, compact_out=True, fused=True
    )
    ref = lambda *a: expert_ffn_fused_ref(*a, offsets, gs, cap)
    gk = jax.grad(loss, argnums=(1, 2, 3, 4))(kern, x, wg, wu, wd)
    gr = jax.grad(loss, argnums=(1, 2, 3, 4))(ref, x, wg, wu, wd)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


def test_fused_requires_compact_out():
    """fused=True without compact_out is a contract error (the single
    kernel always emits the flat layout), not a silent fallback."""
    x = jnp.zeros((8, 8))
    w = jnp.zeros((2, 8, 8))
    offs = jnp.zeros((2,), jnp.int32)
    gs = jnp.zeros((2,), jnp.int32)
    with pytest.raises(ValueError, match="compact_out"):
        registry.expert_ffn_from_rows(
            x, w, w, jnp.zeros((2, 8, 8)), offs, gs, capacity=8, fused=True
        )


def test_fused_vmem_gate_falls_back_to_pair(monkeypatch):
    """Shapes past the fused kernel's VMEM bound (large model dim) must
    fall back to the gather+scatter pair — same results, no error. The
    bound is shrunk so the test doesn't need a genuinely huge tensor."""
    assert not registry.can_gmm_fused(16, 8192, 128, True)
    g, cap, d, f = 3, 16, 8, 12
    counts = [4, 16, 0]
    r, offsets = _segments(counts)
    ks = jax.random.split(RNG, 4)
    x = jax.random.normal(ks[0], (r, d))
    wg = jax.random.normal(ks[1], (g, d, f)) * 0.1
    wu = jax.random.normal(ks[2], (g, d, f)) * 0.1
    wd = jax.random.normal(ks[3], (g, f, d)) * 0.1
    gs = jnp.asarray(counts, jnp.int32)
    want = registry.expert_ffn_from_rows(
        x, wg, wu, wd, offsets, gs, capacity=cap, compact_out=True, fused=True
    )
    monkeypatch.setattr(registry, "FUSED_FFN_MAX_DOWN_DIM", d - 1)
    assert not registry.can_gmm_fused(cap, d, f, True)
    got = registry.expert_ffn_from_rows(
        x, wg, wu, wd, offsets, gs, capacity=cap, compact_out=True, fused=True
    )
    live = _live_rows(counts, offsets, r)
    np.testing.assert_allclose(
        np.asarray(got)[live], np.asarray(want)[live], rtol=1e-5, atol=1e-5
    )


def test_fused_skewed_pipeline_parity_with_drops():
    """Full dispatch->fused-FFN->combine pipeline at heavily skewed routing
    with capacity overflow, single kernel vs the padded reference pipeline
    — the same cell as test_compact_combine_skewed_parity but through
    gmm_fused_ffn."""
    e, cap, d, f = 6, 8, 8, 12
    n, k = 40, 2
    ks = jax.random.split(RNG, 6)
    hot = jax.random.bernoulli(ks[0], 0.7, (n, k))
    ids = jnp.where(hot, 0, jax.random.randint(ks[1], (n, k), 0, 3))
    x = jax.random.normal(ks[2], (n, d))
    w = jax.random.uniform(ks[3], (n, k))
    wg = jax.random.normal(ks[4], (e, d, f)) * 0.1
    wu = jax.random.normal(ks[5], (e, d, f)) * 0.1
    wd = jax.random.normal(ks[0], (e, f, d)) * 0.1
    row_ids, offsets, counts, slots, keep = dispatch_metadata(ids, e, cap)
    assert int(counts[0]) == cap and not bool(keep.all())  # overflow happened
    bufs, slots_b, keep_b = bucket_dispatch(x, ids, e, cap)
    y_pad = expert_ffn_ragged(bufs, wg, wu, wd, counts)
    ref = bucket_combine(y_pad, ids, slots_b, keep_b, w)
    y_flat = registry.expert_ffn_from_rows(
        x[row_ids], wg, wu, wd, offsets, counts,
        capacity=cap, enabled=True, compact_out=True, fused=True,
    )
    out = combine_from_rows(y_flat, offsets[ids] + slots, keep, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# metadata-driven combine (combine_from_rows)
# ---------------------------------------------------------------------------

def test_combine_from_rows_matches_bucket_combine():
    """Gathering the compacted rows through offsets[bucket] + slot must
    reproduce the padded bucket_combine exactly — including capacity drops
    — even when every gap row of the flat array is NaN-poisoned (dropped
    copies select zero before any arithmetic)."""
    n, k, buckets, cap = 24, 2, 5, 4   # cap small -> real capacity drops
    ks = jax.random.split(RNG, 3)
    ids = jax.random.randint(ks[0], (n, k), 0, buckets)
    w = jax.random.uniform(ks[1], (n, k))
    row_ids, offsets, counts, slots, keep = dispatch_metadata(ids, buckets, cap)
    assert not bool(keep.all())  # the cell must exercise drops
    y_pad = jax.random.normal(ks[2], (buckets, cap, 8))
    # Build the compact array bucket_combine's padded buffer corresponds
    # to, poisoning every row outside a live segment.
    r = n * k
    live = _live_rows(np.asarray(counts), np.asarray(offsets), r)
    y_flat = jnp.full((r, 8), jnp.nan)
    for g in range(buckets):
        off, cnt = int(offsets[g]), int(counts[g])
        y_flat = y_flat.at[off : off + cnt].set(y_pad[g, :cnt])
    assert not bool(jnp.isnan(y_flat[jnp.asarray(live)]).any())
    ref = bucket_combine(y_pad, ids, slots, keep, w)
    out = combine_from_rows(y_flat, offsets[ids] + slots, keep, w)
    assert bool(jnp.isfinite(out).all()), "gap garbage leaked into combine"
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6, atol=1e-6)


def test_compact_combine_skewed_parity():
    """Full dispatch->FFN->combine pipeline parity, padded vs compact, at
    heavily skewed routing with capacity overflow — the regime the compact
    leg exists for."""
    e, cap, d, f = 6, 8, 8, 12
    n, k = 40, 2
    ks = jax.random.split(RNG, 6)
    # ~70% of copies hammer expert 0; a couple of experts stay empty.
    hot = jax.random.bernoulli(ks[0], 0.7, (n, k))
    ids = jnp.where(hot, 0, jax.random.randint(ks[1], (n, k), 0, 3))
    x = jax.random.normal(ks[2], (n, d))
    w = jax.random.uniform(ks[3], (n, k))
    wg = jax.random.normal(ks[4], (e, d, f)) * 0.1
    wu = jax.random.normal(ks[5], (e, d, f)) * 0.1
    wd = jax.random.normal(ks[0], (e, f, d)) * 0.1
    row_ids, offsets, counts, slots, keep = dispatch_metadata(ids, e, cap)
    assert int(counts[0]) == cap and not bool(keep.all())  # overflow happened
    # Padded pipeline (the fallback the fused path must match bit-for-bit).
    bufs, slots_b, keep_b = bucket_dispatch(x, ids, e, cap)
    y_pad = expert_ffn_ragged(bufs, wg, wu, wd, counts)
    ref = bucket_combine(y_pad, ids, slots_b, keep_b, w)
    # Compact pipeline: gather-prologue FFN + scatter epilogue + metadata
    # combine. No padded buffer on either side.
    y_flat = registry.expert_ffn_from_rows(
        x[row_ids], wg, wu, wd, offsets, counts,
        capacity=cap, enabled=True, compact_out=True,
    )
    out = combine_from_rows(y_flat, offsets[ids] + slots, keep, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# EP token-split validation (floor-truncation regression)
# ---------------------------------------------------------------------------

def test_validate_ep_token_split():
    # valid splits pass silently
    validate_ep_token_split(4, 8, 2, 4, decode=False)
    validate_ep_token_split(8, 1, 2, 4, decode=True)
    validate_ep_token_split(3, 4, 1, 4, decode=False)   # n_batch=1: any b
    # prefill: seq must divide the EP axis (b*s // (n_batch*ep) would
    # floor-truncate and under-size bucket_capacity)
    with pytest.raises(ValueError, match="seq=7 does not divide ep=4"):
        validate_ep_token_split(4, 7, 2, 4, decode=False)
    # batch must divide the batch axes, prefill and decode alike
    with pytest.raises(ValueError, match="batch=3"):
        validate_ep_token_split(3, 8, 2, 4, decode=False)
    with pytest.raises(ValueError, match="batch=5"):
        validate_ep_token_split(5, 1, 2, 4, decode=True)
    # decode never splits the sequence
    validate_ep_token_split(4, 1, 2, 4, decode=True)


# ---------------------------------------------------------------------------
# dispatch_metadata vs bucket_dispatch
# ---------------------------------------------------------------------------

def test_dispatch_metadata_matches_bucket_dispatch():
    n, k, buckets, cap = 20, 2, 6, 5
    ks = jax.random.split(RNG, 2)
    x = jax.random.normal(ks[0], (n, 8))
    ids = jax.random.randint(ks[1], (n, k), 0, buckets)
    bufs, slots_b, keep_b = bucket_dispatch(x, ids, buckets, cap)
    row_ids, offsets, counts, slots_m, keep_m = dispatch_metadata(ids, buckets, cap)
    np.testing.assert_array_equal(np.asarray(slots_b), np.asarray(slots_m))
    np.testing.assert_array_equal(np.asarray(keep_b), np.asarray(keep_m))
    np.testing.assert_array_equal(
        np.asarray(counts), np.asarray(kept_counts(ids, keep_b, buckets))
    )
    # Rebuilding the padded buffers from the compacted metadata reproduces
    # the scattered buffers exactly (same rows, same positions).
    rows = x[row_ids]
    rebuilt = np.asarray(gather_buckets_ref(rows, offsets, counts, cap))
    np.testing.assert_array_equal(rebuilt, np.asarray(bufs))


def test_dispatch_metadata_compacted_order_is_deterministic():
    """Within a bucket, earlier tokens come first in the compacted order —
    the same 'earlier tokens win' rule bucket_dispatch packs with."""
    ids = jnp.asarray([[1], [0], [1], [0], [1]], jnp.int32)
    row_ids, offsets, counts, _, _ = dispatch_metadata(ids, 2, 8)
    np.testing.assert_array_equal(np.asarray(counts), [2, 3])
    np.testing.assert_array_equal(np.asarray(offsets), [0, 2])
    np.testing.assert_array_equal(np.asarray(row_ids), [1, 3, 0, 2, 4])


# ---------------------------------------------------------------------------
# decode ownership sentinel vs trash row (off-by-one pin)
# ---------------------------------------------------------------------------

def test_decode_sentinel_never_aliases_trash_row():
    """The decode path marks unowned copies with ``total_slots + 1`` while
    ``bucket_dispatch`` keeps one sacrificial row at index ``n_buckets``
    and drops on ``flat_b < n_buckets``. Pin the interplay: the sentinel
    (and the trash index itself) must never land in a real bucket, never
    count toward kept_counts / metadata counts, and never reach combine."""
    n, k, buckets, cap = 8, 2, 4, 4
    ks = jax.random.split(RNG, 2)
    x = jax.random.normal(ks[0], (n, 8))
    base = jax.random.randint(ks[1], (n, k), 0, buckets)
    owned = (jnp.arange(n) % 2) == 0
    for sentinel in (buckets, buckets + 1):  # trash row itself + decode value
        ids = jnp.where(owned[:, None], base, sentinel)
        bufs, slots, keep = bucket_dispatch(x, ids, buckets, cap)
        # Unowned copies are dropped, owned copies under capacity kept.
        assert not bool(keep[~owned].any()), sentinel
        # Buffers only ever contain owned-token rows.
        ref_bufs, _, _ = bucket_dispatch(
            jnp.where(owned[:, None], x, 0.0), jnp.where(owned[:, None], base, sentinel),
            buckets, cap,
        )
        np.testing.assert_array_equal(np.asarray(bufs), np.asarray(ref_bufs))
        # Counts (both implementations) see only owned copies.
        counts_kept = kept_counts(ids, keep, buckets)
        _, _, counts_meta, _, keep_m = dispatch_metadata(ids, buckets, cap)
        owned_ids = base[owned]
        expect = np.minimum(
            np.bincount(np.asarray(owned_ids).reshape(-1), minlength=buckets), cap
        )
        np.testing.assert_array_equal(np.asarray(counts_kept), expect)
        np.testing.assert_array_equal(np.asarray(counts_meta), expect)
        np.testing.assert_array_equal(np.asarray(keep), np.asarray(keep_m))
        # Combine: sentinel copies contribute exactly zero.
        out = bucket_combine(bufs, ids, slots, keep, jnp.ones((n, k)))
        assert bool(jnp.all(out[~owned] == 0.0)), sentinel


# ---------------------------------------------------------------------------
# capacity ceiling regression
# ---------------------------------------------------------------------------

def test_bucket_capacity_uses_ceiling():
    # 100 copies over 3 buckets at factor 1.0: floor(33.3) = 33 dropped a
    # copy of a perfectly balanced batch; ceiling allocates 34.
    assert bucket_capacity(50, 2, 1.0, 3) == 34
    assert bucket_capacity(64, 2, 1.0, 4) == 32   # exact division unchanged
    assert bucket_capacity(2, 2, 1.0, 4) == 8     # floor-of-8 keeps smoke shapes


@pytest.mark.parametrize("n_tok,k,buckets", [(50, 2, 3), (33, 1, 5), (100, 2, 7)])
def test_balanced_routing_drops_nothing_at_capacity_one(n_tok, k, buckets):
    """Perfectly balanced routing at capacity_factor == 1.0 must drop zero
    token copies (regression: floor truncation under-allocated)."""
    cap = bucket_capacity(n_tok, k, 1.0, buckets)
    ids = (jnp.arange(n_tok * k) % buckets).reshape(n_tok, k)
    x = jax.random.normal(RNG, (n_tok, 4))
    _, _, keep = bucket_dispatch(x, ids, buckets, cap)
    assert bool(keep.all())
    _, _, counts, _, keep_m = dispatch_metadata(ids, buckets, cap)
    assert bool(keep_m.all())
    assert int(counts.sum()) == n_tok * k


# ---------------------------------------------------------------------------
# tiled placement consistency (non-divisible n_rows / ep)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("e,ep", [(6, 4), (3, 2), (5, 3), (7, 4)])
def test_tiled_placement_consistent_with_tiling(e, ep):
    """Every replica slot the default placement hands out must hold its
    expert's weight row under the ``jnp.tile`` expansion (slot s = row
    s % n_rows), and every physical slot must carry traffic."""
    n_rows = e
    spd = -(-n_rows // ep)
    n_slots = ep * spd
    slot_of, n_replicas = tiled_placement(e, n_rows, n_slots)
    slot_of, n_replicas = np.asarray(slot_of), np.asarray(n_replicas)
    covered = set()
    for eid in range(e):
        assert n_replicas[eid] >= 1
        for r in range(n_replicas[eid]):
            s = slot_of[eid, r]
            assert 0 <= s < n_slots
            assert s % n_rows == eid, (eid, r, s)
            covered.add(int(s))
        # Padding replica columns stay on valid slots for this expert too
        # (choose_slots never reads them, but a stale table must not alias).
        for r in range(n_replicas[eid], slot_of.shape[1]):
            assert slot_of[eid, r] % n_rows == eid
    assert covered == set(range(n_slots)), "idle shadow slots"


def test_tiled_placement_grows_replica_table():
    """More than r_max wrap-arounds (n_slots > 4 * n_rows) must widen the
    replica table, not truncate it — truncation would leave live tiled
    slots idle while they still inflate the capacity denominator."""
    n_experts = n_rows = 2
    n_slots = 10  # expert 0 -> slots {0,2,4,6,8}: 5 replicas > default 4
    slot_of, n_replicas = tiled_placement(n_experts, n_rows, n_slots)
    slot_of, n_replicas = np.asarray(slot_of), np.asarray(n_replicas)
    covered = set()
    for eid in range(n_experts):
        assert n_replicas[eid] == 5
        for r in range(n_replicas[eid]):
            assert slot_of[eid, r] % n_rows == eid
            covered.add(int(slot_of[eid, r]))
    assert covered == set(range(n_slots)), "idle shadow slots"


def test_moe_ep_rejects_underprovisioned_slots():
    """Fewer physical slots than weight rows would silently drop experts —
    moe_ep must refuse with a clear error, not truncate."""
    from repro.launch.mesh import make_mesh_compat

    cfg = dataclasses.replace(
        smoke(get_config("dbrx-132b")), n_experts=3, experts_per_token=2
    )
    mesh = make_mesh_compat((1, 1), ("data", "model"))
    rng = jax.random.PRNGKey(0)
    p = moe_init(rng, cfg)
    x = jax.random.normal(rng, (2, 8, cfg.d_model)) * 0.5
    ctx = ParallelCtx(mesh=mesh, capacity_factor=8.0, use_kernels=False)
    with mesh, pytest.raises(ValueError, match="physical"):
        moe_ep(p, x, cfg, ctx, slots_per_device=2)


def test_moe_ep_non_divisible_rows_single_device(monkeypatch):
    """moe_ep with n_rows % ep != 0 on a 1-device mesh: force the tiled
    branch by passing slots_per_device explicitly, then check parity with
    the dense oracle (tokens must land on slots holding their expert)."""
    from repro.launch.mesh import make_mesh_compat

    cfg = dataclasses.replace(
        smoke(get_config("dbrx-132b")), n_experts=3, experts_per_token=2
    )
    mesh = make_mesh_compat((1, 1), ("data", "model"))
    rng = jax.random.PRNGKey(0)
    p = moe_init(rng, cfg)
    x = jax.random.normal(rng, (2, 8, cfg.d_model)) * 0.5
    dense, _ = moe_dense(p, x, cfg, CTX_OFF)
    for uk in (False, True):
        ctx = ParallelCtx(mesh=mesh, capacity_factor=8.0, use_kernels=uk)
        with mesh:
            # slots_per_device=4 > n_rows=3: wrap-around shadow slots live.
            out, _ = jax.jit(
                lambda p_, x_: moe_ep(p_, x_, cfg, ctx, slots_per_device=4)
            )(p, x)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(dense), rtol=1e-5, atol=1e-5
        )


# ---------------------------------------------------------------------------
# end-to-end MoE parity through the fused path (prefill + decode shapes)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def moe_cfg():
    return dataclasses.replace(
        smoke(get_config("dbrx-132b")), n_experts=4, experts_per_token=2
    )


@pytest.mark.parametrize("shape", [(2, 8), (4, 1)], ids=["prefill", "decode"])
def test_moe_esp_fused_parity(moe_cfg, shape):
    b, s = shape
    rng = jax.random.PRNGKey(0)
    p = moe_init(rng, moe_cfg)
    x = jax.random.normal(rng, (b, s, moe_cfg.d_model)) * 0.5
    off, _ = moe_esp(p, x, moe_cfg, CTX_OFF)
    on, _ = moe_esp(p, x, moe_cfg, CTX_ON)   # mesh=None + kernels -> fused
    np.testing.assert_allclose(np.asarray(on), np.asarray(off), rtol=1e-5, atol=1e-5)
    dense, _ = moe_dense(p, x, moe_cfg, CTX_OFF)
    np.testing.assert_allclose(np.asarray(on), np.asarray(dense), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", [(2, 8), (2, 1)], ids=["prefill", "decode"])
def test_moe_ep_fused_parity(moe_cfg, shape):
    """EP dispatch on a 1x1 mesh with kernels on takes the fused
    rank-compacted all_to_all path (interpret mode on CPU)."""
    from repro.launch.mesh import make_mesh_compat

    b, s = shape
    mesh = make_mesh_compat((1, 1), ("data", "model"))
    rng = jax.random.PRNGKey(0)
    p = moe_init(rng, moe_cfg)
    x = jax.random.normal(rng, (b, s, moe_cfg.d_model)) * 0.5
    outs = {}
    for name, uk in (("off", False), ("on", True)):
        ctx = ParallelCtx(mesh=mesh, capacity_factor=8.0, use_kernels=uk)
        with mesh:
            outs[name], _ = jax.jit(
                lambda p_, x_, c_=ctx: moe_ep(p_, x_, moe_cfg, c_)
            )(p, x)
    np.testing.assert_allclose(
        np.asarray(outs["on"]), np.asarray(outs["off"]), rtol=1e-5, atol=1e-5
    )
    dense, _ = moe_dense(p, x, moe_cfg, CTX_OFF)
    np.testing.assert_allclose(
        np.asarray(outs["on"]), np.asarray(dense), rtol=1e-5, atol=1e-5
    )


def test_moe_ep_fused_compact_grad(moe_cfg):
    """Gradients through the full fused EP path — compact scatter epilogue
    (custom_vjp), return all_to_all, and metadata combine — must match the
    dense oracle."""
    from repro.launch.mesh import make_mesh_compat

    mesh = make_mesh_compat((1, 1), ("data", "model"))
    rng = jax.random.PRNGKey(0)
    p = moe_init(rng, moe_cfg)
    x = jax.random.normal(rng, (2, 8, moe_cfg.d_model)) * 0.5
    ctx = ParallelCtx(mesh=mesh, capacity_factor=8.0, use_kernels=True)
    gd = jax.grad(lambda p_: moe_dense(p_, x, moe_cfg, CTX_OFF)[0].sum())(p)
    with mesh:
        ge = jax.jit(
            jax.grad(lambda p_: moe_ep(p_, x, moe_cfg, ctx)[0].sum())
        )(p)
    for key in ("w_gate", "w_up", "w_down", "router"):
        np.testing.assert_allclose(
            np.asarray(gd[key]), np.asarray(ge[key]), rtol=1e-4, atol=1e-5
        )
