"""Per-arch smoke tests: reduced same-family configs, one forward + train
step on CPU, asserting shapes and finiteness (the assignment's required
SMOKE coverage), plus prefill/decode consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, shapes_for, smoke
from repro.models import transformer as T
from repro.parallel.ctx import NO_MESH
from repro.runtime.optimizer import AdamWConfig
from repro.runtime.train import init_state, make_train_step


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


def _inputs(cfg, rng, b=2, s=16):
    tokens = jax.random.randint(rng, (b, s), 0, cfg.vocab_size)
    embeds = None
    if cfg.frontend_stub:
        embeds = (
            jax.random.normal(rng, (b, cfg.frontend_tokens, cfg.d_model)) * 0.02
        )
    return tokens, embeds


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finiteness(arch, rng):
    cfg = smoke(get_config(arch))
    params = T.init_params(rng, cfg)
    tokens, embeds = _inputs(cfg, rng)
    logits, aux = T.forward(params, tokens, cfg, NO_MESH, embeds=embeds)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(float(aux["loss"]))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch, rng):
    cfg = smoke(get_config(arch))
    state = init_state(rng, cfg)
    step = jax.jit(make_train_step(cfg, NO_MESH, AdamWConfig(total_steps=10)))
    tokens, embeds = _inputs(cfg, rng)
    batch = {"tokens": tokens, "labels": tokens}
    if embeds is not None:
        batch["embeds"] = embeds
    state, met = step(state, batch)
    assert np.isfinite(float(met["loss"]))
    assert float(met["grad_norm"]) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch, rng):
    """decode(t) after prefill(0..t-1) must match full forward at position t."""
    cfg = smoke(get_config(arch))
    params = T.init_params(rng, cfg)
    tokens, embeds = _inputs(cfg, rng)
    logits, _ = T.forward(params, tokens, cfg, NO_MESH, embeds=embeds)
    # cache must leave decode headroom beyond prompt (+frontend) length
    max_seq = 16 + (cfg.frontend_tokens if cfg.frontend_stub else 0) + 8
    lp, cache = T.prefill(
        params, tokens, cfg, NO_MESH, embeds=embeds, max_seq=max_seq
    )
    np.testing.assert_allclose(
        np.asarray(lp[:, 0]), np.asarray(logits[:, -1]), rtol=1e-4, atol=1e-4
    )
    nxt = jnp.argmax(lp[:, 0:1], -1).astype(tokens.dtype)
    ext = jnp.concatenate([tokens, nxt], axis=1)
    logits2, _ = T.forward(params, ext, cfg, NO_MESH, embeds=embeds)
    ld, _, _ = T.decode_step(params, nxt, cache, cfg, NO_MESH)
    np.testing.assert_allclose(
        np.asarray(ld[:, 0]), np.asarray(logits2[:, -1]), rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("arch", ARCHS)
def test_assigned_shapes_present(arch):
    cfg = get_config(arch)
    names = {s.name for s in shapes_for(cfg)}
    assert {"train_4k", "prefill_32k", "decode_32k"} <= names
    if cfg.subquadratic:
        assert "long_500k" in names


def test_exact_assigned_dimensions():
    """The registry must carry the exact assigned architecture parameters."""
    q = get_config("qwen2-72b")
    assert (q.n_layers, q.d_model, q.n_heads, q.n_kv_heads) == (80, 8192, 64, 8)
    assert (q.d_ff, q.vocab_size, q.qkv_bias) == (29568, 152064, True)
    d = get_config("dbrx-132b")
    assert (d.n_experts, d.experts_per_token) == (16, 4)
    m = get_config("mixtral-8x22b")
    assert (m.n_experts, m.experts_per_token, m.sliding_window) == (8, 2, 4096)
    z = get_config("zamba2-1.2b")
    assert z.ssm_state == 64 and z.block_pattern == "zamba"
    x = get_config("xlstm-350m")
    assert (x.n_layers, x.d_model, x.n_heads) == (24, 1024, 4)
    s = get_config("seamless-m4t-medium")
    assert s.n_encoder_layers == 12 and s.vocab_size == 256206
    i = get_config("internvl2-76b")
    assert (i.n_layers, i.d_model, i.d_ff) == (80, 8192, 28672)
