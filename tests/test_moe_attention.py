"""MoE implementations vs the dense oracle + attention path equivalences
(single-device mesh: shard_map/GSPMD code paths run with axis size 1; the
true multi-device parity checks live in test_multidevice.py)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - deterministic replay shim
    from _hyp_fallback import given, settings, strategies as st

from repro.configs import get_config, smoke
from repro.models.attention import (
    chunked_gqa_attend,
    gqa_attend,
    causal_mask,
)
from repro.models.layers import apply_rope
from repro.models.moe import moe_dense, moe_esp, moe_init, route
from repro.parallel.collectives import bucket_combine, bucket_dispatch
from repro.parallel.ctx import ParallelCtx


@pytest.fixture(scope="module")
def moe_cfg():
    return dataclasses.replace(
        smoke(get_config("dbrx-132b")), n_experts=4, experts_per_token=2
    )


def test_esp_matches_dense_no_mesh(moe_cfg):
    rng = jax.random.PRNGKey(0)
    p = moe_init(rng, moe_cfg)
    x = jax.random.normal(rng, (2, 8, moe_cfg.d_model)) * 0.5
    ctx = ParallelCtx(capacity_factor=8.0)
    ref, _ = moe_dense(p, x, moe_cfg, ctx)
    out, _ = moe_esp(p, x, moe_cfg, ctx)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_capacity_drop_is_graceful(moe_cfg):
    """With capacity factor << 1, outputs shrink toward zero but stay finite
    (dropped copies contribute nothing)."""
    rng = jax.random.PRNGKey(0)
    p = moe_init(rng, moe_cfg)
    x = jax.random.normal(rng, (2, 32, moe_cfg.d_model))
    out, _ = moe_esp(p, x, moe_cfg, ParallelCtx(capacity_factor=0.25))
    full, _ = moe_esp(p, x, moe_cfg, ParallelCtx(capacity_factor=8.0))
    assert np.isfinite(np.asarray(out)).all()
    assert np.linalg.norm(np.asarray(out)) < np.linalg.norm(np.asarray(full))


def test_router_normalized(moe_cfg):
    rng = jax.random.PRNGKey(1)
    p = moe_init(rng, moe_cfg)
    x = jax.random.normal(rng, (3, 5, moe_cfg.d_model))
    ids, w, aux = route(p, x, moe_cfg)
    assert ids.shape == (3, 5, 2) and w.shape == (3, 5, 2)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)
    assert float(aux) >= 1.0 - 1e-5  # aux loss lower bound at perfect balance


@pytest.mark.slow
@given(
    n=st.integers(1, 40),
    k=st.integers(1, 4),
    buckets=st.integers(2, 8),
    seed=st.integers(0, 1000),
)
@settings(max_examples=25, deadline=None)
def test_bucket_dispatch_roundtrip(n, k, buckets, seed):
    """Property: with ample capacity, dispatch+combine with unit weights
    reproduces k * x for every token (each copy returns its token)."""
    rng = jax.random.PRNGKey(seed)
    x = jax.random.normal(rng, (n, 4))
    ids = jax.random.randint(rng, (n, k), 0, buckets)
    cap = n * k  # no drops possible
    bufs, slots, keep = bucket_dispatch(x, ids, buckets, cap)
    assert bool(keep.all())
    out = bucket_combine(bufs, ids, slots, keep, jnp.ones((n, k)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(x) * k, rtol=1e-5, atol=1e-6)


def test_chunked_attention_matches_dense():
    rng = jax.random.PRNGKey(0)
    b, s, h, kv, hd = 2, 256, 8, 4, 32
    q = jax.random.normal(rng, (b, s, h, hd))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (b, s, kv, hd))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (b, s, kv, hd))
    for window in (0, 64):
        ref = gqa_attend(q, k, v, causal_mask(s, window=window))
        out = chunked_gqa_attend(q, k, v, True, window, chunk=64)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5
        )


def test_chunked_attention_grad_matches():
    rng = jax.random.PRNGKey(0)
    b, s, h, kv, hd = 1, 128, 4, 2, 16
    q = jax.random.normal(rng, (b, s, h, hd))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (b, s, kv, hd))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (b, s, kv, hd))
    f_ref = lambda q: gqa_attend(q, k, v, causal_mask(s)).sum()
    f_chk = lambda q: chunked_gqa_attend(q, k, v, True, 0, chunk=32).sum()
    g_ref = jax.grad(f_ref)(q)
    g_chk = jax.grad(f_chk)(q)
    np.testing.assert_allclose(np.asarray(g_chk), np.asarray(g_ref), rtol=1e-4, atol=1e-4)


@given(shift=st.integers(0, 64), seed=st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_rope_relative_property(shift, seed):
    """RoPE property: q.k dot products depend only on relative distance."""
    rng = jax.random.PRNGKey(seed)
    q = jax.random.normal(rng, (1, 1, 1, 32))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (1, 1, 1, 32))
    p0 = jnp.array([[5]])
    p1 = jnp.array([[9]])
    d1 = jnp.sum(apply_rope(q, p0, 1e4) * apply_rope(k, p1, 1e4))
    d2 = jnp.sum(
        apply_rope(q, p0 + shift, 1e4) * apply_rope(k, p1 + shift, 1e4)
    )
    np.testing.assert_allclose(float(d1), float(d2), rtol=1e-4, atol=1e-4)
