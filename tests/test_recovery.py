"""Elastic recovery: device revival and crash-safe snapshot/restore.

Acceptance tests for the full death -> evacuate -> revive -> rebalance ->
crash -> restore lifecycle on one placement-table substrate:

(a) death->revive chaos parity — a seeded FaultPlan kills a device and
    revives it mid-run; every output stays bit-identical to the sequential
    fault-free decode, no token routes to the revived device before its
    first replica commits, and the rebalance moves load back onto it.
(b) crash_restart mid-stream — the scheduler snapshots at the crash tick,
    a *fresh* Server/scheduler is rebuilt from snapshot + params
    checkpoint, and the concatenated pre/post-crash token streams equal
    the uninterrupted run's — including requests QUEUED and just-admitted
    at crash time.

Plus unit coverage for revival_plan, drill_failure's revival reporting,
StepTimer edge cases and restore_elastic onto a different mesh shape.
"""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke
from repro.core.ni_balancer import BalancerState, revival_plan
from repro.models import transformer as T
from repro.parallel.ctx import ParallelCtx
from repro.runtime import snapshot as S
from repro.runtime.elastic import StepTimer, drill_failure, restore_elastic
from repro.runtime.faults import (
    CRASH_RESTART,
    DEVICE_REVIVAL,
    Fault,
    FaultPlan,
    SimulatedCrash,
)
from repro.runtime.scheduler import FINISHED, RequestScheduler
from repro.runtime.serve import Server, ServeConfig

RNG = jax.random.PRNGKey(0)
MOE_KW = dict(slots_per_device=3, virtual_ep=4)


def _moe_cfg():
    return dataclasses.replace(
        smoke(get_config("dbrx-132b")), n_experts=4, experts_per_token=2
    )


def _server(cfg, params, **scfg):
    ctx = ParallelCtx(capacity_factor=8.0)
    defaults = dict(max_seq=64, paged=True, page_size=8)
    defaults.update(scfg)
    return Server(cfg, ctx, jax.tree.map(jnp.copy, params),
                  ServeConfig(**defaults))


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
            for n in lens]


def _reference(cfg, params, prompts, max_new, **scfg):
    out = []
    for p in prompts:
        srv = _server(cfg, params, batch=1, pool_pages=64, **scfg)
        sched = RequestScheduler(srv)
        req = sched.submit(p, max_new_tokens=max_new)
        sched.run()
        assert req.state == FINISHED, (req.state, req.error)
        out.append(np.asarray(req.tokens_out, np.int32))
    return out


# ---------------------------------------------------------------------------
# revival planning (balancer level)
# ---------------------------------------------------------------------------

def test_revival_plan_seeds_hot_experts_onto_blank_device():
    state = BalancerState.initial(n_experts=4, n_devices=4, slots_per_device=2)
    state.load_ema = np.array([0.5, 0.3, 0.15, 0.05])
    dist = lambda a, b: abs(a - b)  # noqa: E731
    state.mark_dead(2)
    state.table.drop_device(2)
    state.revive(2)
    assert 2 not in state.dead
    plan = revival_plan(state, 2, dist)
    assert plan, "a blank device under skewed load must get seeded"
    # every entry targets the revived device, from a live source
    for e, src, dst in plan:
        assert dst == 2 and src not in state.dead
    # hottest per-replica expert is seeded first
    assert plan[0][0] == 0
    # the plan is monotone on peak heat: applying it must not raise it
    before = state.heats().max()
    for mig in plan:
        state.apply(mig)
    assert state.heats().max() <= before + 1e-12


def test_revival_plan_refuses_dead_device():
    state = BalancerState.initial(4, 4, 2)
    state.mark_dead(1)
    with pytest.raises(Exception, match="dead"):
        revival_plan(state, 1, lambda a, b: abs(a - b))


def test_server_revive_guards():
    cfg = _moe_cfg()
    srv = _server(cfg, T.init_params(RNG, cfg), batch=2, pool_pages=10,
                  **MOE_KW)
    with pytest.raises(ValueError, match="not dead"):
        srv.revive(1)
    with pytest.raises(ValueError, match="EP axis"):
        srv.revive(99)


def test_drill_failure_reports_revival_recovery():
    """The ops drill runs death -> rebalance -> revival entirely through
    the public stepped-migration path and reports recovery time."""
    cfg = _moe_cfg()
    srv = _server(cfg, T.init_params(RNG, cfg), batch=2, pool_pages=10,
                  **MOE_KW)
    srv.state.load_ema = np.array([0.5, 0.3, 0.15, 0.05])
    rep = drill_failure(srv, device=2, revive=True)
    assert rep["supported"] and rep["evacuated"]
    assert rep["revival_migrations"] > 0
    # stepped copies take real ticks: commit strictly after submission
    assert rep["revival_recovery_ticks"] > 0
    assert rep["revival_replicas"] == rep["revival_migrations"]
    assert rep["peak_after_revival"] <= rep["peak_after"] + 1e-12
    assert srv.driver.pending == 0
    srv.table.check()
    assert 2 in srv.table.committed_devices()


# ---------------------------------------------------------------------------
# acceptance (a): death -> revive chaos parity + routing invariant
# ---------------------------------------------------------------------------

def test_death_revive_chaos_parity():
    """Seed 14's chaos plan (death of device 3 at step 2, revival at step
    7, plus pool pressure / NaN / straggler) — every output bit-identical
    to the sequential fault-free decode; the revived device is never in
    the committed routing view between death and its first re-committed
    replica; afterwards the rebalance moves load back onto it."""
    seed, max_new = 14, 7
    cfg = _moe_cfg()
    params = T.init_params(RNG, cfg)
    lens = [int(x) for x in
            np.random.default_rng(seed).integers(3, 14, size=4)]
    prompts = _prompts(cfg, lens, seed=seed)
    ref = _reference(cfg, params, prompts, max_new=max_new, **MOE_KW)
    eos = int(ref[0][min(2, max_new - 1)])
    expected = list(ref)
    cut = int(np.argmax(ref[0] == eos)) + 1
    expected[0] = ref[0][:cut]

    srv = _server(cfg, params, batch=3, pool_pages=10, alpha=0.1, **MOE_KW)
    plan = FaultPlan.chaos(seed, n_steps=12, n_devices=4, pressure_pages=5,
                           nan_slots=(0,), revive=True)
    dev = next(f.device for f in plan if f.kind == DEVICE_REVIVAL)

    # Instrument the routing truth: record, per decode tick, whether the
    # (to-be-)revived device appears in the committed routing view — the
    # placement the jitted step routes by.
    routed: list[tuple[int, bool]] = []
    marks: dict[str, int] = {}
    inner = srv._decode
    orig_dead, orig_revive = srv.mark_dead, srv.revive
    srv._decode = lambda *a, **k: (
        routed.append((srv.t, dev in srv.table.committed_devices())),
        inner(*a, **k),
    )[1]
    srv.mark_dead = lambda d: (marks.setdefault("death_t", srv.t),
                               orig_dead(d))[1]
    srv.revive = lambda d: (marks.setdefault("revive_t", srv.t),
                            orig_revive(d))[1]

    sched = RequestScheduler(srv, faults=plan)
    reqs = [sched.submit(p, max_new_tokens=max_new,
                         eos_id=eos if i == 0 else None, arrival=i)
            for i, p in enumerate(prompts)]
    res = sched.run()

    fired = {d[0] for s, k, d in sched.events if k == "fault"}
    assert {"device_death", "device_revival"} <= fired
    # parity: bit-identical to the sequential fault-free oracle
    for i, r in enumerate(reqs):
        assert r.state == FINISHED, (i, r.state, r.error)
        np.testing.assert_array_equal(res[r.rid], expected[i])

    # routing invariant: between death and the first committed replica on
    # the revived device, no decode tick ever saw it in the routing view
    commits = [rec["committed"] for rec in srv.driver.history
               if rec["mig"][2] == dev
               and rec["committed"] is not None
               and rec["committed"] > marks["revive_t"]]
    assert commits, "revival copies never committed"
    first = min(commits)
    window = [t for t, present in routed
              if marks["death_t"] <= t < first and present]
    assert not window, f"device {dev} routed during blackout ticks {window}"
    # ... and load moved back: committed replicas with finite positive heat
    assert any(present for t, present in routed if t >= first)
    assert dev in srv.table.committed_devices()
    heats = srv.state.heats()
    assert np.isfinite(heats[dev]) and heats[dev] > 0
    srv.table.check()


# ---------------------------------------------------------------------------
# acceptance (b): crash_restart mid-stream, bit-identical restore
# ---------------------------------------------------------------------------

def _crash_run(tmp_path, crash_step, seed=3, max_new=6, with_chaos=False):
    cfg = _moe_cfg()
    params = T.init_params(RNG, cfg)
    # arrivals straddle the crash: rid 3 admits the tick before it (at
    # most one decoded token — the "mid-prefill" case at a tick-boundary
    # snapshot), rid 4 is still QUEUED (arrival after the crash).
    lens = [5, 9, 4, 7, 6]
    arrivals = [0, 1, 2, crash_step - 1, crash_step + 2]
    prompts = _prompts(cfg, lens, seed=seed)
    scfg = dict(pool_pages=10, alpha=0.1, **MOE_KW)

    def submit_all(sched):
        return [sched.submit(p, max_new_tokens=max_new, arrival=a)
                for p, a in zip(prompts, arrivals)]

    # uninterrupted reference (same batch shape, no faults)
    ref_sched = RequestScheduler(_server(cfg, params, batch=2, **scfg))
    submit_all(ref_sched)
    ref = ref_sched.run()

    path = os.path.join(str(tmp_path), "snap.npz")
    faults = [Fault(step=crash_step, kind=CRASH_RESTART, path=path)]
    if with_chaos:
        # seed 14's full plan: pressure@1, death@2, nan@4, revival@7,
        # straggler@8, release@9 — crash_step=5 lands between death and
        # revival, so the snapshot carries a dead device mid-blackout.
        faults += list(FaultPlan.chaos(14, n_steps=12, n_devices=4,
                                       pressure_pages=3, nan_slots=(0,),
                                       revive=True))
    plan = FaultPlan(faults)
    sched = RequestScheduler(_server(cfg, params, batch=2, **scfg),
                             faults=plan)
    submit_all(sched)
    with pytest.raises(SimulatedCrash) as ei:
        sched.run()
    assert ei.value.step == crash_step
    assert os.path.exists(path) and os.path.exists(path + ".meta")
    states_at_crash = {r.rid: r.state for r in sched.requests}
    pre_crash = {r.rid: list(r.tokens_out) for r in sched.requests}

    # fresh process: new Server + scheduler from snapshot + params ckpt
    restored = S.restore_scheduler(
        path, cfg, ParallelCtx(capacity_factor=8.0),
        jax.tree.map(jnp.copy, params), faults=plan,
    )
    res = restored.run()
    return ref, res, pre_crash, states_at_crash, restored


def test_crash_restart_mid_stream(tmp_path):
    ref, res, pre, states, restored = _crash_run(tmp_path, crash_step=4)
    # the crash hit an interesting cross-section of lifecycles
    assert "DECODING" in states.values()
    assert "QUEUED" in states.values()
    for rid, want in ref.items():
        got = res[rid]
        # the post-restore stream extends the pre-crash prefix exactly
        np.testing.assert_array_equal(got[: len(pre[rid])], pre[rid])
        np.testing.assert_array_equal(got, want)
    assert all(r.state == FINISHED for r in restored.requests)
    # the crash is not charged against preemption budgets
    crash_victims = [r for r in restored.requests
                     if states[r.rid] == "DECODING"]
    assert crash_victims


def test_crash_restart_with_chaos_and_pending_migrations(tmp_path):
    """Crash landing in the middle of the seed-14 chaos plan (after the
    death, before the revival): the snapshot carries a non-trivial
    placement table and dead set, the remaining faults (revival included)
    re-fire after restore, and parity still holds."""
    ref, res, pre, states, restored = _crash_run(
        tmp_path, crash_step=5, with_chaos=True)
    for rid, want in ref.items():
        np.testing.assert_array_equal(res[rid], want)
    fired = {d[0] for s, k, d in restored.events if k == "fault"}
    assert "device_revival" in fired, "post-crash faults must re-fire"
    srv = restored.server
    assert not srv.state.dead
    srv.table.check()


def test_periodic_snapshot_cadence(tmp_path):
    """SchedulerConfig(snapshot_every=k) snapshots at tick boundaries;
    restoring from the *last periodic* snapshot (not a crash-tick one)
    also reproduces the uninterrupted streams."""
    from repro.runtime.scheduler import SchedulerConfig

    cfg = _moe_cfg()
    params = T.init_params(RNG, cfg)
    prompts = _prompts(cfg, [5, 8, 6], seed=7)
    scfg = dict(pool_pages=10, alpha=0.1, **MOE_KW)
    ref_sched = RequestScheduler(_server(cfg, params, batch=2, **scfg))
    for i, p in enumerate(prompts):
        ref_sched.submit(p, max_new_tokens=5, arrival=i)
    ref = ref_sched.run()

    path = os.path.join(str(tmp_path), "periodic.npz")
    sched = RequestScheduler(
        _server(cfg, params, batch=2, **scfg),
        SchedulerConfig(snapshot_every=3, snapshot_path=path),
    )
    for i, p in enumerate(prompts):
        sched.submit(p, max_new_tokens=5, arrival=i)
    sched.run()
    assert sched.last_snapshot is not None
    assert os.path.exists(path) and os.path.exists(path + ".meta")
    snap = S.load_snapshot(path)
    assert snap.step_no % 3 == 0
    restored = S.restore_scheduler(
        snap, cfg, ParallelCtx(capacity_factor=8.0),
        jax.tree.map(jnp.copy, params),
    )
    res = restored.run()
    for rid, want in ref.items():
        np.testing.assert_array_equal(res[rid], want)


# ---------------------------------------------------------------------------
# satellite: StepTimer + restore_elastic glue
# ---------------------------------------------------------------------------

def test_step_timer_ratio_before_first_step():
    t = StepTimer()
    assert t.ema is None
    assert t.ratio == 1.0
    assert not t.is_straggling


def test_step_timer_ema_and_straggler_threshold(monkeypatch):
    clock = iter([0.0, 1.0,    # step 1: dt = 1.0 (seeds the EMA)
                  1.0, 2.0,    # step 2: dt = 1.0 (healthy)
                  2.0, 4.0])   # step 3: dt = 2.0 (> 1.5x EMA)
    monkeypatch.setattr("repro.runtime.elastic.time.monotonic",
                        lambda: next(clock))
    t = StepTimer(alpha=0.9, threshold=1.5)
    with t:
        pass
    assert t.ema == pytest.approx(1.0)
    assert not t.is_straggling and t.ratio == pytest.approx(1.0)
    with t:
        pass
    assert t.ema == pytest.approx(1.0)
    with t:
        pass
    # EMA folds the outlier in at (1 - alpha) *before* the ratio is read
    assert t.ema == pytest.approx(0.9 * 1.0 + 0.1 * 2.0)
    assert t.is_straggling          # last 2.0 > 1.5 * 1.1
    assert t.ratio == pytest.approx(2.0 / 1.1, rel=1e-6)


def test_step_timer_zero_ema_ratio(monkeypatch):
    monkeypatch.setattr("repro.runtime.elastic.time.monotonic", lambda: 5.0)
    t = StepTimer()
    with t:
        pass
    assert t.ema == 0.0
    assert t.ratio == 1.0          # guarded: no division by zero
    assert not t.is_straggling


def test_restore_elastic_onto_different_mesh_shape(tmp_path):
    """Checkpoints written with no mesh restore onto a fresh (1, 1) mesh:
    arrays come back bitwise equal and placed under the new shardings."""
    from jax.sharding import NamedSharding, PartitionSpec
    from repro.launch.mesh import make_mesh_compat
    from repro.runtime.checkpoint import CheckpointManager

    state = {"w": np.arange(12, dtype=np.float32).reshape(3, 4),
             "b": np.ones(4, np.float32)}
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(7, state, extra={"data_step": 7})

    mesh = make_mesh_compat((1, 1), ("data", "model"))

    def sharding_fn(mesh, template):
        return jax.tree.map(
            lambda _: NamedSharding(mesh, PartitionSpec()), template
        )

    restored, meta = restore_elastic(mgr, state, mesh, sharding_fn)
    assert meta["step"] == 7 and meta["data_step"] == 7
    for k in state:
        np.testing.assert_array_equal(np.asarray(restored[k]), state[k])
        assert restored[k].sharding.mesh.shape == {"data": 1, "model": 1}
