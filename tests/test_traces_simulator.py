import numpy as np

from repro.core.er_mapping import baseline_mapping, er_mapping
from repro.core.hardware import DGX, WSC
from repro.core.simulator import (
    ClusterSystem,
    WSCSystem,
    run_serving_trace,
    simulate_iteration,
)
from repro.core.topology import MeshTopology
from repro.core.traces import (
    device_load_ratios,
    mixed_scenario_trace,
    single_scenario_trace,
)
from repro.core.workloads import DEEPSEEK_V3, PAPER_MODELS, QWEN3_235B


def test_traces_deterministic():
    a = single_scenario_trace(64, 2048, 50, seed=3)
    b = single_scenario_trace(64, 2048, 50, seed=3)
    assert np.array_equal(a.loads, b.loads)
    assert not np.array_equal(
        a.loads, single_scenario_trace(64, 2048, 50, seed=4).loads
    )


def test_single_scenario_ratios_stabilize():
    """Paper Fig. 12: fixed scenario -> device load ratios stable after
    warm-up (and meaningfully imbalanced)."""
    tr = single_scenario_trace(256, 8192, 200, scenario="math")
    ratios = device_load_ratios(tr.loads, 8)
    late = ratios[100:]
    assert late.max() > 1.5                      # imbalance persists
    assert np.abs(late.std(axis=0)).max() < 0.2  # ...but stably so


def test_mixed_scenario_drifts():
    tr = mixed_scenario_trace(256, 8192, 400, period=200)
    ratios = device_load_ratios(tr.loads, 8)
    drift = np.abs(ratios[350:].mean(axis=0) - ratios[:50].mean(axis=0)).max()
    assert drift > 0.1


def test_er_mapping_reduces_communication():
    """Fig. 13(b): ER-Mapping cuts total comm latency for a2a-heavy models."""
    topo = MeshTopology(6, 6)
    for model in (DEEPSEEK_V3, QWEN3_235B):
        base = simulate_iteration(
            model, WSCSystem(WSC, baseline_mapping(topo, 6, 6)), 256, 6
        )
        er = simulate_iteration(
            model, WSCSystem(WSC, er_mapping(topo, 6, 6)), 256, 6
        )
        assert er.alltoall < base.alltoall
        comm_base = base.alltoall + base.allreduce
        comm_er = er.alltoall + er.allreduce
        assert comm_er < comm_base


def test_wsc_beats_dgx_communication():
    """Fig. 13(a)/(b): WSC mesh >> DGX cluster on communication."""
    topo = MeshTopology(6, 6)
    wsc = simulate_iteration(
        QWEN3_235B, WSCSystem(WSC, er_mapping(topo, 6, 6)), 256, 6
    )
    dgx = simulate_iteration(QWEN3_235B, ClusterSystem(DGX, 32, tp=8), 256, 8)
    assert wsc.alltoall + wsc.allreduce < dgx.alltoall + dgx.allreduce


def test_serving_trace_balancer_ordering():
    """Fig. 16: exposed overhead greedy >= topo-aware > non-invasive == 0."""
    topo = MeshTopology(4, 4)
    sys_ = WSCSystem(WSC, er_mapping(topo, 4, 4))
    trace = mixed_scenario_trace(64, 2048, 60, period=30, seed=1)
    res = {
        b: run_serving_trace(
            DEEPSEEK_V3, sys_, trace, 256, 4, balancer=b, alpha=1.0
        )
        for b in ("none", "greedy", "topo", "topo_ni")
    }
    assert res["topo_ni"].exposed_overhead == 0.0
    assert res["greedy"].exposed_overhead >= res["topo"].exposed_overhead
    assert res["topo"].exposed_overhead > 0.0
    # balancing reduces the load imbalance vs none
    assert res["topo_ni"].peak_over_mean[-10:].mean() < res[
        "none"
    ].peak_over_mean[-10:].mean()


def test_paper_models_table():
    assert set(PAPER_MODELS) == {
        "DeepSeek-V3", "Qwen3-235B", "DeepSeek-V2", "DBRX", "Mixtral-8x22B"
    }
    assert DEEPSEEK_V3.n_experts == 256 and DEEPSEEK_V3.topk == 8
