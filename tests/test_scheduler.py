"""Continuous-batching scheduler: lifecycle, admission control, preemption,
and the chaos parity acceptance test.

The determinism yardstick everywhere: a request's tokens must be
bit-identical to a sequential, fault-free, one-request-at-a-time run of the
same scheduler (greedy argmax; capacity_factor high enough that routing
never drops a copy).
"""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke
from repro.models import transformer as T
from repro.parallel.ctx import ParallelCtx
from repro.runtime.faults import (
    CRASH_RESTART,
    NAN_LOGITS,
    POOL_PRESSURE,
    POOL_RELEASE,
    Fault,
    FaultPlan,
    SimulatedCrash,
)
from repro.runtime.scheduler import (
    FAILED,
    FINISHED,
    PREFILLING,
    RequestScheduler,
    SchedulerConfig,
)
from repro.runtime.serve import Server, ServeConfig

RNG = jax.random.PRNGKey(0)


def _dense_cfg(**kw):
    return dataclasses.replace(smoke(get_config("llama3.2-1b")), **kw)


def _moe_cfg(**kw):
    base = dataclasses.replace(
        smoke(get_config("dbrx-132b")), n_experts=4, experts_per_token=2
    )
    return dataclasses.replace(base, **kw)


def _server(cfg, params, **scfg):
    ctx = ParallelCtx(capacity_factor=8.0)
    defaults = dict(max_seq=64, paged=True, page_size=8)
    defaults.update(scfg)
    return Server(cfg, ctx, jax.tree.map(jnp.copy, params),
                  ServeConfig(**defaults))


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
            for n in lens]


def _reference(cfg, params, prompts, max_new, **scfg):
    """Sequential oracle: each request alone in a fresh server with an
    ample pool and no faults."""
    out = []
    for p in prompts:
        srv = _server(cfg, params, batch=1, pool_pages=64, **scfg)
        sched = RequestScheduler(srv)
        req = sched.submit(p, max_new_tokens=max_new)
        sched.run()
        assert req.state == FINISHED, (req.state, req.error)
        out.append(np.asarray(req.tokens_out, np.int32))
    return out


# ---------------------------------------------------------------------------
# guards
# ---------------------------------------------------------------------------

def test_scheduler_requires_paged_server():
    cfg = _dense_cfg()
    srv = Server(cfg, ParallelCtx(), T.init_params(RNG, cfg),
                 ServeConfig(max_seq=32, batch=1))
    with pytest.raises(ValueError, match="paged=True"):
        RequestScheduler(srv)


def test_oversized_request_fails_at_submit():
    cfg = _dense_cfg()
    srv = _server(cfg, T.init_params(RNG, cfg), batch=1, pool_pages=8)
    sched = RequestScheduler(srv)
    req = sched.submit(np.arange(40, dtype=np.int32) % cfg.vocab_size,
                       max_new_tokens=100)
    assert req.state == FAILED and "capacity" in req.error
    assert not sched.queue      # never enqueued, can't wedge the loop
    bad = sched.submit(np.arange(3, dtype=np.int32), max_new_tokens=0)
    assert bad.state == FAILED


def test_starved_pool_fails_head_instead_of_hanging():
    cfg = _dense_cfg()
    params = T.init_params(RNG, cfg)
    srv = _server(cfg, params, batch=2, pool_pages=4)
    # An external tenant steals the whole pool at step 0 and never releases.
    plan = FaultPlan([Fault(step=0, kind=POOL_PRESSURE, pages=4)])
    sched = RequestScheduler(srv, faults=plan)
    req = sched.submit(_prompts(cfg, [6])[0], max_new_tokens=4)
    sched.run(max_steps=50)
    assert req.state == FAILED and "pool" in req.error


# ---------------------------------------------------------------------------
# lifecycle
# ---------------------------------------------------------------------------

def test_ragged_arrivals_all_complete_with_parity():
    """More requests than batch slots, ragged lengths and staggered
    arrivals: every request finishes and matches its sequential run."""
    cfg = _dense_cfg()
    params = T.init_params(RNG, cfg)
    prompts = _prompts(cfg, [5, 11, 3, 8, 14])
    ref = _reference(cfg, params, prompts, max_new=6)
    srv = _server(cfg, params, batch=3, pool_pages=14)
    sched = RequestScheduler(srv)
    reqs = [sched.submit(p, max_new_tokens=6, arrival=i) for i, p in
            enumerate(prompts)]
    res = sched.run()
    for i, r in enumerate(reqs):
        assert r.state == FINISHED, (i, r.state, r.error)
        np.testing.assert_array_equal(res[r.rid], ref[i])
    admits = [e for e in sched.events if e[1] == "admit"]
    assert len(admits) == 5
    # arrival gating: nothing admitted before its arrival step
    by_rid = {r.rid: r for r in reqs}
    assert all(step >= by_rid[rid].arrival for step, _, rid in admits)


def test_eos_retires_mid_flight_and_slot_is_reused():
    cfg = _dense_cfg()
    params = T.init_params(RNG, cfg)
    prompts = _prompts(cfg, [5, 9, 7])
    ref = _reference(cfg, params, prompts, max_new=8)
    eos = int(ref[0][0])   # request 0 stops after its very first token
    srv = _server(cfg, params, batch=2, pool_pages=10)
    sched = RequestScheduler(srv)
    r0 = sched.submit(prompts[0], max_new_tokens=8, eos_id=eos)
    r1 = sched.submit(prompts[1], max_new_tokens=8)
    r2 = sched.submit(prompts[2], max_new_tokens=8)
    sched.run()
    # r0: EOS truncation, exact prefix of the no-EOS reference
    cut = int(np.argmax(ref[0] == eos)) + 1
    np.testing.assert_array_equal(np.asarray(r0.tokens_out), ref[0][:cut])
    np.testing.assert_array_equal(np.asarray(r1.tokens_out), ref[1])
    np.testing.assert_array_equal(np.asarray(r2.tokens_out), ref[2])
    # r2 only fits because r0's retirement freed a slot mid-flight:
    events = {(k, d if k != "preempt" else d[0]): s
              for s, k, d in sched.events}
    assert events[("admit", r2.rid)] >= events[("retire", r0.rid)]


def test_watermark_backpressure_defers_admission():
    cfg = _dense_cfg()
    params = T.init_params(RNG, cfg)
    prompts = _prompts(cfg, [16, 16])
    ref = _reference(cfg, params, prompts, max_new=4)
    srv = _server(cfg, params, batch=2, pool_pages=6)
    # watermark 0.5: 3 of 6 pages; each request needs 2 pages up front, so
    # the second must wait for the first's retirement even though the pool
    # could physically hold both.
    sched = RequestScheduler(srv, SchedulerConfig(admit_watermark=0.5))
    r0 = sched.submit(prompts[0], max_new_tokens=4)
    r1 = sched.submit(prompts[1], max_new_tokens=4)
    sched.run()
    events = {(k, d): s for s, k, d in sched.events if k in ("admit", "retire")}
    assert events[("admit", r1.rid)] >= events[("retire", r0.rid)]
    np.testing.assert_array_equal(np.asarray(r0.tokens_out), ref[0])
    np.testing.assert_array_equal(np.asarray(r1.tokens_out), ref[1])


def test_preemption_recomputes_bit_identical():
    """A pool-pressure window mid-decode evicts the youngest request; on
    re-admission it recomputes from prompt + emitted tokens and its final
    output is indistinguishable from a run that was never preempted."""
    cfg = _dense_cfg()
    params = T.init_params(RNG, cfg)
    prompts = _prompts(cfg, [7, 10, 6])
    ref = _reference(cfg, params, prompts, max_new=10)
    srv = _server(cfg, params, batch=3, pool_pages=9)
    plan = FaultPlan([
        Fault(step=2, kind=POOL_PRESSURE, pages=4),
        Fault(step=8, kind=POOL_RELEASE, pages=4),
    ])
    sched = RequestScheduler(srv, faults=plan)
    reqs = [sched.submit(p, max_new_tokens=10) for p in prompts]
    res = sched.run()
    assert sched.n_preempted > 0, "pressure window should force eviction"
    for i, r in enumerate(reqs):
        assert r.state == FINISHED, (i, r.state, r.error)
        np.testing.assert_array_equal(res[r.rid], ref[i])


def test_nan_fault_fails_only_affected_request():
    """With the retry budget at zero, a NaN-poisoned request FAILs (named,
    no raise) while its batchmate sails through bit-identical."""
    cfg = _dense_cfg()
    params = T.init_params(RNG, cfg)
    prompts = _prompts(cfg, [6, 9])
    ref = _reference(cfg, params, prompts, max_new=8)
    srv = _server(cfg, params, batch=2, pool_pages=12)
    plan = FaultPlan([Fault(step=3, kind=NAN_LOGITS, slots=(0,))])
    sched = RequestScheduler(srv, SchedulerConfig(max_preemptions=0),
                             faults=plan)
    r0 = sched.submit(prompts[0], max_new_tokens=8)
    r1 = sched.submit(prompts[1], max_new_tokens=8)
    sched.run()
    assert r0.state == FAILED and "evicted" in r0.error
    assert r1.state == FINISHED
    np.testing.assert_array_equal(np.asarray(r1.tokens_out), ref[1])
    # partial output before the fault is a clean prefix (no garbage token)
    np.testing.assert_array_equal(
        np.asarray(r0.tokens_out), ref[0][: len(r0.tokens_out)]
    )


# ---------------------------------------------------------------------------
# the acceptance test: chaos parity on the MoE serving stack
# ---------------------------------------------------------------------------

def _chaos_run(seed, n_requests=4, max_new=7, skew_router=False,
               prefill_chunk=None, ep_chunks=1):
    cfg = _moe_cfg()
    params = T.init_params(RNG, cfg)
    if skew_router:
        # Sustained skewed traffic: hot experts' router columns dominate,
        # so the balancer keeps a stream of stepped migrations in flight
        # concurrently with the chaos plan's faults.
        router = np.asarray(params["layers"]["moe"]["router"])  # (L, d, E)
        scale = np.ones(router.shape[-1], router.dtype)
        scale[[0, 1]] = 8.0
        params["layers"]["moe"]["router"] = jnp.asarray(router * scale)
    lens = [int(x) for x in
            np.random.default_rng(seed).integers(3, 14, size=n_requests)]
    prompts = _prompts(cfg, lens, seed=seed)
    moe_kw = dict(slots_per_device=3, virtual_ep=4)
    ref = _reference(cfg, params, prompts, max_new=max_new, **moe_kw)
    # one request retires early via EOS (truncate the reference to match)
    eos = int(ref[0][min(2, max_new - 1)])
    expected = list(ref)
    cut = int(np.argmax(ref[0] == eos)) + 1
    expected[0] = ref[0][:cut]

    srv = _server(cfg, params, batch=3, pool_pages=10, alpha=0.1,
                  prefill_chunk=prefill_chunk, ep_chunks=ep_chunks, **moe_kw)
    # poison slot 0: admission always picks the lowest free slot, so slot 0
    # is the one guaranteed to hold a live request mid-run
    plan = FaultPlan.chaos(seed, n_steps=12, n_devices=4, pressure_pages=5,
                           nan_slots=(0,))
    sched = RequestScheduler(srv, faults=plan)
    reqs = [sched.submit(p, max_new_tokens=max_new,
                         eos_id=eos if i == 0 else None, arrival=i)
            for i, p in enumerate(prompts)]
    res = sched.run()
    # the plan actually exercised the failure paths
    fired = {d[0] for s, k, d in sched.events if k == "fault"}
    assert {"device_death", "pool_pressure", "nan_logits"} <= fired
    for i, r in enumerate(reqs):
        assert r.state == FINISHED, (i, r.state, r.error)
        np.testing.assert_array_equal(res[r.rid], expected[i])
    return sched


def test_chaos_parity_moe():
    """Ragged arrivals + undersized pool + device death + straggler + NaN
    step + mid-stream EOS: every admitted request completes and every
    output is bit-identical to the sequential fault-free decode — including
    requests that were preempted and recomputed. No decode step raises."""
    sched = _chaos_run(seed=14)
    assert sched.n_preempted > 0     # the chaos actually bit


def test_chaos_parity_with_concurrent_migration_stream():
    """The chaos plan with a skewed router on top: live stepped migrations
    (slice copies + atomic table swaps) run concurrently with preemption,
    device death and NaN faults — and every surviving request still matches
    the sequential fault-free oracle bit-for-bit."""
    sched = _chaos_run(seed=14, skew_router=True)
    srv = sched.server
    assert srv.migrations > 0, "migration stream never ran"
    assert srv.driver is not None and srv.driver.history
    srv.table.check()


def test_chaos_parity_chunked_dispatch():
    """The chunked EP dispatch pipeline (ep_chunks=3 over the 12 virtual
    expert groups) under the full chaos plan — device death mid-stream,
    pool pressure, NaN faults, preemption and recompute: every stream must
    stay bit-identical to the *unchunked* sequential fault-free oracle,
    because chunking is a schedule, not a numerical change."""
    sched = _chaos_run(seed=14, ep_chunks=3)
    assert sched.n_preempted > 0
    assert sched.server.scfg.ep_chunks == 3
    assert sched.stats()["ep_chunks"] == 3   # ops visibility


@pytest.mark.slow
@pytest.mark.parametrize("seed", [11, 23, 47])
def test_chaos_parity_moe_seeds(seed):
    _chaos_run(seed, n_requests=6, max_new=10)


@pytest.mark.slow
@pytest.mark.parametrize("ep_chunks", [2, 4])
def test_chaos_parity_chunked_dispatch_depths(ep_chunks):
    _chaos_run(seed=23, ep_chunks=ep_chunks)


# ---------------------------------------------------------------------------
# chunked admission: prefill as a lane in the decode step
# ---------------------------------------------------------------------------

def test_prefill_chunk_validation():
    """Bad prefill_chunk values fail at ServeConfig construction with a
    named error (validate_ep_token_split convention), not as an opaque
    scatter error inside the jitted step."""
    kw = dict(max_seq=64, paged=True, page_size=8)
    with pytest.raises(ValueError, match="positive"):
        ServeConfig(prefill_chunk=-8, **kw)
    with pytest.raises(ValueError, match="positive"):
        ServeConfig(prefill_chunk=0, **kw)
    with pytest.raises(ValueError, match="page-size-aligned"):
        ServeConfig(prefill_chunk=12, **kw)
    with pytest.raises(ValueError, match="max_seq"):
        ServeConfig(prefill_chunk=128, **kw)
    with pytest.raises(ValueError, match="paged=True"):
        ServeConfig(prefill_chunk=128, max_seq=256, paged=False)
    assert ServeConfig(prefill_chunk=16, **kw).prefill_chunk == 16


def test_chunked_admission_stream_parity_and_bounded_stall():
    """Chunked admission vs splice admission: bit-identical streams, O(1)
    inter-token gap for live requests while a long prompt admits, first
    token within ceil(len/chunk)+1 ticks of admission, and ONE compiled
    step program serving idle, decode-only and decode+chunk ticks."""
    cfg = _dense_cfg()
    params = T.init_params(RNG, cfg)
    prompts = _prompts(cfg, [30, 5, 9, 12])
    chunk = 8

    def collect(prefill_chunk):
        srv = _server(cfg, params, batch=3, pool_pages=32,
                      prefill_chunk=prefill_chunk)
        sched = RequestScheduler(srv)
        reqs = [sched.submit(p, max_new_tokens=6, arrival=i)
                for i, p in enumerate(prompts)]
        res = sched.run()
        return srv, sched, reqs, res

    _, sched_a, _, res_a = collect(None)
    srv_b, sched_b, reqs_b, res_b = collect(chunk)
    for rid in res_a:
        np.testing.assert_array_equal(res_b[rid], res_a[rid])
    assert srv_b._decode._cache_size() == 1
    stats = sched_b.stats()
    # no live request ever waited more than the one fused step per tick
    assert stats["max_stall_ticks"] == 0
    assert stats["queue_depth"] == 0 and stats["prefill_backlog"] == 0
    for r in reqs_b:
        assert r.state == FINISHED
        ticks_to_first = r.first_token_step - r.admitted_step + 1
        assert ticks_to_first <= -(-len(r.prompt) // chunk) + 1
        per = stats["per_request"][r.rid]
        assert per["ttft_ticks"] == r.ttft_ticks
        assert per["n_tokens"] == 6


def test_preempt_mid_prefill_requeues_without_tokens():
    """Preempting a half-prefilled request returns its chunk pages, resets
    its progress, counts no emitted tokens, and requeues it at the front;
    the eventual output still matches the sequential oracle."""
    cfg = _dense_cfg()
    params = T.init_params(RNG, cfg)
    prompts = _prompts(cfg, [40, 4])
    ref = _reference(cfg, params, prompts, max_new=5)
    srv = _server(cfg, params, batch=2, pool_pages=16, prefill_chunk=8)
    sched = RequestScheduler(srv)
    r0 = sched.submit(prompts[0], max_new_tokens=5)
    r1 = sched.submit(prompts[1], max_new_tokens=5)
    while not (r0.state == PREFILLING and r0.prefill_pos > 0):
        sched.step()
    free_before = srv.page_pool.n_free
    held = len(srv._prefill_pages[r0.slot])
    sched._preempt(r0, "test-evict")
    assert r0.tokens_out == [] and r0.prefill_pos == 0
    assert r0.preemptions == 1
    assert srv.page_pool.n_free == free_before + held
    assert sched.queue[0] is r0
    res = sched.run()
    assert r0.state == FINISHED and r1.state == FINISHED
    np.testing.assert_array_equal(res[r0.rid], ref[0])
    np.testing.assert_array_equal(res[r1.rid], ref[1])


def test_chaos_parity_chunked_prefill():
    """The full chaos plan (device death, pool pressure, NaN step, EOS)
    with chunked admission on: every stream still matches the sequential
    fault-free splice-admission oracle bit-for-bit, on one compiled step
    program. (Seed 11, not 14: chunked admission shifts the tick at which
    each request is live, and 14's pressure window happens to miss — 11's
    actually evicts someone.)"""
    sched = _chaos_run(seed=11, prefill_chunk=8)
    assert sched.n_preempted > 0
    assert sched.server._decode._cache_size() == 1


def test_crash_restart_mid_prefill(tmp_path):
    """crash_restart landing while a request is half-prefilled: the
    snapshot records PREFILLING progress, restore requeues the request
    (its chunk KV died with the process) and re-prefills from chunk zero,
    and the restored streams are bit-identical to an uninterrupted run."""
    from repro.runtime import snapshot as S

    cfg = _dense_cfg()
    params = T.init_params(RNG, cfg)
    prompts = _prompts(cfg, [20, 6])
    kw = dict(batch=2, pool_pages=16, prefill_chunk=8)

    ref_sched = RequestScheduler(_server(cfg, params, **kw))
    for i, p in enumerate(prompts):
        ref_sched.submit(p, max_new_tokens=5, arrival=i)
    ref = ref_sched.run()

    # rid 0's 20-token prompt takes 3 chunk ticks from its step-0
    # admission; the crash at step 1 lands mid-prefill (pos=8, no token).
    path = os.path.join(str(tmp_path), "snap.npz")
    plan = FaultPlan([Fault(step=1, kind=CRASH_RESTART, path=path)])
    sched = RequestScheduler(_server(cfg, params, **kw), faults=plan)
    reqs = [sched.submit(p, max_new_tokens=5, arrival=i)
            for i, p in enumerate(prompts)]
    with pytest.raises(SimulatedCrash):
        sched.run()
    assert reqs[0].state == PREFILLING
    assert 0 < reqs[0].prefill_pos < len(prompts[0])
    assert reqs[0].tokens_out == []

    restored = S.restore_scheduler(
        path, cfg, ParallelCtx(capacity_factor=8.0),
        jax.tree.map(jnp.copy, params), faults=plan,
    )
    rec = next(r for r in restored.requests if r.rid == reqs[0].rid)
    assert rec.prefill_pos == 0    # chunk KV died: restart from chunk zero
    res = restored.run()
    assert all(r.state == FINISHED for r in restored.requests)
    for rid, want in ref.items():
        np.testing.assert_array_equal(res[rid], want)
