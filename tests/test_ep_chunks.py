"""Chunked EP dispatch (``ep_chunks=K``): the pipelined dispatch/combine
schedule must be a pure performance knob.

The contract under test everywhere: chunking slices the per-bucket
offsets/counts of ONE global ``dispatch_metadata`` call, so every bucket's
rows, keep mask, and FP combine order are unchanged — outputs are
*bit-identical* to the single-shot path for every K, on the mesh
(``ep_moe_shardmap``), no-mesh (``moe_esp``) and local-loopback paths,
with kernels on or off, under balanced and skewed routing, and with
capacity drops in play. Bad chunk counts fail loudly with named errors at
``ServeConfig`` construction and at every collectives entry point.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke
from repro.launch.mesh import make_mesh_compat
from repro.models import moe as M
from repro.models import transformer as T
from repro.parallel.collectives import validate_ep_chunks
from repro.parallel.ctx import ParallelCtx
from repro.runtime.serve import Server, ServeConfig

RNG = jax.random.PRNGKey(0)


def _cfg(**kw):
    base = dataclasses.replace(
        smoke(get_config("dbrx-132b")), n_experts=4, experts_per_token=2
    )
    return dataclasses.replace(base, **kw)


def _skewed_params(cfg, hot=(0, 1), scale=8.0):
    params = M.moe_init(RNG, cfg)
    router = np.asarray(params["router"])
    s = np.ones(router.shape[-1], router.dtype)
    s[list(hot)] = scale
    params = dict(params)
    params["router"] = jnp.asarray(router * s)
    return params


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------

def test_validate_ep_chunks_named_errors():
    assert validate_ep_chunks(1) == 1
    assert validate_ep_chunks(4, 8) == 4
    for bad in (0, -1, 2.0, True, "2"):
        with pytest.raises((ValueError, TypeError), match="ep_chunks"):
            validate_ep_chunks(bad)
    with pytest.raises(ValueError, match="does not divide"):
        validate_ep_chunks(3, 8, where="test")


def test_serve_config_validates_ep_chunks():
    ok = ServeConfig(max_seq=32, batch=2, slots_per_device=2, ep_chunks=2)
    assert ok.ep_chunks == 2
    # virtual_ep multiplies the group count: 3 slots x 4 virtual ranks = 12
    ok = ServeConfig(max_seq=32, batch=2, slots_per_device=3, virtual_ep=4,
                     ep_chunks=3)
    assert ok.ep_chunks == 3
    with pytest.raises(ValueError, match="ep_chunks"):
        ServeConfig(max_seq=32, batch=2, slots_per_device=3, ep_chunks=2)
    with pytest.raises(ValueError, match="ep_chunks"):
        ServeConfig(max_seq=32, batch=2, slots_per_device=2, ep_chunks=0)
    # ep_chunks=1 (the single-shot path) never needs divisibility
    assert ServeConfig(max_seq=32, batch=2, slots_per_device=3,
                       ep_chunks=1).ep_chunks == 1


def test_serve_config_ep_chunks_round_trips_via_asdict():
    # The crash-safe snapshot stores ServeConfig as dataclasses.asdict and
    # restores with ServeConfig(**d) — the new field must survive the trip
    # (and re-validate on the way back in).
    scfg = ServeConfig(max_seq=32, batch=2, slots_per_device=3, virtual_ep=4,
                       ep_chunks=3)
    back = ServeConfig(**dataclasses.asdict(scfg))
    assert back.ep_chunks == 3
    d = dataclasses.asdict(scfg)
    d["slots_per_device"], d["virtual_ep"] = 4, None   # 3 does not divide 4
    with pytest.raises(ValueError, match="ep_chunks"):
        ServeConfig(**d)


# ---------------------------------------------------------------------------
# bit parity: no-mesh paths
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["esp", "ep"])
@pytest.mark.parametrize("use_kernels", [False, True])
def test_no_mesh_chunked_parity(impl, use_kernels):
    """Single-process esp/ep: chunked output must be bit-identical to
    ep_chunks=1 under balanced routing, skewed routing, and a tight
    capacity that actually drops copies."""
    cfg = _cfg()
    for label, params, cf in (
        ("balanced", M.moe_init(RNG, cfg), 8.0),
        ("skewed", _skewed_params(cfg), 8.0),
        ("capacity_drop", _skewed_params(cfg), 1.0),
    ):
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
        base = None
        for K in (1, 2, 4):
            ctx = ParallelCtx(moe_impl=impl, capacity_factor=cf,
                              use_kernels=use_kernels, ep_chunks=K)
            out, _ = M.moe_apply(params, x, cfg, ctx)
            out = np.asarray(out)
            assert np.all(np.isfinite(out))
            if base is None:
                base = out
            else:
                np.testing.assert_array_equal(
                    out, base,
                    err_msg=f"{impl} uk={use_kernels} {label} K={K}")


def test_no_mesh_bad_chunk_count_fails_on_every_branch():
    # Validation runs at moe entry, not inside the fused branch: a bad
    # count must fail loudly even when kernels are off (padded branch).
    cfg = _cfg()   # 4 experts: 3 does not divide
    params = M.moe_init(RNG, cfg)
    x = jax.random.normal(RNG, (2, 4, cfg.d_model))
    for uk in (False, True):
        ctx = ParallelCtx(moe_impl="esp", capacity_factor=4.0,
                          use_kernels=uk, ep_chunks=3)
        with pytest.raises(ValueError, match="ep_chunks"):
            M.moe_apply(params, x, cfg, ctx)


# ---------------------------------------------------------------------------
# bit parity + grads: 1x1 mesh (shard_map path without multidevice cost)
# ---------------------------------------------------------------------------

def test_mesh_chunked_parity_and_grads():
    cfg = _cfg()
    params = _skewed_params(cfg)
    mesh = make_mesh_compat((1, 1), ("data", "model"))
    for shape in ((2, 8), (4, 1)):   # prefill and decode shapes
        x = jax.random.normal(jax.random.PRNGKey(2), (*shape, cfg.d_model))
        base = None
        for K in (1, 2, 4):
            ctx = ParallelCtx(mesh=mesh, moe_impl="ep", capacity_factor=1.0,
                              use_kernels=True, ep_chunks=K)
            out, _ = M.moe_apply(params, x, cfg, ctx)
            out = np.asarray(out)
            if base is None:
                base = out
            else:
                np.testing.assert_array_equal(out, base,
                                              err_msg=f"{shape} K={K}")

    # Gradients flow through the chunked custom_vjp identically.
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 8, cfg.d_model))

    def loss(p, K):
        ctx = ParallelCtx(mesh=mesh, moe_impl="ep", capacity_factor=2.0,
                          use_kernels=True, ep_chunks=K)
        out, _ = M.moe_apply(p, x, cfg, ctx)
        return jnp.sum(out * out)

    g1 = jax.grad(loss)(params, 1)
    g2 = jax.grad(loss)(params, 2)
    for key in ("w_gate", "w_up", "w_down", "router"):
        np.testing.assert_allclose(np.asarray(g1[key]), np.asarray(g2[key]),
                                   rtol=1e-6, atol=1e-6, err_msg=key)


# ---------------------------------------------------------------------------
# serving: one compiled program, bit-identical streams
# ---------------------------------------------------------------------------

def test_server_chunked_generation_parity_one_program():
    """A chunked server must generate bit-identical tokens to the
    single-shot server, from ONE compiled step program (the chunk count is
    static, baked into the jitted closures — no traced switch)."""
    cfg = _cfg()
    params = T.init_params(RNG, cfg)
    prompt = jnp.ones((2, 6), jnp.int32)

    def gen(ep_chunks):
        srv = Server(cfg, ParallelCtx(capacity_factor=8.0),
                     jax.tree.map(jnp.copy, params),
                     ServeConfig(max_seq=32, batch=2, slots_per_device=3,
                                 virtual_ep=4, ep_chunks=ep_chunks))
        out = np.asarray(srv.generate(prompt, 8))
        return srv, out

    srv1, base = gen(1)
    for K in (2, 3):
        srv, out = gen(K)
        np.testing.assert_array_equal(out, base, err_msg=f"ep_chunks={K}")
        assert srv.ctx.ep_chunks == K          # config landed on the ctx
        assert srv._decode._cache_size() == 1  # still one compiled program
    assert srv1._decode._cache_size() == 1
