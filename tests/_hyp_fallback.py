"""Deterministic stand-in for ``hypothesis`` when it isn't installed.

Property-test modules import ``given``/``settings``/``strategies`` through

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hyp_fallback import given, settings, strategies as st

With real hypothesis absent this shim replays each property over a fixed
pseudo-random sample grid (seeded, so runs are reproducible) instead of
skipping the tests outright. Only the tiny strategy surface this repo uses
is implemented; install ``hypothesis`` (see requirements-dev.txt) for real
shrinking/coverage.
"""

from __future__ import annotations

import random

_MAX_FALLBACK_EXAMPLES = 25  # keep the deterministic replay cheap


class _Strategy:
    def __init__(self, sample):
        self._sample = sample

    def sample(self, rng: random.Random):
        return self._sample(rng)


class strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda rng: bool(rng.getrandbits(1)))

    @staticmethod
    def floats(min_value: float, max_value: float) -> _Strategy:
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def sampled_from(elements) -> _Strategy:
        elements = list(elements)
        return _Strategy(lambda rng: rng.choice(elements))


def settings(max_examples: int = 20, **_ignored):
    def deco(f):
        f._hyp_max_examples = max_examples
        return f

    return deco


def given(*arg_strats: _Strategy, **kw_strats: _Strategy):
    def deco(f):
        # Zero-arg wrapper (no functools.wraps: pytest must NOT see the
        # strategy parameters of ``f`` and go hunting for fixtures).
        def wrapper():
            n = getattr(wrapper, "_hyp_max_examples", None)
            if n is None:
                n = getattr(f, "_hyp_max_examples", 20)
            rng = random.Random(0xC0FFEE)
            for _ in range(min(n, _MAX_FALLBACK_EXAMPLES)):
                args = [s.sample(rng) for s in arg_strats]
                kwargs = {name: s.sample(rng) for name, s in kw_strats.items()}
                f(*args, **kwargs)

        wrapper.__name__ = f.__name__
        wrapper.__doc__ = f.__doc__
        wrapper.__module__ = f.__module__
        wrapper._hyp_max_examples = getattr(f, "_hyp_max_examples", None)
        return wrapper

    return deco
