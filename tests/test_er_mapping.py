import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - deterministic replay shim
    from _hyp_fallback import given, settings, strategies as st

from repro.core.er_mapping import (
    baseline_mapping,
    er_mapping,
    factor_pair,
    grid_cycle,
    hierarchical_er_mapping,
)
from repro.core.ftd import ftd_stats
from repro.core.topology import MeshTopology


@given(st.integers(1, 9), st.integers(1, 9))
@settings(max_examples=50, deadline=None)
def test_grid_cycle_visits_all_unit_steps(h, w):
    cyc = grid_cycle(h, w)
    assert sorted(cyc) == sorted((r, c) for r in range(h) for c in range(w))
    for (r1, c1), (r2, c2) in zip(cyc, cyc[1:]):
        assert abs(r1 - r2) + abs(c1 - c2) == 1
    if (h % 2 == 0 or w % 2 == 0) and h > 1 and w > 1:
        # true Hamiltonian cycle: closing step is also unit length
        (r1, c1), (r2, c2) = cyc[-1], cyc[0]
        assert abs(r1 - r2) + abs(c1 - c2) == 1


def _check_mapping_invariants(m):
    topo = m.topo
    # every device appears in exactly one TP group and one FTD
    seen = sorted(d for g in m.tp_groups for d in g)
    assert seen == list(range(topo.n_devices))
    seen = sorted(d for f in m.ftds for d in f)
    assert seen == list(range(topo.n_devices))
    # each FTD holds exactly one member of every TP group
    for f in m.ftds:
        groups = sorted(int(m.group_of[d]) for d in f)
        assert groups == list(range(m.dp))


@pytest.mark.parametrize("ctor", [baseline_mapping, er_mapping])
@pytest.mark.parametrize("rows,cols,dp,tp", [(4, 4, 4, 4), (6, 6, 6, 6), (8, 8, 4, 16), (8, 8, 16, 4)])
def test_mapping_invariants(ctor, rows, cols, dp, tp):
    m = ctor(MeshTopology(rows, cols), dp, tp)
    _check_mapping_invariants(m)


def test_paper_fig8_numbers():
    """Fig. 8: baseline 4x4 has ~2.7 avg FTD hops, intersecting FTDs;
    ER-Mapping halves hops to 1.33 and removes all intersections."""
    topo = MeshTopology(4, 4)
    sb = ftd_stats(baseline_mapping(topo, 4, 4))
    se = ftd_stats(er_mapping(topo, 4, 4))
    assert sb.avg_hops == pytest.approx(8 / 3, abs=0.01)   # "2.7 hops"
    assert se.avg_hops == pytest.approx(4 / 3, abs=0.01)   # 2x reduction
    assert sb.n_intersecting_pairs > 0
    assert se.n_intersecting_pairs == 0


def test_er_ring_hop_is_tile_pitch():
    topo = MeshTopology(4, 4)
    mb = baseline_mapping(topo, 4, 4)
    me = er_mapping(topo, 4, 4)
    assert mb.max_ring_hop() == 1      # contiguous blocks: unit ring steps
    assert me.max_ring_hop() == 2      # entwined rings: two-hop steps


def test_device_order_is_permutation():
    m = er_mapping(MeshTopology(8, 8), 8, 8)
    order = m.device_order()
    assert order.shape == (8, 8)
    assert sorted(order.ravel().tolist()) == list(range(64))


def test_hierarchical_mapping_multi_wafer():
    topo = MeshTopology(4, 4, n_wafers=2)
    m = hierarchical_er_mapping(topo, 4, 8)
    _check_mapping_invariants(m)
    # group ranks are striped across wafers: half the members per wafer
    for g in range(4):
        wafers = [m.topo.wafer_of(m.topo.coord(d)) for d in m.tp_groups[g]]
        assert wafers.count(0) == 4 and wafers.count(1) == 4


def test_factor_pair_prefers_square():
    assert factor_pair(16, 16, 16) == (4, 4)
    assert factor_pair(8, 4, 4) == (2, 4) or factor_pair(8, 4, 4) == (4, 2)
    with pytest.raises(ValueError):
        factor_pair(7, 4, 4)
