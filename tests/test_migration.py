import pytest

from repro.core.comm_model import A2AWorkload, link_heatmaps
from repro.core.er_mapping import er_mapping
from repro.core.hardware import WSC
from repro.core.migration import MigrationEngine, decompose
from repro.core.topology import MeshTopology

EXPERT_BYTES = 42e6  # DeepSeek-V3 expert


@pytest.fixture
def setup():
    topo = MeshTopology(4, 4)
    m = er_mapping(topo, 4, 4)
    ar, a2a = link_heatmaps(m, WSC, 256 * 4096 * 2, A2AWorkload(256, 8192, 8))
    return topo, m, ar, a2a


def test_decompose_structure(setup):
    topo, m, *_ = setup
    # same FTD -> single local step
    f0 = m.ftds[0]
    steps = decompose((0, f0[0], f0[1]), m, EXPERT_BYTES)
    assert [s.kind for s in steps] == ["local"]
    # cross-FTD -> local/global/local with matching endpoints
    src, dst = m.ftds[0][0], m.ftds[3][3]
    steps = decompose((0, src, dst), m, EXPERT_BYTES)
    kinds = [s.kind for s in steps]
    assert "global" in kinds
    assert steps[0].src == src and steps[-1].dst == dst
    for s1, s2 in zip(steps, steps[1:]):
        assert s1.dst == s2.src


def test_noninvasive_completes_with_zero_exposure(setup):
    topo, m, ar, a2a = setup
    eng = MigrationEngine(m, WSC, EXPERT_BYTES, mode="noninvasive")
    exposed = eng.submit([(0, m.ftds[0][0], m.ftds[3][3])])
    assert exposed == 0.0
    for _ in range(200):
        eng.step_iteration(1e-3, 1e-3, ar, a2a)
        if eng.pending == 0:
            break
    assert eng.pending == 0
    assert eng.total_exposed == 0.0


def test_invasive_exposes_route_time(setup):
    topo, m, *_ = setup
    eng = MigrationEngine(m, WSC, EXPERT_BYTES, mode="invasive")
    exposed = eng.submit([(0, 0, 15)])
    assert exposed > 0
    assert eng.total_exposed == exposed


def test_noninvasive_slower_when_links_hot(setup):
    """With saturated links (tiny phases) migrations take more iterations."""
    topo, m, ar, a2a = setup
    fast = MigrationEngine(m, WSC, EXPERT_BYTES)
    slow = MigrationEngine(m, WSC, EXPERT_BYTES)
    mig = [(0, m.ftds[0][0], m.ftds[3][3])]
    fast.submit(list(mig))
    slow.submit(list(mig))
    it_fast = it_slow = 0
    while fast.pending and it_fast < 500:
        fast.step_iteration(1e-3, 1e-3, ar, a2a)
        it_fast += 1
    while slow.pending and it_slow < 500:
        slow.step_iteration(2e-6, 2e-6, ar, a2a)
        it_slow += 1
    assert it_fast <= it_slow
