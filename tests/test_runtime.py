"""Runtime substrate: optimizer, train loop, checkpoint/restart, data."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke
from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.data import DataConfig, SyntheticLM
from repro.runtime.optimizer import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    cosine_lr,
)
from repro.parallel.ctx import NO_MESH
from repro.runtime.train import init_state, make_train_step


def test_adamw_minimizes_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=200, weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    opt = adamw_init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(g, opt, params, cfg)
    assert float(loss(params)) < 1e-2


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    lr = cosine_lr(cfg)
    assert float(lr(jnp.array(0))) == pytest.approx(0.0)
    assert float(lr(jnp.array(10))) == pytest.approx(1.0, rel=1e-3)
    assert float(lr(jnp.array(100))) == pytest.approx(0.1, rel=1e-2)


def test_grad_clip():
    cfg = AdamWConfig(lr=0.0, clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(3)}
    opt = adamw_init(params)
    big = {"w": jnp.full(3, 1e6)}
    _, _, met = adamw_update(big, opt, params, cfg)
    assert float(met["grad_norm"]) > 1e5  # reported norm is pre-clip


def test_loss_decreases_short_training():
    cfg = smoke(get_config("tinyllama-1.1b"))
    state = init_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(
        make_train_step(cfg, NO_MESH, AdamWConfig(lr=1e-2, warmup_steps=2, total_steps=40))
    )
    data = SyntheticLM(DataConfig(cfg.vocab_size, 8, 32))
    losses = []
    for i in range(10):
        state, met = step(state, data.batch_at(i))
        losses.append(float(met["loss"]))
    assert losses[-1] < losses[0]


@pytest.mark.slow
def test_grad_accumulation_equivalence():
    """2 microbatches of B == 1 batch of 2B (up to clip/numerics)."""
    cfg = smoke(get_config("llama3.2-1b"))
    opt = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10, clip_norm=1e9)
    data = SyntheticLM(DataConfig(cfg.vocab_size, 8, 16))
    b = data.batch_at(0)
    big = {"tokens": b["tokens"], "labels": b["labels"]}
    micro = {
        "tokens": b["tokens"].reshape(2, 4, 16),
        "labels": b["labels"].reshape(2, 4, 16),
    }
    s1 = init_state(jax.random.PRNGKey(0), cfg)
    s2 = jax.tree.map(jnp.copy, s1)
    s1, _ = jax.jit(make_train_step(cfg, NO_MESH, opt))(s1, big)
    s2, _ = jax.jit(make_train_step(cfg, NO_MESH, opt, microbatches=2))(s2, micro)
    err = max(
        float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s2["params"]))
    )
    assert err < 1e-5


def test_checkpoint_roundtrip_and_retention(tmp_path):
    cfg = smoke(get_config("tinyllama-1.1b"))
    state = init_state(jax.random.PRNGKey(0), cfg)
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (10, 20, 30):
        mgr.save(s, state, extra={"data_step": s})
    assert mgr.steps() == [20, 30]  # retention gc
    restored, meta = mgr.restore(state)
    assert meta["step"] == 30
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity(tmp_path):
    """A leftover .tmp never shadows the real checkpoint."""
    cfg = smoke(get_config("tinyllama-1.1b"))
    state = init_state(jax.random.PRNGKey(0), cfg)
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, state)
    # simulate a crash mid-save of step 2
    open(os.path.join(str(tmp_path), "ckpt_00000002.npz.tmp.npz"), "w").close()
    assert mgr.latest() == 1
    mgr.restore(state)  # still restorable


def test_async_checkpoint(tmp_path):
    cfg = smoke(get_config("tinyllama-1.1b"))
    state = init_state(jax.random.PRNGKey(0), cfg)
    mgr = CheckpointManager(str(tmp_path))
    mgr.async_save(5, state)
    mgr.wait()
    assert mgr.latest() == 5


def test_async_checkpoint_enforces_retention(tmp_path):
    """The background writer must run the same retention gc the sync path
    does (the old thread target was bare `save` — `keep` was a no-op for
    async-only users and the directory grew without bound)."""
    cfg = smoke(get_config("tinyllama-1.1b"))
    state = init_state(jax.random.PRNGKey(0), cfg)
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.async_save(s, state)
    mgr.wait()
    assert mgr.steps() == [3, 4]


def test_async_checkpoint_reraises_write_failure(tmp_path, monkeypatch):
    """A failed background write surfaces at the next wait() instead of
    dying silently on the worker thread."""
    cfg = smoke(get_config("tinyllama-1.1b"))
    state = init_state(jax.random.PRNGKey(0), cfg)
    mgr = CheckpointManager(str(tmp_path))

    def boom(*a, **k):
        raise OSError("disk full")

    monkeypatch.setattr("repro.runtime.checkpoint.save", boom)
    mgr.async_save(1, state)
    with pytest.raises(OSError, match="disk full"):
        mgr.wait()
    # the exception is consumed: the manager is reusable afterwards
    monkeypatch.undo()
    mgr.async_save(2, state)
    mgr.wait()
    assert mgr.latest() == 2


def test_torn_checkpoint_skipped_and_gced(tmp_path):
    """A crash between the .npz replace and the .meta replace leaves a
    meta-less checkpoint: steps()/latest() must skip it (so restore falls
    back to the newest complete one) and a later gc reclaims the orphan."""
    cfg = smoke(get_config("tinyllama-1.1b"))
    state = init_state(jax.random.PRNGKey(0), cfg)
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, state, extra={"data_step": 1})
    mgr.save(2, state, extra={"data_step": 2})
    # injected partial write: step 2's npz landed, its meta did not
    os.remove(os.path.join(str(tmp_path), "ckpt_00000002.npz.meta"))
    assert mgr.steps() == [1]
    assert mgr.latest() == 1
    _, meta = mgr.restore(state)
    assert meta["step"] == 1
    # torn npz is still on disk (never silently deleted before a newer
    # complete step exists beyond it) ...
    assert mgr.steps(complete_only=False) == [1, 2]
    # ... and the next successful save's gc reclaims it
    mgr.save(3, state)
    assert mgr.steps(complete_only=False) == [1, 3]
    assert mgr.steps() == [1, 3]


def test_data_determinism_and_host_sharding():
    a = SyntheticLM(DataConfig(1000, 8, 32, seed=1)).batch_at(7)
    b = SyntheticLM(DataConfig(1000, 8, 32, seed=1)).batch_at(7)
    assert np.array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    # host shards are disjoint parts of the same global batch contract
    h0 = SyntheticLM(DataConfig(1000, 8, 32, seed=1, n_hosts=2, host_id=0)).batch_at(7)
    h1 = SyntheticLM(DataConfig(1000, 8, 32, seed=1, n_hosts=2, host_id=1)).batch_at(7)
    assert h0["tokens"].shape == (4, 32)
    assert not np.array_equal(np.asarray(h0["tokens"]), np.asarray(h1["tokens"]))


def test_data_labels_shifted():
    d = SyntheticLM(DataConfig(1000, 4, 16, seed=0))
    b = d.batch_at(0)
    # labels are the next-token stream: markov structure -> learnable
    assert b["tokens"].shape == b["labels"].shape == (4, 16)


def test_restart_replays_stream():
    """Restart-from-cursor yields the identical batch sequence."""
    d = SyntheticLM(DataConfig(1000, 4, 16, seed=2))
    run1 = [d.batch_at(i)["tokens"] for i in range(5)]
    run2 = [d.batch_at(i)["tokens"] for i in range(3, 5)]
    assert np.array_equal(np.asarray(run1[3]), np.asarray(run2[0]))
    assert np.array_equal(np.asarray(run1[4]), np.asarray(run2[1]))
