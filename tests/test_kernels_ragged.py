"""Ragged (count-aware) GMM kernels and the kernel-dispatch layer.

Covers:
* ``gmm_ragged`` / ``gmm_dual_act_ragged`` parity vs the einsum oracles
  across uneven group counts (zero-token groups, full groups, counts that
  don't hit tile boundaries) and non-MXU-aligned C/D/F;
* tile-skip semantics — garbage (NaN) rows past a group's count must never
  leak into kept rows or the output tail;
* the ``groups_per_weight`` divisor mapping both MoE layouts rely on;
* differentiability of the registry ops (kernel forward, reference-math
  backward via custom_vjp);
* end-to-end parity of ``moe_esp`` / ``moe_ep`` / prefill / decode
  attention with kernels on vs off (interpret mode on CPU).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke
from repro.kernels import registry
from repro.kernels.gmm.ops import expert_ffn_ragged, gmm_ragged_op
from repro.kernels.gmm.ragged import gmm_dual_act_ragged
from repro.kernels.gmm.ref import (
    expert_ffn_ragged_ref,
    gmm_ragged_ref,
    gmm_ref,
)
from repro.models import attention as A
from repro.models.moe import moe_dense, moe_ep, moe_esp, moe_init
from repro.parallel.ctx import ParallelCtx

RNG = jax.random.PRNGKey(0)

CTX_ON = ParallelCtx(capacity_factor=8.0, use_kernels=True)
CTX_OFF = ParallelCtx(capacity_factor=8.0, use_kernels=False)


@pytest.fixture(scope="module")
def moe_cfg():
    return dataclasses.replace(
        smoke(get_config("dbrx-132b")), n_experts=4, experts_per_token=2
    )


# ---------------------------------------------------------------------------
# gmm_ragged vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "g,c,d,f,counts",
    [
        (4, 16, 8, 12, [0, 5, 16, 3]),          # zero group, full group
        (3, 96, 64, 160, [1, 95, 40]),          # non-128 C/D/F
        (2, 128, 128, 256, [128, 17]),          # MXU-native tiles
        (5, 24, 48, 40, [24, 0, 0, 7, 2]),      # multiple empty groups
    ],
)
def test_gmm_ragged_matches_ref(g, c, d, f, counts):
    ks = jax.random.split(RNG, 2)
    x = jax.random.normal(ks[0], (g, c, d))
    w = jax.random.normal(ks[1], (g, d, f)) * 0.1
    gs = jnp.asarray(counts, jnp.int32)
    out = gmm_ragged_op(x, w, gs)
    ref = gmm_ragged_ref(x, w, gs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)
    # Kept rows agree with the *dense* oracle bit-for-bit when the K dim
    # fits one accumulation tile (same fp32 contraction order).
    if d <= 128:
        dense = np.asarray(gmm_ref(x, w))
        outn = np.asarray(out)
        for gi, cnt in enumerate(counts):
            np.testing.assert_array_equal(outn[gi, :cnt], dense[gi, :cnt])
    # Rows past each group's count are exactly zero.
    outn = np.asarray(out)
    for gi, cnt in enumerate(counts):
        assert (outn[gi, cnt:] == 0).all()


def test_gmm_ragged_skips_dead_rows():
    """NaNs planted past each group's count must not reach the output —
    dead row-tiles are skipped (no MXU pass), partial tiles are masked.
    This is the semantic footprint of FLOPs ~ sum(group_sizes)."""
    g, c, d, f = 3, 64, 32, 48
    counts = jnp.asarray([10, 0, 33], jnp.int32)
    ks = jax.random.split(RNG, 2)
    x = jax.random.normal(ks[0], (g, c, d))
    rows = jnp.arange(c)[None, :, None]
    x = jnp.where(rows < counts[:, None, None], x, jnp.nan)
    w = jax.random.normal(ks[1], (g, d, f)) * 0.1
    out = np.asarray(gmm_ragged_op(x, w, counts))
    assert np.isfinite(out).all()
    ref = gmm_ragged_ref(jnp.nan_to_num(x), w, counts)
    np.testing.assert_allclose(out, np.asarray(ref), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("gpw", [2, 3])
def test_gmm_ragged_groups_per_weight(gpw):
    """gpw consecutive groups share one weight row — the flattened EP
    (slot-major) and ESP (expert-major) bucket layouts."""
    gw, c, d, f = 2, 16, 24, 20
    g = gw * gpw
    ks = jax.random.split(RNG, 2)
    x = jax.random.normal(ks[0], (g, c, d))
    w = jax.random.normal(ks[1], (gw, d, f)) * 0.1
    gs = jnp.arange(g, dtype=jnp.int32) * 2  # 0, 2, 4, ...
    out = gmm_ragged_op(x, w, gs, groups_per_weight=gpw)
    ref = gmm_ragged_ref(x, w, gs, groups_per_weight=gpw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_dual_act_ragged_matches_ref():
    g, c, d, f = 4, 32, 16, 24
    ks = jax.random.split(RNG, 3)
    x = jax.random.normal(ks[0], (g, c, d))
    wg = jax.random.normal(ks[1], (g, d, f)) * 0.1
    wu = jax.random.normal(ks[2], (g, d, f)) * 0.1
    gs = jnp.asarray([0, 32, 5, 19], jnp.int32)
    out = gmm_dual_act_ragged(x, wg, wu, gs, interpret=True)
    mask = (jnp.arange(c)[None, :] < gs[:, None])[..., None]
    ref = (jax.nn.silu(gmm_ref(x, wg)) * gmm_ref(x, wu)) * mask
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_expert_ffn_ragged_end_to_end():
    gw, gpw, c, d, f = 2, 2, 16, 8, 12
    g = gw * gpw
    ks = jax.random.split(RNG, 4)
    x = jax.random.normal(ks[0], (g, c, d))
    wg = jax.random.normal(ks[1], (gw, d, f)) * 0.1
    wu = jax.random.normal(ks[2], (gw, d, f)) * 0.1
    wd = jax.random.normal(ks[3], (gw, f, d)) * 0.1
    gs = jnp.asarray([7, 0, 16, 2], jnp.int32)
    out = expert_ffn_ragged(x, wg, wu, wd, gs, groups_per_weight=gpw)
    ref = expert_ffn_ragged_ref(x, wg, wu, wd, gs, gpw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# registry: dispatch decisions + differentiability
# ---------------------------------------------------------------------------

def test_registry_compiled_gates():
    """The compiled (non-interpret) path only takes MXU-tileable shapes;
    interpret mode takes anything."""
    assert registry.can_gmm(128, 128, 256, interpret=False)
    assert not registry.can_gmm(128, 96, 256, interpret=False)
    assert registry.can_gmm(7, 5, 3, interpret=True)
    assert registry.can_flash_attend(128, 128, 8, 2, 128, interpret=False)
    assert not registry.can_flash_attend(128, 128, 8, 3, 128, interpret=False)
    assert not registry.can_flash_attend(100, 100, 8, 2, 64, interpret=False)
    assert registry.can_flash_decode(256, 8, 2, 128, interpret=False)
    assert not registry.can_flash_decode(100, 8, 2, 64, interpret=False)


def test_registry_expert_ffn_grad_matches_ref():
    """Kernel forward + reference backward (custom_vjp) must match the
    all-reference gradients."""
    g, c, d, f = 4, 16, 8, 12
    ks = jax.random.split(RNG, 4)
    x = jax.random.normal(ks[0], (g, c, d))
    wg = jax.random.normal(ks[1], (g, d, f)) * 0.1
    wu = jax.random.normal(ks[2], (g, d, f)) * 0.1
    wd = jax.random.normal(ks[3], (g, f, d)) * 0.1
    gs = jnp.asarray([3, 16, 0, 9], jnp.int32)

    def loss(fn, x, wg, wu, wd):
        return (fn(x, wg, wu, wd) ** 2).sum()

    kern = lambda *a: registry.expert_ffn(*a, group_sizes=gs, enabled=True)
    ref = lambda *a: expert_ffn_ragged_ref(*a, gs)
    gk = jax.grad(loss, argnums=(1, 2, 3, 4))(kern, x, wg, wu, wd)
    gr = jax.grad(loss, argnums=(1, 2, 3, 4))(ref, x, wg, wu, wd)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


def test_registry_attend_grad_matches_ref():
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (1, 32, 4, 16))
    k = jax.random.normal(ks[1], (1, 32, 2, 16))
    v = jax.random.normal(ks[2], (1, 32, 2, 16))
    f_kern = lambda q_: registry.attend(q_, k, v, causal=True).sum()
    f_ref = lambda q_: A.gqa_attend(q_, k, v, A.causal_mask(32)).sum()
    np.testing.assert_allclose(
        np.asarray(jax.grad(f_kern)(q)),
        np.asarray(jax.grad(f_ref)(q)),
        rtol=1e-4,
        atol=1e-4,
    )


# ---------------------------------------------------------------------------
# end-to-end: kernels on vs off
# ---------------------------------------------------------------------------

def test_moe_esp_kernels_on_off_parity(moe_cfg):
    rng = jax.random.PRNGKey(0)
    p = moe_init(rng, moe_cfg)
    x = jax.random.normal(rng, (2, 8, moe_cfg.d_model)) * 0.5
    off, _ = moe_esp(p, x, moe_cfg, CTX_OFF)
    on, _ = moe_esp(p, x, moe_cfg, CTX_ON)
    np.testing.assert_allclose(np.asarray(on), np.asarray(off), rtol=1e-5, atol=1e-5)
    dense, _ = moe_dense(p, x, moe_cfg, CTX_OFF)
    np.testing.assert_allclose(np.asarray(on), np.asarray(dense), rtol=1e-5, atol=1e-5)


def test_moe_esp_kernels_grad_parity(moe_cfg):
    rng = jax.random.PRNGKey(1)
    p = moe_init(rng, moe_cfg)
    x = jax.random.normal(rng, (2, 8, moe_cfg.d_model)) * 0.5
    g_on = jax.grad(lambda p_: moe_esp(p_, x, moe_cfg, CTX_ON)[0].sum())(p)
    g_off = jax.grad(lambda p_: moe_esp(p_, x, moe_cfg, CTX_OFF)[0].sum())(p)
    for k in ("w_gate", "w_up", "w_down", "router"):
        np.testing.assert_allclose(
            np.asarray(g_on[k]), np.asarray(g_off[k]), rtol=1e-4, atol=1e-5
        )


def test_moe_ep_kernels_on_off_parity(moe_cfg):
    """EP dispatch (shard_map all_to_all) on a 1x1 mesh: the kernel path
    runs inside the shard_map body exactly as on a real EP axis."""
    from repro.launch.mesh import make_mesh_compat

    mesh = make_mesh_compat((1, 1), ("data", "model"))
    rng = jax.random.PRNGKey(0)
    p = moe_init(rng, moe_cfg)
    x = jax.random.normal(rng, (2, 8, moe_cfg.d_model)) * 0.5
    outs = {}
    for name, uk in (("off", False), ("on", True)):
        ctx = ParallelCtx(mesh=mesh, capacity_factor=8.0, use_kernels=uk)
        with mesh:
            outs[name], _ = jax.jit(
                lambda p_, x_, c_=ctx: moe_ep(p_, x_, moe_cfg, c_)
            )(p, x)
    np.testing.assert_allclose(
        np.asarray(outs["on"]), np.asarray(outs["off"]), rtol=1e-5, atol=1e-5
    )
    dense, _ = moe_dense(p, x, moe_cfg, CTX_OFF)
    np.testing.assert_allclose(
        np.asarray(outs["on"]), np.asarray(dense), rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("window", [0, 8])
def test_attention_kernels_on_off_parity(window):
    cfg = dataclasses.replace(smoke(get_config("dbrx-132b")), sliding_window=window)
    rng = jax.random.PRNGKey(0)
    p = A.attn_init(rng, cfg)
    x = jax.random.normal(rng, (2, 16, cfg.d_model)) * 0.5
    off = A.attention(p, x, cfg, CTX_OFF)
    on = A.attention(p, x, cfg, CTX_ON)
    np.testing.assert_allclose(np.asarray(on), np.asarray(off), rtol=1e-5, atol=1e-5)


def test_decode_attention_kernels_on_off_parity():
    cfg = smoke(get_config("dbrx-132b"))
    rng = jax.random.PRNGKey(0)
    p = A.attn_init(rng, cfg)
    cache = A.cache_init(cfg, 2, 32)
    x = jax.random.normal(rng, (2, 1, cfg.d_model)) * 0.5
    for pos in (0, 5, 31):
        o_off, c_off = A.decode_attention(p, x, cache, jnp.int32(pos), cfg, CTX_OFF)
        o_on, c_on = A.decode_attention(p, x, cache, jnp.int32(pos), cfg, CTX_ON)
        np.testing.assert_allclose(
            np.asarray(o_on), np.asarray(o_off), rtol=1e-5, atol=1e-5
        )
        np.testing.assert_array_equal(np.asarray(c_on["k"]), np.asarray(c_off["k"]))
