"""End-to-end behaviour tests: train -> checkpoint -> crash -> restore ->
identical continuation; then serve the trained model."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke
from repro.models import transformer as T
from repro.parallel.ctx import NO_MESH
from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.data import DataConfig, SyntheticLM
from repro.runtime.optimizer import AdamWConfig
from repro.runtime.serve import ServeConfig, Server
from repro.runtime.train import init_state, make_train_step


def test_train_crash_restore_identical(tmp_path):
    """The fault-tolerance contract: kill the job at step 6, restore from
    the step-5 checkpoint, and the rerun reproduces the original run's
    states bit-for-bit (deterministic data + optimizer)."""
    cfg = smoke(get_config("tinyllama-1.1b"))
    opt = AdamWConfig(lr=5e-3, warmup_steps=2, total_steps=20)
    data = SyntheticLM(DataConfig(cfg.vocab_size, 4, 32))
    step = jax.jit(make_train_step(cfg, NO_MESH, opt))
    mgr = CheckpointManager(str(tmp_path))

    state = init_state(jax.random.PRNGKey(0), cfg)
    reference = None
    for i in range(8):
        state, _ = step(state, data.batch_at(i))
        if i == 4:
            mgr.save(5, state, extra={"data_step": 5})
        if i == 7:
            reference = state

    # crash + restore
    template = init_state(jax.random.PRNGKey(0), cfg)
    state2, meta = mgr.restore(template)
    for i in range(meta["data_step"], 8):
        state2, _ = step(state2, data.batch_at(i))

    for a, b in zip(jax.tree.leaves(reference["params"]), jax.tree.leaves(state2["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6)


@pytest.mark.slow
def test_serve_after_training(tmp_path):
    """Train briefly, then serve: batched greedy generation is deterministic
    and produces in-vocab tokens."""
    cfg = smoke(get_config("llama3.2-1b"))
    opt = AdamWConfig(lr=5e-3, warmup_steps=2, total_steps=10)
    data = SyntheticLM(DataConfig(cfg.vocab_size, 4, 32))
    step = jax.jit(make_train_step(cfg, NO_MESH, opt))
    state = init_state(jax.random.PRNGKey(0), cfg)
    for i in range(5):
        state, _ = step(state, data.batch_at(i))

    server = Server(cfg, NO_MESH, state["params"], ServeConfig(max_seq=64, batch=3))
    prompt = jnp.ones((3, 8), jnp.int32)
    out1 = server.generate(prompt, 12)
    out2 = server.generate(prompt, 12)
    assert out1.shape == (3, 12)
    assert np.array_equal(np.asarray(out1), np.asarray(out2))
    assert int(out1.max()) < cfg.vocab_size


def test_moe_serving_single_device():
    """MoE serving works on one device (dense fallback path)."""
    cfg = smoke(get_config("mixtral-8x22b"))
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    server = Server(cfg, NO_MESH, params, ServeConfig(max_seq=48, batch=2))
    out = server.generate(jnp.ones((2, 6), jnp.int32), 8)
    assert out.shape == (2, 8)
