import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - deterministic replay shim
    from _hyp_fallback import given, settings, strategies as st

from repro.core.ni_balancer import (
    BalancerState,
    greedy_balance,
    imbalance_degree,
    should_trigger,
    topology_aware_balance,
)
from repro.core.topology import MeshTopology


def _dist_ring(a, b):
    return abs(a - b)


def _skewed_state(n_experts=16, n_devices=8, slots=3, seed=0):
    state = BalancerState.initial(n_experts, n_devices, slots)
    rng = np.random.default_rng(seed)
    loads = rng.dirichlet(np.full(n_experts, 0.3))
    state.load_ema = loads
    return state


def test_algorithm1_reduces_peak_heat():
    state = _skewed_state()
    before = state.heats().max()
    migs = topology_aware_balance(state, _dist_ring)
    assert migs
    for m in migs:
        state.apply(m)
    assert state.heats().max() < before


def test_algorithm1_respects_slots():
    state = _skewed_state(slots=2)
    migs = topology_aware_balance(state, _dist_ring)
    for m in migs:
        state.apply(m)
    assert state.slots_used().max() <= 2


def test_topology_aware_shorter_moves_than_greedy():
    """Algorithm 1's destination choice minimizes hop distance; EPLB-greedy
    ignores it. Average migration distance must not be larger."""
    topo = MeshTopology(4, 4)
    dist = lambda a, b: topo.hops(topo.coord(a), topo.coord(b))
    s1, s2 = _skewed_state(32, 16, 3, seed=1), _skewed_state(32, 16, 3, seed=1)
    topo_migs = topology_aware_balance(s1, dist)
    greedy_migs = greedy_balance(s2)
    d_topo = np.mean([dist(a, b) for _, a, b in topo_migs]) if topo_migs else 0
    d_greedy = np.mean([dist(a, b) for _, a, b in greedy_migs]) if greedy_migs else 0
    assert d_topo <= d_greedy + 1e-9


def test_dead_device_evacuated():
    from repro.core.ni_balancer import evacuate

    state = _skewed_state(8, 4, 4)
    migs = evacuate(state, 1, _dist_ring)
    assert migs  # experts 1 and 5 lived only on device 1
    # every expert homed on the dead device now has a live replica
    for e in range(state.n_experts):
        homes = state.replicas[e]
        if 1 in homes:
            assert any(d != 1 for d in homes)
    # load balancing still operates on the survivor set
    more = topology_aware_balance(state, _dist_ring)
    for m in more:
        assert m[2] != 1  # never migrate TO the dead device


def test_eq2_trigger():
    loads = [np.array([10.0, 1.0, 1.0, 1.0])]
    assert imbalance_degree(loads) == pytest.approx((10 - 3.25) / 3.25)
    assert should_trigger(loads, alpha=1.0, dt_since_migration=5, beta=0)
    assert not should_trigger(loads, alpha=5.0, dt_since_migration=5, beta=0)
    assert not should_trigger(loads, alpha=1.0, dt_since_migration=0.5, beta=1)


@given(st.integers(0, 10_000))
@settings(max_examples=30, deadline=None)
def test_balance_never_increases_peak(seed):
    state = _skewed_state(12, 6, 3, seed=seed)
    before = state.heats().max()
    migs = topology_aware_balance(state, _dist_ring)
    for m in migs:
        state.apply(m)
    assert state.heats().max() <= before + 1e-12


def test_observe_ema():
    state = BalancerState.initial(4, 2, 3)
    state.observe(np.array([100.0, 0, 0, 0]))
    state.observe(np.array([100.0, 0, 0, 0]))
    assert state.load_ema[0] > 0.5
    assert state.load_ema.sum() == pytest.approx(1.0)
