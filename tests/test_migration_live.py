"""Live stepped expert migration: slice schedule, atomic commit, parity.

The contract under test (docs/serving.md "Live migration"): a balancer plan
executes as one weight-row slice per decode tick, the committed routing
table never references a half-copied slot, the table swap happens only at a
step boundary after the last slice landed, and — because replicas are exact
copies — the generated tokens are bit-identical to both the instantaneous
baseline (``migration_slices=0``) and the dense no-balancer reference.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke
from repro.models import transformer as T
from repro.parallel.ctx import ParallelCtx
from repro.runtime.serve import Server, ServeConfig

RNG = jax.random.PRNGKey(0)


def _moe_cfg(**kw):
    base = dataclasses.replace(
        smoke(get_config("dbrx-132b")), n_experts=4, experts_per_token=2
    )
    return dataclasses.replace(base, **kw)


def _server(cfg, params, **scfg):
    ctx = ParallelCtx(capacity_factor=8.0)
    return Server(cfg, ctx, jax.tree.map(jnp.copy, params), ServeConfig(**scfg))


def _skew_router(params, hot=(0, 1), factor=8.0):
    """Sustained skewed traffic: scale the hot experts' router columns so
    their logit variance dominates and top-k picks them almost always —
    the Eq. 2 imbalance trigger then fires under real decode traffic."""
    params = jax.tree.map(jnp.copy, params)
    router = np.asarray(params["layers"]["moe"]["router"])  # (L, d, E)
    scale = np.ones(router.shape[-1], router.dtype)
    scale[list(hot)] = factor
    params["layers"]["moe"]["router"] = jnp.asarray(router * scale)
    return params


# ---------------------------------------------------------------------------
# acceptance: stepped == instantaneous == dense, with a >= 3-tick span
# ---------------------------------------------------------------------------

def test_stepped_migration_token_parity_and_span():
    cfg = _moe_cfg()
    params = _skew_router(T.init_params(RNG, cfg))
    prompt = jnp.ones((2, 6), jnp.int32)
    n_new = 12
    vep = dict(slots_per_device=3, virtual_ep=4, alpha=0.1)

    out_dense = _server(cfg, params, max_seq=32, batch=2).generate(
        prompt, n_new
    )
    srv_inst = _server(cfg, params, max_seq=32, batch=2,
                       migration_slices=0, **vep)
    out_inst = srv_inst.generate(prompt, n_new)
    srv_step = _server(cfg, params, max_seq=32, batch=2,
                       migration_slices=4, **vep)
    out_step = srv_step.generate(prompt, n_new)

    # Both balanced servers actually migrated under the skewed traffic.
    assert srv_inst.migrations > 0
    assert srv_step.migrations > 0 and srv_step.driver.history
    # Bit-exact parity: replicas are exact copies and tokens never observe
    # a half-copied slot, so stepping the copy cannot change any output.
    np.testing.assert_array_equal(np.asarray(out_dense), np.asarray(out_inst))
    np.testing.assert_array_equal(np.asarray(out_dense), np.asarray(out_step))
    # Slice schedule: every committed migration spread its copy over
    # >= 3 distinct decode ticks (no whole-expert single-tick copy) and the
    # atomic table swap happened strictly after the final slice's tick.
    for rec in srv_step.driver.history:
        assert len(rec["issue_ticks"]) == rec["n_slices"] >= 3
        assert len(set(rec["issue_ticks"])) >= 3
        assert rec["committed"] > max(rec["issue_ticks"])


# ---------------------------------------------------------------------------
# invariant: the committed routing view never references a torn replica
# ---------------------------------------------------------------------------

def test_never_routes_to_torn_replica():
    cfg = _moe_cfg()
    params = _skew_router(T.init_params(RNG, cfg))
    srv = _server(cfg, params, max_seq=32, batch=2, slots_per_device=3,
                  virtual_ep=4, alpha=0.1, migration_slices=4)
    prompt = jnp.ones((2, 6), jnp.int32)
    logits, cache = srv.prefill(prompt)
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    saw_in_flight = False
    prev_version, prev_commits = srv.table.version, srv.migrations
    for _ in range(12):
        logits, cache = srv.decode(tok, cache)
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        srv.table.check()
        committed_slots = set(np.asarray(srv.slot_of).ravel().tolist())
        for fl in srv.driver.in_flight:
            saw_in_flight = True
            # The reserved destination slot is invisible to routing: no
            # table entry — live or inert tail — references it.
            assert fl.dst_slot not in committed_slots
            assert not srv.table.used_slots(include_pending=False)[fl.dst_slot]
        # The routing view only swaps at commits: version bumps track the
        # number of committed migrations exactly (no other mutation here).
        assert (srv.table.version - prev_version
                == srv.migrations - prev_commits)
        prev_version, prev_commits = srv.table.version, srv.migrations
    assert saw_in_flight, "no migration was ever in flight — test is vacuous"
    assert srv.migrations > 0


# ---------------------------------------------------------------------------
# device death mid-migration: abort + requeue / fast-forward, never torn
# ---------------------------------------------------------------------------

def test_mark_dead_mid_migration_aborts_and_requeues():
    cfg = _moe_cfg()
    params = T.init_params(RNG, cfg)
    # 6 virtual devices x 2 slots: experts 0,1 on dev0; 2,3 on dev1;
    # devs 2-5 empty.
    srv = _server(cfg, params, max_seq=32, batch=1, slots_per_device=2,
                  virtual_ep=6, migration_slices=4)
    moe_before = {
        w: np.asarray(srv.params["layers"]["moe"][w]).copy()
        for w in ("w_gate", "w_up", "w_down")
    }
    accepted = srv.driver.submit([(0, 0, 3)], srv._moe(), srv.t)
    assert accepted == [(0, 0, 3)]
    srv.drain_migrations()   # slice 1 of 4
    srv.drain_migrations()   # slice 2 of 4
    (fl,) = srv.driver.in_flight
    assert 0 < fl.next_slice < fl.n_slices, "die mid-copy, not at an edge"

    srv.mark_dead(3)
    # Aborted, reservation released, no torn commit.
    (rec,) = srv.driver.aborted
    assert rec["mig"] == (0, 0, 3) and rec["committed"] is None
    assert (0, rec["dst_slot"]) not in srv.table.pending
    assert int(srv.table.n_replicas[0]) == 1
    # Requeued toward a live destination, restarting from slice zero
    # (dev1 is full, so the nearest free live device is 2).
    (fl2,) = srv.driver.in_flight
    assert fl2.mig == (0, 0, 2) and fl2.next_slice == 0
    # Let the requeued migration land; the committed replica is exact.
    for _ in range(fl2.n_slices + 1):
        srv.drain_migrations()
    assert srv.migrations == 1 and not srv.driver.in_flight
    dst_slot = srv.table.slot_on_device(0, 2)
    assert dst_slot is not None
    for w in ("w_gate", "w_up", "w_down"):
        np.testing.assert_array_equal(
            np.asarray(srv.params["layers"]["moe"][w])[:, dst_slot],
            moe_before[w][:, 0],
        )


def test_mark_dead_mid_migration_fast_forwards_source():
    cfg = _moe_cfg()
    params = T.init_params(RNG, cfg)
    srv = _server(cfg, params, max_seq=32, batch=1, slots_per_device=2,
                  virtual_ep=6, migration_slices=4)
    moe_before = {
        w: np.asarray(srv.params["layers"]["moe"][w]).copy()
        for w in ("w_gate", "w_up", "w_down")
    }
    assert srv.driver.submit([(2, 1, 4)], srv._moe(), srv.t) == [(2, 1, 4)]
    srv.drain_migrations()   # slice 1 of 4
    # Source device dies mid-copy: the remaining slices are issued
    # immediately and the replica commits (never torn), then evacuation
    # rescues the other orphan (expert 3) and routing drops dev 1.
    srv.mark_dead(1)
    (rec,) = [r for r in srv.driver.history if r["mig"] == (2, 1, 4)]
    assert rec["committed"] is not None
    assert len(rec["issue_ticks"]) == rec["n_slices"]
    assert not srv.driver.in_flight
    dst_slot = srv.table.slot_on_device(2, 4)
    assert dst_slot is not None
    for w in ("w_gate", "w_up", "w_down"):
        np.testing.assert_array_equal(
            np.asarray(srv.params["layers"]["moe"][w])[:, dst_slot],
            moe_before[w][:, 2],
        )
    # Expert 3 (the other orphan) was evacuated table-side + weight-side.
    assert all(d != 1 for d in srv.table.replica_devices(3))
    assert not np.any(
        np.asarray(srv.slot_of) // srv.scfg.slots_per_device == 1
    )
    srv.table.check()
