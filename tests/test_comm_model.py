import numpy as np
import pytest

from repro.core.comm_model import (
    A2AWorkload,
    cluster_allreduce,
    cluster_alltoall,
    cold_links,
    hier_allreduce,
    link_heatmaps,
    mesh_allreduce,
    mesh_alltoall,
)
from repro.core.er_mapping import (
    baseline_mapping,
    er_mapping,
    hierarchical_er_mapping,
)
from repro.core.hardware import DGX, NVL72, WSC
from repro.core.topology import MeshTopology

B = 256 * 4096 * 2  # 256 tokens x 4k hidden, fp16
WL = A2AWorkload(tokens_per_group=256, token_bytes=4096 * 2, topk=8)


def test_er_trades_allreduce_for_alltoall():
    """Paper Section IV-B: ER doubles all-reduce but more than halves
    all-to-all; the paper's headline trade."""
    topo = MeshTopology(4, 4)
    mb, me = baseline_mapping(topo, 4, 4), er_mapping(topo, 4, 4)
    ar_b, ar_e = mesh_allreduce(mb, WSC, B), mesh_allreduce(me, WSC, B)
    a2a_b, a2a_e = mesh_alltoall(mb, WSC, WL), mesh_alltoall(me, WSC, WL)
    assert ar_e.time == pytest.approx(2 * ar_b.time, rel=0.05)
    assert a2a_e.time <= 0.5 * a2a_b.time + 1e-9
    # net communication still wins when a2a dominates
    assert ar_e.time + a2a_e.time < ar_b.time + a2a_b.time


def test_retaining_allgather_shrinks_alltoall():
    """Paper Fig. 9/14(b): dropping AG spreads sources across the mesh."""
    topo = MeshTopology(4, 4)
    me = er_mapping(topo, 4, 4)
    with_ag = mesh_alltoall(me, WSC, WL, retain_ag=True)
    no_ag = mesh_alltoall(me, WSC, WL, retain_ag=False)
    assert with_ag.time < no_ag.time


def test_hierarchical_allreduce_beats_flat_on_multiwafer():
    topo = MeshTopology(4, 4, n_wafers=2)
    m = hierarchical_er_mapping(topo, 4, 8)
    flat = mesh_allreduce(m, WSC, B)
    hier = hier_allreduce(m, WSC, B)
    assert hier.time < flat.time


def test_cluster_models_ordering():
    """DGX (IB-bottlenecked) is slower than NVL72 at equal device count."""
    ar_dgx = cluster_allreduce(DGX, 64, B)
    ar_nvl = cluster_allreduce(NVL72, 64, B)
    assert ar_nvl.time < ar_dgx.time
    a2a_dgx = cluster_alltoall(DGX, 64, 1e9)
    a2a_nvl = cluster_alltoall(NVL72, 64, 1e9)
    assert a2a_nvl.time < a2a_dgx.time


def test_wsc_beats_dgx_alltoall():
    """Paper Fig. 13(a): unified mesh >> IB-separated nodes for dispatch."""
    topo = MeshTopology(6, 6)
    me = er_mapping(topo, 6, 6)
    wsc = mesh_alltoall(me, WSC, WL)
    dgx = cluster_alltoall(DGX, 32, WL.tokens_per_group * WL.topk * WL.token_bytes / 8)
    assert wsc.time < dgx.time


def test_cold_links_complementary():
    """Paper Fig. 11: all-reduce leaves intra-FTD links cold, all-to-all
    leaves inter-FTD links cold — the union covers most of the mesh."""
    topo = MeshTopology(4, 4)
    me = er_mapping(topo, 4, 4)
    ar_loads, a2a_loads = link_heatmaps(me, WSC, B, WL)
    cold_ar = cold_links(ar_loads, frac=0.5)
    cold_a2a = cold_links(a2a_loads, frac=0.05)
    union = cold_ar | cold_a2a
    assert union.mean() >= 0.6
    # all-to-all is FTD-confined: strictly inter-FTD links carry nothing
    inter = []
    for i, (u, v) in enumerate(topo.links):
        if me.ftd_of[u] != me.ftd_of[v]:
            inter.append(i)
    assert (a2a_loads[inter] == 0).all()


def test_imbalance_increases_alltoall():
    topo = MeshTopology(4, 4)
    me = er_mapping(topo, 4, 4)
    load = np.ones(16)
    load[5] = 3.0
    wl_imb = A2AWorkload(256, 4096 * 2, 8, device_load=load)
    assert mesh_alltoall(me, WSC, wl_imb).time > mesh_alltoall(me, WSC, WL).time
