"""Multi-device parity checks (8 forced host devices, run in subprocesses
so the main pytest process keeps its single real device)."""

import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow  # subprocess + forced-device tests: full tier only

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8, timeout: int = 420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True,
        text=True,
        env=env,
        timeout=timeout,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


def test_ep_esp_decode_parity_8dev():
    out = _run(
        """
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.configs import get_config, smoke
        from repro.models.moe import moe_dense, moe_ep, moe_esp, moe_init
        from repro.parallel.ctx import ParallelCtx
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((2, 4), ("data", "model"))
        ctx = ParallelCtx(mesh=mesh, capacity_factor=8.0)
        cfg = dataclasses.replace(smoke(get_config("dbrx-132b")),
                                  n_experts=4, experts_per_token=2)
        rng = jax.random.PRNGKey(0)
        p = moe_init(rng, cfg)
        # train-shape parity (seq split over EP axis)
        x = jax.random.normal(rng, (4, 8, cfg.d_model)) * 0.5
        ref, _ = moe_dense(p, x, cfg, ctx)
        with mesh:
            ep, _ = jax.jit(lambda p, x: moe_ep(p, x, cfg, ctx))(p, x)
            esp, _ = jax.jit(lambda p, x: moe_esp(p, x, cfg, ctx))(p, x)
        assert float(jnp.max(jnp.abs(ep - ref))) < 1e-5, "ep train parity"
        assert float(jnp.max(jnp.abs(esp - ref))) < 1e-5, "esp train parity"
        # decode-shape parity (owned-token dispatch + psum)
        xd = jax.random.normal(rng, (8, 1, cfg.d_model)) * 0.5
        refd, _ = moe_dense(p, xd, cfg, ctx)
        with mesh:
            epd, _ = jax.jit(lambda p, x: moe_ep(p, x, cfg, ctx))(p, xd)
        assert float(jnp.max(jnp.abs(epd - refd))) < 1e-5, "ep decode parity"
        print("PARITY_OK")
        """
    )
    assert "PARITY_OK" in out


def test_ep_fused_dispatch_parity_8dev():
    """Fused rank-compacted dispatch + compact combine (kernels on,
    interpret mode) across a real 4-way all_to_all: both legs ship the
    compact exchange buffer and the combine gathers through dest/posr/keep
    metadata. Prefill + decode (ownership sentinel + psum) + a
    non-divisible expert count (tiled shadow slots), all vs the dense
    oracle."""
    out = _run(
        """
        import jax, jax.numpy as jnp, dataclasses
        from repro.configs import get_config, smoke
        from repro.models.moe import moe_dense, moe_ep, moe_init
        from repro.parallel.ctx import ParallelCtx
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((2, 4), ("data", "model"))
        ctx = ParallelCtx(mesh=mesh, capacity_factor=8.0, use_kernels=True)
        ref_ctx = ParallelCtx(capacity_factor=8.0, use_kernels=False)
        rng = jax.random.PRNGKey(0)
        for n_exp in (4, 6):  # 6 % ep(4) != 0 -> tiled shadow slots
            cfg = dataclasses.replace(smoke(get_config("dbrx-132b")),
                                      n_experts=n_exp, experts_per_token=2)
            p = moe_init(rng, cfg)
            x = jax.random.normal(rng, (4, 8, cfg.d_model)) * 0.5
            ref, _ = moe_dense(p, x, cfg, ref_ctx)
            with mesh:
                ep, _ = jax.jit(lambda p, x: moe_ep(p, x, cfg, ctx))(p, x)
            err = float(jnp.max(jnp.abs(ep - ref)))
            assert err < 1e-5, ("prefill", n_exp, err)
            xd = jax.random.normal(rng, (8, 1, cfg.d_model)) * 0.5
            refd, _ = moe_dense(p, xd, cfg, ref_ctx)
            with mesh:
                epd, _ = jax.jit(lambda p, x: moe_ep(p, x, cfg, ctx))(p, xd)
            err = float(jnp.max(jnp.abs(epd - refd)))
            assert err < 1e-5, ("decode", n_exp, err)
        print("FUSED_OK")
        """
    )
    assert "FUSED_OK" in out


def test_ep_fused_ffn_single_kernel_8dev():
    """The fully-fused single-kernel FFN (gmm_fused_ffn) must actually
    engage inside ep_moe_shardmap's shard_map body over a real 4-way
    all_to_all — and match both the two-kernel gather+scatter pair (VMEM
    gate forced shut) and the dense oracle, prefill and decode."""
    out = _run(
        """
        import jax, jax.numpy as jnp, dataclasses
        from repro.configs import get_config, smoke
        from repro.kernels import registry
        from repro.models.moe import moe_dense, moe_ep, moe_init
        from repro.parallel.ctx import ParallelCtx
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((2, 4), ("data", "model"))
        ctx = ParallelCtx(mesh=mesh, capacity_factor=8.0, use_kernels=True)
        ref_ctx = ParallelCtx(capacity_factor=8.0, use_kernels=False)
        cfg = dataclasses.replace(smoke(get_config("dbrx-132b")),
                                  n_experts=4, experts_per_token=2)
        rng = jax.random.PRNGKey(0)
        p = moe_init(rng, cfg)
        # Record whether the fused gate was consulted AND said yes.
        orig = registry.can_gmm_fused
        verdicts = []
        def spy(*a, **kw):
            v = orig(*a, **kw)
            verdicts.append(v)
            return v
        registry.can_gmm_fused = spy
        for shape in ((4, 8), (8, 1)):
            x = jax.random.normal(rng, (*shape, cfg.d_model)) * 0.5
            ref, _ = moe_dense(p, x, cfg, ref_ctx)
            verdicts.clear()
            with mesh:
                fused, _ = jax.jit(lambda p, x: moe_ep(p, x, cfg, ctx))(p, x)
            assert verdicts and all(verdicts), ("fused gate never engaged", shape)
            err = float(jnp.max(jnp.abs(fused - ref)))
            assert err < 1e-5, ("fused vs dense", shape, err)
            # Force the VMEM gate shut: the registry must fall back to the
            # two-kernel pair with identical results over the same exchange.
            registry.can_gmm_fused = lambda *a, **kw: False
            with mesh:
                pair, _ = jax.jit(lambda p, x: moe_ep(p, x, cfg, ctx))(p, x)
            registry.can_gmm_fused = spy
            err = float(jnp.max(jnp.abs(fused - pair)))
            assert err < 1e-6, ("fused vs pair", shape, err)
        print("FUSED_FFN_OK")
        """
    )
    assert "FUSED_FFN_OK" in out


def test_ep_compact_combine_skewed_and_validation_8dev():
    """Combine-leg coverage the dense-oracle cells can't give: (1) fused
    vs padded ep_moe_shardmap parity under *heavily skewed* hand-crafted
    routing (capacity drops on both paths must agree bit-for-bit over a
    real 4-way all_to_all); (2) the prefill token-split validation raises
    a clear error instead of floor-truncating bucket_capacity."""
    out = _run(
        """
        import jax, jax.numpy as jnp
        from repro.parallel.collectives import ep_moe_shardmap, uniform_placement
        from repro.parallel.ctx import ParallelCtx
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((2, 4), ("data", "model"))
        ep = 4
        e, d, f, k = 8, 8, 16, 2
        b, s = 4, 8
        rng = jax.random.PRNGKey(0)
        ks = jax.random.split(rng, 6)
        x = jax.random.normal(ks[0], (b, s, d)) * 0.5
        # ~75% of copies hammer expert 0 (one device's slots); rest spread.
        hot = jax.random.bernoulli(ks[1], 0.75, (b, s, k))
        ids = jnp.where(hot, 0, jax.random.randint(ks[2], (b, s, k), 0, e))
        w = jax.random.uniform(ks[3], (b, s, k))
        w = w / w.sum(-1, keepdims=True)
        slot_weights = {
            "w_gate": jax.random.normal(ks[4], (e, d, f)) * 0.1,
            "w_up": jax.random.normal(ks[5], (e, d, f)) * 0.1,
            "w_down": jax.random.normal(ks[0], (e, f, d)) * 0.1,
        }
        slot_of, n_rep = uniform_placement(e, e)
        outs = {}
        for name, uk in (("padded", False), ("fused", True)):
            ctx = ParallelCtx(mesh=mesh, use_kernels=uk)
            with mesh:
                outs[name] = jax.jit(lambda x_, i_, w_: ep_moe_shardmap(
                    x_, i_, w_, slot_weights, slot_of, n_rep, ctx,
                    capacity_factor=1.0,  # tight capacity -> real drops
                    slots_per_device=e // ep))(x, ids, w)
        err = float(jnp.max(jnp.abs(outs["fused"] - outs["padded"])))
        assert err < 1e-5, ("skewed fused-vs-padded", err)
        # decode-shape ownership psum under the same skew
        xd = jax.random.normal(ks[0], (8, 1, d)) * 0.5
        idd = jnp.where(jax.random.bernoulli(ks[1], 0.75, (8, 1, k)), 0,
                        jax.random.randint(ks[2], (8, 1, k), 0, e))
        wd_ = jax.random.uniform(ks[3], (8, 1, k))
        for name, uk in (("padded", False), ("fused", True)):
            ctx = ParallelCtx(mesh=mesh, use_kernels=uk)
            with mesh:
                outs[name] = jax.jit(lambda x_, i_, w_: ep_moe_shardmap(
                    x_, i_, w_, slot_weights, slot_of, n_rep, ctx,
                    capacity_factor=1.0, slots_per_device=e // ep,
                    decode=True))(xd, idd, wd_)
        err = float(jnp.max(jnp.abs(outs["fused"] - outs["padded"])))
        assert err < 1e-5, ("skewed decode fused-vs-padded", err)
        # token-split validation: seq not divisible by ep must raise the
        # named error, not die inside shard_map / silently floor-truncate
        ctx = ParallelCtx(mesh=mesh, use_kernels=True)
        xbad = jax.random.normal(rng, (4, 7, d))
        try:
            with mesh:
                ep_moe_shardmap(xbad, ids[:, :7], w[:, :7], slot_weights,
                                slot_of, n_rep, ctx, 1.0, e // ep)
        except ValueError as exc:
            assert "seq=7 does not divide ep=4" in str(exc), exc
        else:
            raise AssertionError("non-divisible seq did not raise")
        print("SKEWED_OK")
        """
    )
    assert "SKEWED_OK" in out


def test_ep_chunked_dispatch_parity_8dev():
    """The pipelined chunked dispatch over a REAL 4-way all_to_all:
    ep_chunks=2 splits each rank's exchange into per-chunk buffers and
    interleaves the legs with the per-chunk fused FFN — and must stay
    bit-identical to the single-shot path under heavy skew at tight
    capacity (real drops), on prefill and decode shapes, with kernels on
    and off (the fallback path ignores the knob but must still accept
    it)."""
    out = _run(
        """
        import numpy as np
        import jax, jax.numpy as jnp
        from repro.parallel.collectives import ep_moe_shardmap, uniform_placement
        from repro.parallel.ctx import ParallelCtx
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((2, 4), ("data", "model"))
        ep = 4
        e, d, f, k = 8, 8, 16, 2    # spd = 2 -> ep_chunks in {1, 2}
        rng = jax.random.PRNGKey(0)
        ks = jax.random.split(rng, 6)
        slot_weights = {
            "w_gate": jax.random.normal(ks[4], (e, d, f)) * 0.1,
            "w_up": jax.random.normal(ks[5], (e, d, f)) * 0.1,
            "w_down": jax.random.normal(ks[0], (e, f, d)) * 0.1,
        }
        slot_of, n_rep = uniform_placement(e, e)
        for (b, s), decode in (((4, 8), False), ((8, 1), True)):
            x = jax.random.normal(ks[0], (b, s, d)) * 0.5
            hot = jax.random.bernoulli(ks[1], 0.75, (b, s, k))
            ids = jnp.where(hot, 0, jax.random.randint(ks[2], (b, s, k), 0, e))
            w = jax.random.uniform(ks[3], (b, s, k))
            w = w / w.sum(-1, keepdims=True)
            for uk in (True, False):
                base = None
                for K in (1, 2):
                    ctx = ParallelCtx(mesh=mesh, use_kernels=uk, ep_chunks=K)
                    with mesh:
                        out = jax.jit(lambda x_, i_, w_: ep_moe_shardmap(
                            x_, i_, w_, slot_weights, slot_of, n_rep, ctx,
                            capacity_factor=1.0, slots_per_device=e // ep,
                            decode=decode))(x, ids, w)
                    out = np.asarray(out)
                    assert np.all(np.isfinite(out))
                    if base is None:
                        base = out
                    else:
                        np.testing.assert_array_equal(
                            out, base,
                            err_msg=f"decode={decode} uk={uk} K={K}")
        # non-dividing chunk count: named error before any collective runs
        ctx = ParallelCtx(mesh=mesh, use_kernels=True, ep_chunks=3)
        try:
            with mesh:
                x = jax.random.normal(rng, (4, 8, d))
                ids = jax.random.randint(rng, (4, 8, k), 0, e)
                w = jnp.ones((4, 8, k)) / k
                ep_moe_shardmap(x, ids, w, slot_weights, slot_of, n_rep,
                                ctx, 1.0, e // ep)
        except ValueError as exc:
            assert "ep_chunks" in str(exc), exc
        else:
            raise AssertionError("non-dividing ep_chunks did not raise")
        print("CHUNKED_OK")
        """
    )
    assert "CHUNKED_OK" in out


def test_gqa_kv_replicated_flash_attention_8dev():
    """Mixtral-style GQA on a wide TP axis (n_kv_heads=2 < tp=4,
    tp % nkv == 0): flash attention must take the kv-head-replicated
    shard_map variant instead of silently falling back to einsum, and
    match the einsum fallback. nkv=3 (tp % nkv != 0) must stay on the
    fallback."""
    out = _run(
        """
        import jax, jax.numpy as jnp, dataclasses
        from repro.configs import get_config, smoke
        from repro.models import attention as A
        from repro.parallel.ctx import ParallelCtx
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((2, 4), ("data", "model"))
        cfg = dataclasses.replace(smoke(get_config("llama3.2-1b")),
                                  n_heads=8, n_kv_heads=2)
        p = A.attn_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model)) * 0.3
        ctx_on = ParallelCtx(mesh=mesh, use_kernels=True)
        ctx_off = ParallelCtx(mesh=mesh, use_kernels=False)
        q = jnp.zeros((4, 16, 8, cfg.head_dim_))
        kk = jnp.zeros((4, 16, 2, cfg.head_dim_))
        assert A._flash_attend_eligible(q, kk, ctx_on), "kv-rep not eligible"
        with mesh:
            on = jax.jit(lambda p, x: A.attention(p, x, cfg, ctx_on))(p, x)
            off = jax.jit(lambda p, x: A.attention(p, x, cfg, ctx_off))(p, x)
        err = float(jnp.max(jnp.abs(on - off)))
        assert err < 2e-5, ("kv-rep parity", err)
        # tp not a multiple of nkv: ineligible, einsum fallback unchanged
        q3 = jnp.zeros((4, 16, 12, cfg.head_dim_))
        k3 = jnp.zeros((4, 16, 3, cfg.head_dim_))
        assert not A._flash_attend_eligible(q3, k3, ctx_on)
        print("KVREP_OK")
        """
    )
    assert "KVREP_OK" in out


def test_gqa_kv_replicated_flash_decode_8dev():
    """Dense-cache flash decode under wide TP with non-dividing kv heads
    (nkv=2 < tp=4, tp % nkv == 0): must take the kv-head-replicated
    shard_map variant (prefill already had one) and match the einsum
    fallback across a multi-step decode. nkv=3 stays ineligible."""
    out = _run(
        """
        import jax, jax.numpy as jnp, dataclasses
        from repro.configs import get_config, smoke
        from repro.models import attention as A
        from repro.parallel.ctx import ParallelCtx
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((2, 4), ("data", "model"))
        cfg = dataclasses.replace(smoke(get_config("llama3.2-1b")),
                                  n_heads=8, n_kv_heads=2)
        ctx_on = ParallelCtx(mesh=mesh, use_kernels=True, seq_parallel_kv=False)
        ctx_off = ParallelCtx(mesh=mesh, use_kernels=False, seq_parallel_kv=False)
        p = A.attn_init(jax.random.PRNGKey(0), cfg)
        b, max_seq = 4, 32
        q = jnp.zeros((b, 1, 8, cfg.head_dim_))
        kc = jnp.zeros((b, max_seq, 2, cfg.head_dim_))
        assert A._flash_decode_eligible(q, kc, ctx_on), "kv-rep decode not eligible"
        cache_on = A.cache_init(cfg, b, max_seq)
        cache_off = A.cache_init(cfg, b, max_seq)
        x0 = jax.random.normal(jax.random.PRNGKey(1), (b, 1, cfg.d_model)) * 0.3
        pos = jnp.asarray(0, jnp.int32)
        with mesh:
            for step in range(6):
                x = x0 * (step % 3 + 1) / 3
                o_on, cache_on = jax.jit(lambda p,x,c,t: A.decode_attention(
                    p, x, c, t, cfg, ctx_on))(p, x, cache_on, pos)
                o_off, cache_off = jax.jit(lambda p,x,c,t: A.decode_attention(
                    p, x, c, t, cfg, ctx_off))(p, x, cache_off, pos)
                err = float(jnp.max(jnp.abs(o_on - o_off)))
                assert err < 2e-5, ("kv-rep decode parity", step, err)
                pos = pos + 1
        # tp not a multiple of nkv: ineligible, fallback unchanged
        q3 = jnp.zeros((b, 1, 12, cfg.head_dim_))
        k3 = jnp.zeros((b, max_seq, 3, cfg.head_dim_))
        assert not A._flash_decode_eligible(q3, k3, ctx_on)
        print("KVREP_DECODE_OK")
        """
    )
    assert "KVREP_DECODE_OK" in out


def test_ep_gradient_parity_8dev():
    """EP dispatch must be differentiable and match dense gradients — on
    both the padded fallback (kernels off) and the fused compact path
    (kernels on: gather prologue + scatter epilogue custom_vjp, return
    all_to_all adjoint, combine_from_rows gather vjp across real rank
    segments — a 1x1 mesh can't exercise any of that)."""
    out = _run(
        """
        import jax, jax.numpy as jnp, dataclasses
        from repro.configs import get_config, smoke
        from repro.models.moe import moe_dense, moe_ep, moe_init
        from repro.parallel.ctx import ParallelCtx
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((2, 4), ("data", "model"))
        cfg = dataclasses.replace(smoke(get_config("dbrx-132b")),
                                  n_experts=4, experts_per_token=2)
        rng = jax.random.PRNGKey(0)
        p = moe_init(rng, cfg)
        x = jax.random.normal(rng, (4, 8, cfg.d_model)) * 0.5
        gd = jax.grad(lambda p: moe_dense(
            p, x, cfg, ParallelCtx(capacity_factor=8.0))[0].sum())(p)
        for uk in (False, True):
            ctx = ParallelCtx(mesh=mesh, capacity_factor=8.0, use_kernels=uk)
            loss_e = lambda p: moe_ep(p, x, cfg, ctx)[0].sum()
            with mesh:
                ge = jax.jit(jax.grad(loss_e))(p)
            for k in ("w_gate", "w_up", "w_down", "router"):
                err = float(jnp.max(jnp.abs(gd[k] - ge[k])))
                assert err < 1e-4, (uk, k, err)
        print("GRAD_OK")
        """
    )
    assert "GRAD_OK" in out


def test_seq_parallel_decode_and_compressed_sync_8dev():
    out = _run(
        """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.parallel.collectives import (
            seq_parallel_decode_attend, seq_parallel_decode_kernel_eligible)
        from repro.models.attention import gqa_attend
        from repro.parallel.ctx import ParallelCtx
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((2, 4), ("data", "model"))
        ctx = ParallelCtx(mesh=mesh)
        q = jax.random.normal(jax.random.PRNGKey(1), (2, 1, 8, 16))
        k = jax.random.normal(jax.random.PRNGKey(2), (2, 16, 4, 16))
        v = jax.random.normal(jax.random.PRNGKey(3), (2, 16, 4, 16))
        mask = jnp.arange(16) <= 9
        ref = gqa_attend(q, k, v, mask[None, None, None, None, :])
        with mesh:
            out = jax.jit(lambda q,k,v,m: seq_parallel_decode_attend(q,k,v,m,ctx))(q,k,v,mask)
        assert float(jnp.max(jnp.abs(out - ref))) < 1e-5
        # kernelized merge: flash-decode partials + psum LSE merge must take
        # the kernel path (eligibility) and match the einsum reference.
        ctx_k = ParallelCtx(mesh=mesh, use_kernels=True)
        assert seq_parallel_decode_kernel_eligible(16, 8, 4, 16, ctx_k)
        assert not seq_parallel_decode_kernel_eligible(16, 8, 4, 16, ctx)
        with mesh:
            out_k = jax.jit(lambda q,k,v,m: seq_parallel_decode_attend(q,k,v,m,ctx_k))(q,k,v,mask)
        assert float(jnp.max(jnp.abs(out_k - ref))) < 1e-5, "kernelized merge parity"
        # compressed cross-pod sync: mean preserved within int8 error
        mesh2 = make_mesh_compat((2, 2, 2), ("pod", "data", "model"))
        from repro.parallel.grad_compress import compressed_pod_mean
        tree = {"w": jax.random.normal(jax.random.PRNGKey(5), (64, 33))}
        with mesh2:
            out2 = jax.jit(lambda t: compressed_pod_mean(t, mesh2))(tree)
        rel = float(jnp.max(jnp.abs(out2["w"] - tree["w"])) / jnp.max(jnp.abs(tree["w"])))
        assert rel < 0.03, rel
        print("SP_OK")
        """
    )
    assert "SP_OK" in out


def test_paged_decode_under_mesh_8dev():
    """Paged decode under a mesh (pool kv-heads on the model axis, pool
    replicated over batch): parity with the no-mesh dense cache, kernel
    path on (interpret). Also: seq_parallel_kv decode rides the kernelized
    merge inside full decode_attention."""
    out = _run(
        """
        import jax, jax.numpy as jnp, dataclasses
        from repro.configs import get_config, smoke
        from repro.models import attention as A
        from repro.parallel.ctx import ParallelCtx
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((2, 4), ("data", "model"))
        cfg = dataclasses.replace(smoke(get_config("llama3.2-1b")),
                                  n_heads=8, n_kv_heads=4)
        ctx0 = ParallelCtx()
        ctx_p = ParallelCtx(mesh=mesh, use_kernels=True, seq_parallel_kv=False)
        ctx_sp = ParallelCtx(mesh=mesh, use_kernels=True)  # seq_parallel_kv
        p = A.attn_init(jax.random.PRNGKey(0), cfg)
        b, max_seq = 4, 32
        dense = A.cache_init(cfg, b, max_seq)
        dense_sp = A.cache_init(cfg, b, max_seq)
        paged = A.paged_cache_init(cfg, b, max_seq, page_size=8)
        x0 = jax.random.normal(jax.random.PRNGKey(1), (b, 1, cfg.d_model)) * 0.3
        pos = jnp.asarray(0, jnp.int32)
        with mesh:
            for step in range(10):
                x = x0 * (step % 4 + 1) / 4
                o_ref, dense = A.decode_attention(p, x, dense, pos, cfg, ctx0)
                o_p, paged = jax.jit(lambda p,x,c,t: A.decode_attention(
                    p, x, c, t, cfg, ctx_p))(p, x, paged, pos)
                o_sp, dense_sp = jax.jit(lambda p,x,c,t: A.decode_attention(
                    p, x, c, t, cfg, ctx_sp))(p, x, dense_sp, pos)
                err_p = float(jnp.max(jnp.abs(o_ref - o_p)))
                err_sp = float(jnp.max(jnp.abs(o_ref - o_sp)))
                assert err_p < 2e-5, ("paged", step, err_p)
                assert err_sp < 2e-5, ("seq_parallel", step, err_sp)
                pos = pos + 1
        print("PAGED_MESH_OK")
        """
    )
    assert "PAGED_MESH_OK" in out


def test_server_migration_preserves_outputs_8dev():
    """Expert migration is semantics-preserving: generation with shadow
    replicas equals generation without any balancing."""
    out = _run(
        """
        import jax, jax.numpy as jnp, dataclasses, numpy as np
        from repro.configs import get_config, smoke
        from repro.models import transformer as T
        from repro.runtime.serve import Server, ServeConfig
        from repro.parallel.ctx import ParallelCtx
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((2, 4), ("data", "model"))
        ctx = ParallelCtx(mesh=mesh, capacity_factor=8.0)
        cfg = dataclasses.replace(smoke(get_config("dbrx-132b")),
                                  n_experts=8, experts_per_token=2)
        params = T.init_params(jax.random.PRNGKey(1), cfg)
        prompt = jnp.ones((4, 8), jnp.int32)
        with mesh:
            s_off = Server(cfg, ctx, jax.tree.map(jnp.copy, params),
                           ServeConfig(max_seq=64, batch=4, slots_per_device=3,
                                       alpha=1e9))  # never triggers
            out_off = s_off.generate(prompt, 10)
            s_on = Server(cfg, ctx, jax.tree.map(jnp.copy, params),
                          ServeConfig(max_seq=64, batch=4, slots_per_device=3,
                                      alpha=0.1))   # triggers eagerly
            out_on = s_on.generate(prompt, 10)
        assert s_on.migrations > 0, "balancer should have migrated"
        assert np.array_equal(np.asarray(out_off), np.asarray(out_on)), \
            "migration changed outputs"
        print("MIG_OK", s_on.migrations)
        """
    )
    assert "MIG_OK" in out


def test_dryrun_machinery_small_mesh():
    """lower_cell + collective parser on a small forced mesh (2x4)."""
    out = _run(
        """
        import jax, dataclasses
        import repro.launch.dryrun as D
        from repro.configs import get_config
        from repro.configs.base import ShapeConfig
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((2, 4), ("data", "model"))
        cfg = dataclasses.replace(get_config("llama3.2-1b"), n_layers=2)
        shape = ShapeConfig("t", 256, 8, "train")
        with mesh:
            lowered, compiled, tl, tc = D.lower_cell(cfg, shape, mesh, False)
            coll = D.collective_bytes(compiled.as_text())
            ma = compiled.memory_analysis()
        assert coll["total"] > 0, coll
        assert ma.temp_size_in_bytes > 0
        print("DRYRUN_OK", coll["total"])
        """
    )
    assert "DRYRUN_OK" in out


def test_er_mesh_device_permutation():
    out = _run(
        """
        import jax, numpy as np
        from repro.launch.mesh import make_er_mesh
        from repro.core.er_mapping import er_mapping
        from repro.core.topology import MeshTopology
        mesh = make_er_mesh()
        assert mesh.shape == {"data": 16, "model": 16}
        ids = np.array([[d.id for d in row] for row in mesh.devices])
        m = er_mapping(MeshTopology(16, 16), 16, 16)
        assert np.array_equal(ids, m.device_order())
        # logical row g (= TP group) lands one member per physical tile
        print("ERMESH_OK")
        """,
        devices=512,
    )
    assert "ERMESH_OK" in out
