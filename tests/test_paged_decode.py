"""Paged-KV flash decode: kernel parity, partials merge, model-level cache
parity (full + sliding-window ring), serving page pool, overflow guard.

All Pallas paths run with interpret=True on CPU (the kernels target TPU).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke
from repro.kernels import registry
from repro.kernels.flash_decode.ops import (
    flash_decode_paged_op,
    flash_decode_partials_op,
)
from repro.kernels.flash_decode.paged import flash_decode_paged
from repro.kernels.flash_decode.ref import (
    decode_ref,
    gather_pages,
    paged_decode_ref,
)
from repro.models import attention as A
from repro.models import transformer as T
from repro.parallel.ctx import ParallelCtx
from repro.runtime.serve import PagePool, Server, ServeConfig

RNG = jax.random.PRNGKey(0)
TOL = dict(rtol=2e-5, atol=2e-5)


def _pool(key, b, nb, bs, nkv, hd, dtype=jnp.float32):
    """Identity-table pool covering (b, nb*bs) logical slots."""
    k = jax.random.normal(key, (b * nb, bs, nkv, hd), dtype)
    tables = jnp.arange(b * nb, dtype=jnp.int32).reshape(b, nb)
    return k, tables


# ---------------------------------------------------------------------------
# kernel-level parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,nb,bs,h,kv,hd,lengths",
    [
        (3, 4, 64, 8, 2, 32, [200, 64, 1]),       # partial / boundary / single
        (2, 2, 128, 4, 4, 64, [256, 256]),        # every block full
        (3, 4, 16, 16, 8, 16, [15, 16, 17]),      # single-block edges
        (1, 8, 32, 4, 2, 32, [129, 0, 0][:1]),    # long, one past a boundary
    ],
)
def test_paged_kernel_parity(b, nb, bs, h, kv, hd, lengths, dtype):
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (b, h, hd), dtype)
    pool_k, tables = _pool(ks[1], b, nb, bs, kv, hd, dtype)
    pool_v, _ = _pool(ks[2], b, nb, bs, kv, hd, dtype)
    ln = jnp.asarray(lengths, jnp.int32)
    out = flash_decode_paged_op(q, pool_k, pool_v, tables, ln)
    ref = paged_decode_ref(
        q.astype(jnp.float32),
        pool_k.astype(jnp.float32),
        pool_v.astype(jnp.float32),
        tables,
        ln,
    )
    tol = dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else TOL
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref), **tol)


def test_paged_kernel_scrambled_table():
    """Physical page order must not matter — only the block table does."""
    b, nb, bs, h, kv, hd = 2, 4, 32, 4, 2, 16
    ks = jax.random.split(RNG, 4)
    q = jax.random.normal(ks[0], (b, h, hd))
    pool_k, tables = _pool(ks[1], b, nb, bs, kv, hd)
    pool_v, _ = _pool(ks[2], b, nb, bs, kv, hd)
    ln = jnp.asarray([100, 40], jnp.int32)
    ref = paged_decode_ref(q, pool_k, pool_v, tables, ln)
    perm = jax.random.permutation(ks[3], b * nb)
    pk = jnp.zeros_like(pool_k).at[perm].set(pool_k)
    pv = jnp.zeros_like(pool_v).at[perm].set(pool_v)
    t2 = perm[tables.reshape(-1)].reshape(b, nb).astype(jnp.int32)
    out = flash_decode_paged_op(q, pk, pv, t2, ln)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


def test_paged_kernel_skips_dead_blocks_bytes():
    """The dead-block clamp revisits the last live page, so distinct pages
    touched == live blocks — garbage in dead pages must not leak through."""
    b, nb, bs, h, kv, hd = 2, 8, 16, 4, 2, 16
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (b, h, hd))
    pool_k, tables = _pool(ks[1], b, nb, bs, kv, hd)
    pool_v, _ = _pool(ks[2], b, nb, bs, kv, hd)
    ln = jnp.asarray([20, 40], jnp.int32)
    ref = paged_decode_ref(q, pool_k, pool_v, tables, ln)
    # poison every dead page (block index >= ceil(len/bs))
    dead = np.ones((b * nb,), bool)
    for bi, l in enumerate([20, 40]):
        live_blocks = -(-l // bs)
        dead[bi * nb : bi * nb + live_blocks] = False
    poison = jnp.where(jnp.asarray(dead)[:, None, None, None], jnp.nan, 0.0)
    out = flash_decode_paged_op(q, pool_k + poison, pool_v + poison, tables, ln)
    assert np.isfinite(np.asarray(out)).all(), "dead-page NaNs leaked"
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


# ---------------------------------------------------------------------------
# partials + LSE merge
# ---------------------------------------------------------------------------

def _merge(parts):
    ms = jnp.stack([m for _, m, _ in parts])
    mm = jnp.max(ms, axis=0)
    num = sum(a * jnp.exp(m - mm)[..., None] for a, m, _ in parts)
    den = sum(l * jnp.exp(m - mm) for _, m, l in parts)
    return num / jnp.maximum(den, 1e-30)[..., None]


@pytest.mark.parametrize("n_shards", [2, 4])
def test_dense_partials_merge(n_shards):
    """flash_decode partials over disjoint KV slices merge to the full
    masked softmax — the sequence-parallel decode contract."""
    b, t, h, kv, hd = 2, 256, 8, 2, 32
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (b, h, hd))
    k = jax.random.normal(ks[1], (b, t, kv, hd))
    v = jax.random.normal(ks[2], (b, t, kv, hd))
    valid = (jnp.arange(t)[None, :] < 70).astype(jnp.int32).repeat(b, 0)
    ref = decode_ref(q, k, v, valid)
    sl = t // n_shards
    parts = [
        flash_decode_partials_op(
            q, k[:, i * sl : (i + 1) * sl], v[:, i * sl : (i + 1) * sl],
            valid[:, i * sl : (i + 1) * sl],
        )
        for i in range(n_shards)
    ]
    np.testing.assert_allclose(np.asarray(_merge(parts)), np.asarray(ref), **TOL)
    # shards past the fill are fully masked and must contribute nothing
    acc, m, l = parts[-1]
    assert float(jnp.max(m)) <= -1e29


def test_paged_partials_match_dense_partials():
    b, nb, bs, h, kv, hd = 2, 4, 32, 4, 2, 16
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (b, h, hd))
    pool_k, tables = _pool(ks[1], b, nb, bs, kv, hd)
    pool_v, _ = _pool(ks[2], b, nb, bs, kv, hd)
    ln = jnp.asarray([100, 40], jnp.int32)
    k = gather_pages(pool_k, tables)
    v = gather_pages(pool_v, tables)
    valid = (jnp.arange(nb * bs)[None, :] < ln[:, None]).astype(jnp.int32)
    a1, m1, l1 = flash_decode_partials_op(q, k, v, valid)
    a2, m2, l2 = jax.jit(
        lambda *args: flash_decode_paged(
            *args, return_partials=True, interpret=True
        )
    )(q, pool_k, pool_v, tables, ln)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), **TOL)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# model-level cache parity
# ---------------------------------------------------------------------------

def _cfg(**kw):
    return dataclasses.replace(smoke(get_config("llama3.2-1b")), **kw)


@pytest.mark.parametrize("use_kernels", [False, True])
def test_decode_attention_paged_vs_dense(use_kernels):
    cfg = _cfg()
    ctx = ParallelCtx(use_kernels=use_kernels)
    p = A.attn_init(RNG, cfg)
    b, max_seq = 3, 48
    dense = A.cache_init(cfg, b, max_seq)
    paged = A.paged_cache_init(cfg, b, max_seq, page_size=16)
    x0 = jax.random.normal(jax.random.PRNGKey(1), (b, 1, cfg.d_model)) * 0.3
    pos = jnp.asarray(0, jnp.int32)
    for step in range(18):
        x = x0 * (step % 5 + 1) / 5
        od, dense = A.decode_attention(p, x, dense, pos, cfg, ParallelCtx())
        op, paged = A.decode_attention(p, x, paged, pos, cfg, ctx)
        np.testing.assert_allclose(np.asarray(od), np.asarray(op), **TOL)
        pos = pos + 1
    # lengths advanced per request
    assert np.all(np.asarray(paged["lengths"]) == 18)


def test_decode_attention_ring_wraparound():
    """Sliding-window ring as a small block table: parity with the dense
    pos % L ring across several wraps (window not a page multiple — the
    page shrinks to a divisor)."""
    cfg = _cfg(sliding_window=12)
    bs, nb = A.paged_layout(cfg, 64, page_size=8)
    assert bs * nb == 12 and bs < 8, (bs, nb)  # shrunk to a divisor of 12
    ctx = ParallelCtx()
    p = A.attn_init(RNG, cfg)
    b = 2
    dense = A.cache_init(cfg, b, 64)
    paged = A.paged_cache_init(cfg, b, 64, page_size=8)
    x0 = jax.random.normal(jax.random.PRNGKey(1), (b, 1, cfg.d_model)) * 0.3
    pos = jnp.asarray(0, jnp.int32)
    for step in range(30):   # wraps the 12-slot ring twice
        x = x0 * (step % 7 + 1) / 7
        od, dense = A.decode_attention(p, x, dense, pos, cfg, ctx)
        op, paged = A.decode_attention(p, x, paged, pos, cfg, ctx)
        np.testing.assert_allclose(np.asarray(od), np.asarray(op), **TOL)
        pos = pos + 1


def test_prefill_paged_then_decode_parity():
    cfg = _cfg()
    ctx = ParallelCtx()
    params = T.init_params(RNG, cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 9), 0, cfg.vocab_size)
    ld, cd = T.prefill(params, tokens, cfg, ctx, max_seq=32)
    lp, cp = T.prefill(params, tokens, cfg, ctx, max_seq=32, paged=True, page_size=8)
    np.testing.assert_allclose(np.asarray(ld), np.asarray(lp), **TOL)
    tok = jnp.argmax(ld[:, -1:], -1).astype(jnp.int32)
    for _ in range(6):
        ld, cd, _ = T.decode_step(params, tok, cd, cfg, ctx)
        lp, cp, _ = T.decode_step(params, tok, cp, cfg, ctx)
        np.testing.assert_allclose(np.asarray(ld), np.asarray(lp), **TOL)
        tok = jnp.argmax(ld[:, -1:], -1).astype(jnp.int32)


def test_paged_ragged_lengths_match_individual_requests():
    """Batched requests of different context lengths decode together in one
    paged cache; each must match its own single-request dense decode."""
    cfg = _cfg()
    ctx = ParallelCtx()
    params = T.init_params(RNG, cfg)
    lens = [3, 9, 6]
    b, s = len(lens), max(lens)
    tokens = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, cfg.vocab_size)
    srv = Server(
        cfg, ctx, params,
        ServeConfig(max_seq=32, batch=b, paged=True, page_size=8, pool_pages=12),
    )
    logits, cache = srv.prefill(tokens, lengths=np.asarray(lens))
    tok0 = jnp.zeros((b, 1), jnp.int32) + 7
    steps = []
    tok = tok0
    for _ in range(5):
        logits, cache = srv.decode(tok, cache)
        steps.append(logits)
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    for i, ln in enumerate(lens):
        # single-request dense reference on the unpadded prompt
        _, cref = T.prefill(params, tokens[i : i + 1, :ln], cfg, ctx, max_seq=32)
        tok = tok0[i : i + 1]
        for t in range(5):
            lref, cref, _ = T.decode_step(params, tok, cref, cfg, ctx)
            np.testing.assert_allclose(
                np.asarray(lref[0]), np.asarray(steps[t][i]), rtol=1e-4, atol=1e-4
            )
            tok = jnp.argmax(lref[:, -1:], -1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# layout rules / eligibility gates
# ---------------------------------------------------------------------------

def test_paged_layout_rules():
    cfg = _cfg()
    assert A.paged_layout(cfg, 1024, 128) == (128, 8)
    assert A.paged_layout(cfg, 100, 128) == (100, 1)        # one short block
    assert A.paged_layout(cfg, 130, 128) == (128, 2)        # partial tail ok
    cfgw = _cfg(sliding_window=12)
    bs, nb = A.paged_layout(cfgw, 1024, 8)                  # ring: divisor only
    assert bs * nb == 12 and 12 % bs == 0
    cfgw2 = _cfg(sliding_window=256)
    assert A.paged_layout(cfgw2, 1024, 128) == (128, 2)     # divides: unchanged


def test_can_flash_decode_paged_gates():
    assert registry.can_flash_decode_paged(128, 8, 2, 128, False)
    assert not registry.can_flash_decode_paged(64, 8, 2, 128, False)   # page
    assert not registry.can_flash_decode_paged(128, 8, 2, 64, False)   # hd
    assert not registry.can_flash_decode_paged(128, 8, 3, 128, False)  # gqa
    assert registry.can_flash_decode_paged(5, 8, 2, 12, True)          # interpret


# ---------------------------------------------------------------------------
# dense-cache overflow (regression: silent last-slot clobber)
# ---------------------------------------------------------------------------

def test_dense_overflow_freezes_and_server_raises():
    cfg = _cfg()
    ctx = ParallelCtx()
    p = A.attn_init(RNG, cfg)
    b, max_seq = 2, 8
    cache = A.cache_init(cfg, b, max_seq)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, 1, cfg.d_model)) * 0.3
    pos = jnp.asarray(0, jnp.int32)
    for _ in range(max_seq):
        _, cache = A.decode_attention(p, x, cache, pos, cfg, ctx)
        pos = pos + 1
    k_full = np.asarray(cache["k"]).copy()
    out_over, cache = A.decode_attention(p, x, cache, pos, cfg, ctx)
    # the cache froze: no silent clobber of the last slot
    assert np.array_equal(k_full, np.asarray(cache["k"]))
    # and the output is well-defined "frozen context" attention, not garbage
    assert np.isfinite(np.asarray(out_over)).all()

    params = T.init_params(RNG, cfg)
    srv = Server(cfg, ctx, params, ServeConfig(max_seq=6, batch=1))
    logits, c = srv.prefill(jnp.ones((1, 4), jnp.int32))
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    logits, c = srv.decode(tok, c)   # pos 4 -> ok
    logits, c = srv.decode(tok, c)   # pos 5 -> ok
    with pytest.raises(RuntimeError, match="max_seq"):
        srv.decode(tok, c)           # pos 6 == max_seq -> refuse


# ---------------------------------------------------------------------------
# page pool allocator
# ---------------------------------------------------------------------------

def test_page_pool_alloc_free():
    pool = PagePool(4)
    a = pool.alloc(3)
    assert len(set(a)) == 3 and pool.n_free == 1
    with pytest.raises(RuntimeError, match="exhausted"):
        pool.alloc(2)
    pool.free(a[:2])
    assert pool.n_free == 3
    with pytest.raises(ValueError, match="double free"):
        pool.free(a[:1] + a[:1])


def test_server_pool_shared_across_ragged_batch():
    """An oversubscribed pool (fewer pages than batch * NB) admits a ragged
    batch, grows lazily at block boundaries, and frees on release."""
    cfg = _cfg()
    ctx = ParallelCtx()
    params = T.init_params(RNG, cfg)
    # max_seq 32 / page 8 -> 4 blocks/request; 3 requests would need 12
    # pages fully backed — give the pool just 7.
    srv = Server(
        cfg, ctx, params,
        ServeConfig(max_seq=32, batch=3, paged=True, page_size=8, pool_pages=7),
    )
    tokens = jax.random.randint(RNG, (3, 8), 0, cfg.vocab_size)
    lens = np.asarray([2, 8, 5])
    logits, cache = srv.prefill(tokens, lengths=lens)
    assert srv.page_pool.n_free == 7 - 3          # one page each
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    for _ in range(8):                            # crosses a block boundary
        logits, cache = srv.decode(tok, cache)
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    assert srv.page_pool.n_free < 4
    used_before = srv.page_pool.n_free
    cache = srv.release(1, cache)
    assert srv.page_pool.n_free > used_before
    assert int(cache["layers"]["lengths"][0, 1]) == 0
    # released rows stay inert across further steps: length pinned at 0,
    # no pages re-allocated for them, live rows keep decoding
    free_after_release = srv.page_pool.n_free
    for _ in range(3):
        logits, cache = srv.decode(tok, cache)
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    assert int(cache["layers"]["lengths"][0, 1]) == 0
    assert srv.page_pool.n_free == free_after_release
    assert 1 not in srv._pages
    # a fresh batch reuses the freed pages
    srv.prefill(tokens, lengths=lens)
    assert srv.page_pool.n_free == 7 - 3


def test_server_decode_with_externally_primed_cache():
    """A cache primed via T.prefill directly (not Server.prefill) must keep
    decoding — no slot may be treated as released / pinned to length 0."""
    cfg = _cfg()
    ctx = ParallelCtx()
    params = T.init_params(RNG, cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 6), 0, cfg.vocab_size)
    logits, cache = T.prefill(params, tokens, cfg, ctx, max_seq=32, paged=True, page_size=16)
    srv = Server(
        cfg, ctx, params,
        ServeConfig(max_seq=32, batch=2, paged=True, page_size=16),
    )
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    for step in range(4):
        lref, cache_ref, _ = T.decode_step(params, tok, jax.tree.map(jnp.copy, cache), cfg, ctx)
        lsrv, cache = srv.decode(tok, cache)
        np.testing.assert_allclose(np.asarray(lref), np.asarray(lsrv), **TOL)
        assert np.all(np.asarray(cache["layers"]["lengths"][0]) == 6 + step + 1)
        tok = jnp.argmax(lsrv[:, -1:], -1).astype(jnp.int32)


def test_paged_ragged_ring_wrap_prefill():
    """Ragged right-padded prompts + a sliding-window ring that wraps during
    prefill: the per-request slot gather must keep each request's own tail
    (a global roll would fill short requests with pad-row K/V)."""
    cfg = _cfg(sliding_window=8)
    ctx = ParallelCtx()
    params = T.init_params(RNG, cfg)
    lens = [4, 16]          # request 1 wraps the 8-slot ring, request 0 not
    b, s = len(lens), max(lens)
    tokens = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, cfg.vocab_size)
    srv = Server(
        cfg, ctx, params,
        ServeConfig(max_seq=32, batch=b, paged=True, page_size=4, pool_pages=6),
    )
    logits, cache = srv.prefill(tokens, lengths=np.asarray(lens))
    tok0 = jnp.zeros((b, 1), jnp.int32) + 7
    tok = tok0
    steps = []
    for _ in range(4):
        logits, cache = srv.decode(tok, cache)
        steps.append(logits)
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    for i, ln in enumerate(lens):
        _, cref = T.prefill(params, tokens[i : i + 1, :ln], cfg, ctx, max_seq=32)
        tok = tok0[i : i + 1]
        for t in range(4):
            lref, cref, _ = T.decode_step(params, tok, cref, cfg, ctx)
            np.testing.assert_allclose(
                np.asarray(lref[0]), np.asarray(steps[t][i]), rtol=1e-4, atol=1e-4
            )
            tok = jnp.argmax(lref[:, -1:], -1).astype(jnp.int32)


def test_server_paged_with_frontend_embeds():
    """Prepended frontend-stub embeds count toward each request's live KV
    rows (lengths / page allocation / overflow mirror)."""
    cfg = smoke(get_config("internvl2-76b"))
    assert cfg.frontend_stub and cfg.block_pattern == "attn"
    ctx = ParallelCtx()
    params = T.init_params(RNG, cfg)
    b, s = 2, 5
    tokens = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, cfg.vocab_size)
    embeds = jax.random.normal(jax.random.PRNGKey(3), (b, cfg.frontend_tokens, cfg.d_model)) * 0.02
    out_d = Server(cfg, ctx, params, ServeConfig(max_seq=32, batch=b)).generate(
        tokens, 6, embeds=embeds
    )
    srv = Server(cfg, ctx, params, ServeConfig(max_seq=32, batch=b, paged=True, page_size=8))
    out_p = srv.generate(tokens, 6, embeds=embeds)
    assert np.array_equal(np.asarray(out_d), np.asarray(out_p))
    # lengths include the embed rows
    assert srv._written[0] == s + cfg.frontend_tokens + 6


def test_paged_overflow_guard_is_per_request():
    """Releasing a finished request restores serving headroom: the paged
    overflow guard keys on per-request occupancy, not the global step
    count, so a ragged batch keeps decoding after its longest request is
    done — and still refuses once a live request truly fills."""
    cfg = _cfg()
    ctx = ParallelCtx()
    params = T.init_params(RNG, cfg)
    srv = Server(
        cfg, ctx, params,
        ServeConfig(max_seq=16, batch=2, paged=True, page_size=8),
    )
    tokens = jax.random.randint(RNG, (2, 14), 0, cfg.vocab_size)
    lens = np.asarray([4, 14])
    logits, cache = srv.prefill(tokens, lengths=lens)
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    for _ in range(2):                       # request 1 reaches 16 = cap
        logits, cache = srv.decode(tok, cache)
    cache = srv.release(1, cache)            # finished: frees its capacity
    for _ in range(6):                       # request 0 keeps going (6..12)
        logits, cache = srv.decode(tok, cache)
    assert int(cache["layers"]["lengths"][0, 0]) == 12
    for _ in range(4):                       # ... until IT fills at 16
        logits, cache = srv.decode(tok, cache)
    with pytest.raises(RuntimeError, match="cache full"):
        srv.decode(tok, cache)


def test_release_without_cache_refreshes_tables_before_write():
    """release(slot) with no cache handle must still keep the freed pages
    safe: the next decode pushes the trash-row table before any write, so a
    page re-allocated to a live request is never scattered into."""
    cfg = _cfg()
    ctx = ParallelCtx()
    params = T.init_params(RNG, cfg)
    srv = Server(
        cfg, ctx, params,
        ServeConfig(max_seq=32, batch=2, paged=True, page_size=8, pool_pages=8),
    )
    tokens = jax.random.randint(RNG, (2, 8), 0, cfg.vocab_size)
    logits, cache = srv.prefill(tokens)
    srv.release(1)                      # no cache handle
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    logits, cache = srv.decode(tok, cache)
    # slot 1's table row on device is now all write-off pages
    trash = srv.trash_page
    assert np.all(np.asarray(cache["layers"]["tables"][0, 1]) == trash)
    assert int(cache["layers"]["lengths"][0, 1]) == 0
    # slot 0 keeps decoding normally
    assert int(cache["layers"]["lengths"][0, 0]) == 9


def test_server_paged_generate_matches_dense():
    cfg = _cfg()
    ctx = ParallelCtx()
    params = T.init_params(RNG, cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(3), (2, 6), 0, cfg.vocab_size)
    out_d = Server(cfg, ctx, params, ServeConfig(max_seq=32, batch=2)).generate(
        prompt, 8
    )
    out_p = Server(
        cfg, ctx, params, ServeConfig(max_seq=32, batch=2, paged=True, page_size=8)
    ).generate(prompt, 8)
    assert np.array_equal(np.asarray(out_d), np.asarray(out_p))
