import os
import sys

# src-layout import path (tests also run without installation).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# tests dir itself, for the optional-dependency shims (_hyp_fallback).
sys.path.insert(0, os.path.dirname(__file__))

# NOTE: deliberately no xla_force_host_platform_device_count here — smoke
# tests and benches must see the real single device. Multi-device scenarios
# run in subprocesses (tests/test_multidevice.py) with their own XLA_FLAGS.


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: slow multidevice/property/interpret-mode tests. The fast "
        "tier (scripts/check.sh) deselects them with -m 'not slow'; "
        "scripts/check.sh --all (and plain pytest) runs the full matrix.",
    )
