import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.topology import MeshTopology


@given(
    st.integers(2, 8), st.integers(2, 8),
    st.integers(0, 63), st.integers(0, 63),
)
@settings(max_examples=60, deadline=None)
def test_route_length_is_manhattan(rows, cols, a, b):
    topo = MeshTopology(rows, cols)
    ca = topo.coord(a % topo.n_devices)
    cb = topo.coord(b % topo.n_devices)
    route = topo.route(ca, cb)
    assert len(route) == topo.hops(ca, cb)
    # route is connected and ends at the destination
    if route:
        assert route[0][0] == topo.device_id(ca)
        assert route[-1][1] == topo.device_id(cb)
        for (u1, v1), (u2, v2) in zip(route, route[1:]):
            assert v1 == u2


def test_links_bidirectional_and_counted_once():
    topo = MeshTopology(3, 4)
    links = set(topo.links)
    assert len(links) == len(topo.links)
    for (u, v) in topo.links:
        assert (v, u) in links
    # 2D mesh: directed links = 2*(r*(c-1) + c*(r-1))
    assert topo.n_links == 2 * (3 * 3 + 4 * 2)


def test_link_loads_conservation():
    topo = MeshTopology(4, 4)
    traffic = {(0, 15): 10.0, (5, 6): 2.0}
    loads = topo.link_loads(traffic)
    # total link-bytes = sum(vol * hops)
    expected = 10.0 * topo.hops((0, 0), (3, 3)) + 2.0 * 1
    assert loads.sum() == pytest.approx(expected)


def test_multi_wafer_geometry():
    topo = MeshTopology(4, 4, n_wafers=2)
    assert topo.n_devices == 32
    assert topo.global_cols == 8
    cross = [l for l in topo.links if topo.is_cross_wafer(l)]
    assert len(cross) == 2 * 4  # 4 border rows, both directions
    assert topo.wafer_of((0, 5)) == 1
