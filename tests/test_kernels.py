"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles,
executed with interpret=True on CPU (the kernels target TPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention_op
from repro.kernels.flash_attention.ref import mha_ref
from repro.kernels.flash_decode.ops import flash_decode_op
from repro.kernels.flash_decode.ref import decode_ref
from repro.kernels.gmm.ops import expert_ffn, gmm_op
from repro.kernels.gmm.ref import expert_ffn_ref, gmm_ref

RNG = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-5, atol=2e-5
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "g,c,d,f",
    [(1, 8, 8, 8), (4, 64, 32, 48), (2, 128, 128, 256), (3, 96, 64, 160)],
)
def test_gmm_sweep(g, c, d, f, dtype):
    ks = jax.random.split(RNG, 2)
    x = jax.random.normal(ks[0], (g, c, d), dtype=dtype)
    w = jax.random.normal(ks[1], (g, d, f), dtype=dtype) * 0.1
    out = gmm_op(x, w)
    ref = gmm_ref(x.astype(jnp.float32), w.astype(jnp.float32))
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref), **_tol(dtype)
    )


@pytest.mark.parametrize("g,c,d,f", [(2, 32, 16, 24), (4, 128, 64, 128)])
def test_expert_ffn_fused(g, c, d, f):
    ks = jax.random.split(RNG, 4)
    x = jax.random.normal(ks[0], (g, c, d))
    wg = jax.random.normal(ks[1], (g, d, f)) * 0.1
    wu = jax.random.normal(ks[2], (g, d, f)) * 0.1
    wd = jax.random.normal(ks[3], (g, f, d)) * 0.1
    np.testing.assert_allclose(
        np.asarray(expert_ffn(x, wg, wu, wd)),
        np.asarray(expert_ffn_ref(x, wg, wu, wd)),
        rtol=1e-4,
        atol=1e-4,
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,t,h,kv,hd,causal,window",
    [
        (2, 64, 64, 4, 2, 32, True, 0),
        (1, 32, 64, 8, 8, 16, True, 0),     # rectangular (continuation)
        (2, 64, 64, 4, 4, 32, True, 16),    # sliding window
        (2, 32, 32, 4, 2, 32, False, 0),    # bidirectional (encoder)
        (1, 128, 128, 8, 2, 64, True, 0),   # deep GQA
    ],
)
def test_flash_attention_sweep(b, s, t, h, kv, hd, causal, window, dtype):
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd), dtype=dtype)
    k = jax.random.normal(ks[1], (b, t, kv, hd), dtype=dtype)
    v = jax.random.normal(ks[2], (b, t, kv, hd), dtype=dtype)
    out = flash_attention_op(q, k, v, causal=causal, window=window)
    ref = mha_ref(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        causal=causal, window=window,
    )
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref), **_tol(dtype)
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,t,h,kv,hd,fill",
    [(2, 256, 8, 2, 32, 200), (1, 1024, 4, 4, 64, 1024), (3, 64, 16, 8, 16, 30)],
)
def test_flash_decode_sweep(b, t, h, kv, hd, fill, dtype):
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (b, h, hd), dtype=dtype)
    k = jax.random.normal(ks[1], (b, t, kv, hd), dtype=dtype)
    v = jax.random.normal(ks[2], (b, t, kv, hd), dtype=dtype)
    valid = (jnp.arange(t)[None, :] < fill).astype(jnp.int32).repeat(b, 0)
    out = flash_decode_op(q, k, v, valid)
    ref = decode_ref(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32), valid
    )
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref), **_tol(dtype)
    )


def test_flash_matches_model_attention_semantics():
    """The kernel must agree with the model's own attention math (the ref
    used by the executable path), not just its own oracle."""
    from repro.models.attention import causal_mask, gqa_attend

    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (2, 64, 8, 32))
    k = jax.random.normal(ks[1], (2, 64, 4, 32))
    v = jax.random.normal(ks[2], (2, 64, 4, 32))
    out = flash_attention_op(q, k, v, causal=True)
    ref = gqa_attend(q, k, v, causal_mask(64))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)
