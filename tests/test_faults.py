"""Fault-injection harness + Server fault-tolerance paths.

FaultPlan determinism, named release errors, migration-cap no-op, the full
mark_dead evacuation (weights, routing table, decode-after-death), straggler
draining via report_step_time, and the virtual-EP local dispatch that makes
all of it runnable on one process.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke
from repro.models import moe as M
from repro.models import transformer as T
from repro.parallel.ctx import ParallelCtx
from repro.runtime.faults import (
    DEVICE_DEATH,
    NAN_LOGITS,
    POOL_PRESSURE,
    Fault,
    FaultPlan,
)
from repro.runtime.serve import Server, ServeConfig, SlotReleaseError
from repro.core.ni_balancer import topology_aware_balance

RNG = jax.random.PRNGKey(0)


def _moe_cfg(**kw):
    base = dataclasses.replace(
        smoke(get_config("dbrx-132b")), n_experts=4, experts_per_token=2
    )
    return dataclasses.replace(base, **kw)


def _dense_cfg(**kw):
    return dataclasses.replace(smoke(get_config("llama3.2-1b")), **kw)


def _server(cfg, params, **scfg):
    ctx = ParallelCtx(capacity_factor=8.0)
    return Server(cfg, ctx, jax.tree.map(jnp.copy, params), ServeConfig(**scfg))


# ---------------------------------------------------------------------------
# FaultPlan
# ---------------------------------------------------------------------------

def test_fault_plan_seeded_deterministic():
    a = FaultPlan.chaos(7, 20, n_devices=4, pressure_pages=5, nan_slots=(1,))
    b = FaultPlan.chaos(7, 20, n_devices=4, pressure_pages=5, nan_slots=(1,))
    assert repr(a) == repr(b) and len(a) == len(b) == 5
    c = FaultPlan.chaos(8, 20, n_devices=4, pressure_pages=5, nan_slots=(1,))
    assert repr(c) != repr(a)
    # per-step lookup covers exactly the plan
    assert sum(len(a.at(s)) for s in range(200)) == len(a)
    kinds = {f.kind for f in a}
    assert DEVICE_DEATH in kinds and POOL_PRESSURE in kinds and NAN_LOGITS in kinds


def test_fault_kind_validated():
    with pytest.raises(ValueError, match="unknown fault kind"):
        Fault(step=0, kind="meteor_strike")


def test_fault_plan_stable_order_within_step():
    plan = FaultPlan([
        Fault(step=3, kind=POOL_PRESSURE, pages=2),
        Fault(step=3, kind=DEVICE_DEATH, device=1),
    ])
    assert [f.kind for f in plan.at(3)] == [DEVICE_DEATH, POOL_PRESSURE]
    assert plan.at(4) == ()


# ---------------------------------------------------------------------------
# named lifecycle errors (satellite: release no longer a silent no-op)
# ---------------------------------------------------------------------------

def test_release_unknown_slot_raises():
    cfg = _dense_cfg()
    params = T.init_params(RNG, cfg)
    srv = _server(cfg, params, max_seq=32, batch=2, paged=True, page_size=8,
                  pool_pages=8)
    with pytest.raises(SlotReleaseError, match="slot 0"):
        srv.release(0)
    cache = srv.empty_cache()
    tokens = np.arange(5, dtype=np.int32)[None, :] % cfg.vocab_size
    _, cache = srv.prefill_into_slot(1, tokens, cache)
    cache = srv.release(1, cache)
    with pytest.raises(SlotReleaseError, match="slot 1"):
        srv.release(1, cache)


# ---------------------------------------------------------------------------
# migration replica cap (satellite: cap is a no-op, not an overwrite)
# ---------------------------------------------------------------------------

def test_apply_migration_replica_cap_is_noop():
    cfg = _moe_cfg()
    params = T.init_params(RNG, cfg)
    # 6 virtual devices x 2 slots: experts 0..3 on devs 0-1, devs 2-5 free.
    srv = _server(cfg, params, max_seq=32, batch=1, slots_per_device=2,
                  virtual_ep=6)
    r_max = srv.slot_of.shape[1]
    for dst in (2, 3, 4):
        assert srv._apply_migration((0, 0, dst))
    assert int(srv.n_replicas[0]) == r_max
    assert len(srv.state.replicas[0]) == r_max
    table_before = np.asarray(srv.slot_of).copy()
    w_before = np.asarray(srv.params["layers"]["moe"]["w_gate"]).copy()
    # At the cap: must refuse, leaving table, weights AND balancer state
    # untouched (the old behaviour overwrote slot_of[e, -1] and leaked the
    # previous replica's slot from the free-slot accounting forever).
    assert not srv._apply_migration((0, 0, 5))
    assert int(srv.n_replicas[0]) == r_max
    assert len(srv.state.replicas[0]) == r_max
    np.testing.assert_array_equal(np.asarray(srv.slot_of), table_before)
    np.testing.assert_array_equal(
        np.asarray(srv.params["layers"]["moe"]["w_gate"]), w_before
    )
    # ...and the slot the no-op would have leaked is still allocatable.
    assert srv._apply_migration((1, 0, 5))


# ---------------------------------------------------------------------------
# mark_dead: end-to-end evacuation (satellite test)
# ---------------------------------------------------------------------------

def test_mark_dead_moves_weights_and_routing():
    cfg = _moe_cfg()
    params = T.init_params(RNG, cfg)
    # 4 virtual devices x 2 slots: dev0 = {e0, e1}, dev1 = {e2, e3};
    # killing dev1 orphans e2 and e3.
    srv = _server(cfg, params, max_seq=32, batch=2, slots_per_device=2,
                  virtual_ep=4, paged=True, page_size=8, pool_pages=10)
    spd = srv.scfg.slots_per_device
    moe_before = {
        w: np.asarray(srv.params["layers"]["moe"][w]).copy()
        for w in ("w_gate", "w_up", "w_down")
    }
    plan = srv.mark_dead(1)
    assert sorted(e for e, _, _ in plan) == [2, 3]
    assert all(src == 1 and dst not in (1,) for _, src, dst in plan)
    # Physical weight movement: the evacuated experts' rows now live in a
    # slot of the destination device (slot s initially holds expert s).
    slot_of = np.asarray(srv.slot_of)
    n_rep = np.asarray(srv.n_replicas)
    for e, _src, dst in plan:
        live = [int(s) for s in slot_of[e, : n_rep[e]]]
        landed = [s for s in live if s // spd == dst]
        assert landed, f"expert {e} has no replica on destination {dst}"
        for w in ("w_gate", "w_up", "w_down"):
            np.testing.assert_array_equal(
                np.asarray(srv.params["layers"]["moe"][w])[:, landed[0]],
                moe_before[w][:, e],
            )
    # Routing: no table entry (including inert tail columns) targets dev 1.
    assert not np.any(slot_of // spd == 1)
    assert all(1 not in r for r in srv.state.replicas)
    assert 1 in srv.state.dead and np.isinf(srv.state.heats()[1])
    # The step loop survives the death: decode still runs and is finite.
    cache = srv.empty_cache()
    toks = np.arange(6, dtype=np.int32)[None, :] % cfg.vocab_size
    logits, cache = srv.prefill_into_slot(0, toks, cache)
    assert np.isfinite(np.asarray(logits)).all()
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    tok = jnp.pad(tok, ((0, 1), (0, 0)))
    for _ in range(3):
        logits, cache = srv.decode(tok, cache)
        assert np.isfinite(np.asarray(logits[0])).all()
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)


def test_mark_dead_without_orphans_still_drops_routing():
    cfg = _moe_cfg()
    params = T.init_params(RNG, cfg)
    srv = _server(cfg, params, max_seq=32, batch=1, slots_per_device=2,
                  virtual_ep=4)
    # Replicate dev1's experts elsewhere first: death then orphans nothing.
    assert srv._apply_migration((2, 1, 2))
    assert srv._apply_migration((3, 1, 3))
    plan = srv.mark_dead(1)
    assert plan == []
    assert not np.any(np.asarray(srv.slot_of) // 2 == 1)
    assert all(1 not in r for r in srv.state.replicas)


# ---------------------------------------------------------------------------
# straggler draining (satellite test)
# ---------------------------------------------------------------------------

def test_report_step_time_scales_heat_and_drains():
    cfg = _moe_cfg()
    params = T.init_params(RNG, cfg)
    srv = _server(cfg, params, max_seq=32, batch=1, slots_per_device=2,
                  virtual_ep=4)
    state = srv.state
    base = state.heats().copy()
    srv.report_step_time(1, 5.0)
    once = state.heats()
    assert once[1] == pytest.approx(base[1] * (0.8 + 0.2 * 5.0))
    assert once[0] == pytest.approx(base[0])  # healthy devices untouched
    for _ in range(30):  # EMA converges to the measured ratio
        srv.report_step_time(1, 5.0)
    assert state.slowdown[1] == pytest.approx(5.0, rel=1e-3)
    # The balancer now drains the straggler: first migration moves load
    # off device 1 (the hottest once the slowdown multiplier applies).
    migs = topology_aware_balance(state, srv.distance)
    assert migs and migs[0][1] == 1


# ---------------------------------------------------------------------------
# virtual EP substrate: local dispatch parity + masked-token routing
# ---------------------------------------------------------------------------

def test_virtual_ep_generate_matches_dense():
    """ep_moe_local + slot-expanded weights + live migrations must be
    numerically identical to the dense MoE reference (replicas are exact
    copies; only the placement changes)."""
    cfg = _moe_cfg()
    params = T.init_params(RNG, cfg)
    prompt = jnp.ones((2, 6), jnp.int32)
    out_dense = _server(cfg, params, max_seq=32, batch=2).generate(prompt, 8)
    srv = _server(cfg, params, max_seq=32, batch=2, slots_per_device=3,
                  virtual_ep=4, alpha=0.1)  # eager balancer: migrate live
    out_vep = srv.generate(prompt, 8)
    assert srv.use_balancer and srv.migrations > 0
    assert np.array_equal(np.asarray(out_dense), np.asarray(out_vep))


def test_token_mask_zeroes_dead_rows():
    """Masked (released-slot) rows produce zero MoE output, spend no
    bucket capacity, and drop out of the balancer counts."""
    cfg = _moe_cfg()
    p = M.moe_init(RNG, cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 1, cfg.d_model))
    mask = jnp.asarray([True, False, True, False])[:, None]
    ctx = ParallelCtx(capacity_factor=8.0)
    full, aux_full = M.moe_dense(p, x, cfg, ctx)
    out, aux = M.moe_dense(p, x, cfg, ctx, token_mask=mask)
    np.testing.assert_array_equal(np.asarray(out[1]), 0)
    np.testing.assert_array_equal(np.asarray(out[3]), 0)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(full[0]),
                               rtol=1e-6, atol=1e-6)
    counts_full = np.asarray(aux_full["counts"])
    counts = np.asarray(aux["counts"])
    assert counts.sum() == counts_full.sum() / 2  # 2 of 4 rows masked
