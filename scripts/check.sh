#!/usr/bin/env bash
# One-command gate: tier-1 tests + a fast interpret-mode kernel smoke.
#
#   ./scripts/check.sh          # full gate
#   ./scripts/check.sh -k gmm   # extra args forwarded to the tier-1 pytest
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -x -q "$@"

echo "== kernel smoke (interpret mode) =="
python - <<'EOF'
import jax, jax.numpy as jnp, numpy as np
from repro.kernels.gmm.ops import expert_ffn_ragged
from repro.kernels.gmm.ref import expert_ffn_ragged_ref
from repro.kernels.registry import attend, decode_attend
from repro.models.attention import causal_mask, gqa_attend

rng = jax.random.PRNGKey(0)
ks = jax.random.split(rng, 4)
x = jax.random.normal(ks[0], (4, 16, 8))
wg = jax.random.normal(ks[1], (4, 8, 12)) * 0.1
wu = jax.random.normal(ks[2], (4, 8, 12)) * 0.1
wd = jax.random.normal(ks[3], (4, 12, 8)) * 0.1
gs = jnp.asarray([0, 5, 16, 3], jnp.int32)
np.testing.assert_allclose(
    np.asarray(expert_ffn_ragged(x, wg, wu, wd, gs)),
    np.asarray(expert_ffn_ragged_ref(x, wg, wu, wd, gs)),
    rtol=1e-5, atol=1e-5)

q = jax.random.normal(ks[0], (1, 32, 4, 16))
k = jax.random.normal(ks[1], (1, 32, 2, 16))
v = jax.random.normal(ks[2], (1, 32, 2, 16))
np.testing.assert_allclose(
    np.asarray(attend(q, k, v, causal=True)),
    np.asarray(gqa_attend(q, k, v, causal_mask(32))),
    rtol=2e-5, atol=2e-5)

valid = (jnp.arange(32)[None, :] < 20).astype(jnp.int32)
out = decode_attend(q[:, 0], k, v, valid)
assert np.isfinite(np.asarray(out)).all()
print("kernel smoke OK")
EOF

echo "ALL CHECKS PASSED"
