#!/usr/bin/env bash
# One-command gate: tier-1 tests + interpret-mode kernel & bench smokes +
# the bench baseline regression check.
#
#   ./scripts/check.sh          # fast tier (-m "not slow") + smokes + baseline
#   ./scripts/check.sh --all    # full matrix incl. slow multidevice tests
#   ./scripts/check.sh --lint   # ruff only (what the CI lint job runs)
#   ./scripts/check.sh -k gmm   # extra args forwarded to the tier-1 pytest
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "--lint" ]]; then
  echo "== lint: ruff check =="
  if ! python -m ruff --version >/dev/null 2>&1; then
    echo "check.sh --lint: ruff is not installed in this environment." >&2
    echo "Install it with:  pip install ruff  (see requirements-dev.txt)" >&2
    exit 1
  fi
  python -m ruff check .
  echo "LINT OK"
  exit 0
fi

# Fail early with a readable message when the runtime dependency is absent
# (a bare 'ModuleNotFoundError: jax' traceback from deep inside pytest
# collection is the alternative).
if ! python -c "import jax" >/dev/null 2>&1; then
  echo "check.sh: the 'jax' package is missing from this Python environment." >&2
  echo "This repo needs jax + jaxlib (CPU is fine; kernels run in interpret" >&2
  echo "mode off-TPU). Install with:  pip install jax jaxlib" >&2
  exit 1
fi

MARK=(-m "not slow")
TIER="fast tier (-m 'not slow'; --all for the full matrix)"
if [[ "${1:-}" == "--all" ]]; then
  MARK=()
  TIER="full matrix"
  shift
fi

echo "== tier-1: pytest [$TIER] =="
python -m pytest -x -q ${MARK[@]+"${MARK[@]}"} "$@"

echo "== kernel smoke (interpret mode) =="
python - <<'EOF'
import jax, jax.numpy as jnp, numpy as np
from repro.kernels.gmm.ops import expert_ffn_gather, expert_ffn_ragged
from repro.kernels.gmm.ref import expert_ffn_gather_ref, expert_ffn_ragged_ref
from repro.kernels.registry import attend, decode_attend
from repro.models.attention import causal_mask, gqa_attend

rng = jax.random.PRNGKey(0)
ks = jax.random.split(rng, 4)
x = jax.random.normal(ks[0], (4, 16, 8))
wg = jax.random.normal(ks[1], (4, 8, 12)) * 0.1
wu = jax.random.normal(ks[2], (4, 8, 12)) * 0.1
wd = jax.random.normal(ks[3], (4, 12, 8)) * 0.1
gs = jnp.asarray([0, 5, 16, 3], jnp.int32)
np.testing.assert_allclose(
    np.asarray(expert_ffn_ragged(x, wg, wu, wd, gs)),
    np.asarray(expert_ffn_ragged_ref(x, wg, wu, wd, gs)),
    rtol=1e-5, atol=1e-5)

# fused dispatch-gather: flat rows + per-bucket offsets, no padded buffer
rows = jax.random.normal(ks[0], (24, 8))
offs = jnp.asarray([0, 0, 5, 21], jnp.int32)
np.testing.assert_allclose(
    np.asarray(expert_ffn_gather(rows, wg, wu, wd, offs, gs, capacity=16)),
    np.asarray(expert_ffn_gather_ref(rows, wg, wu, wd, offs, gs, 16)),
    rtol=1e-5, atol=1e-5)

# compact combine leg: scatter epilogue + metadata combine — live rows of
# the flat output must match the compact oracle, and rows the combine
# drops may hold NaN garbage without poisoning any kept token
from repro.kernels.gmm.ops import expert_ffn_gather_compact
from repro.kernels.gmm.ref import expert_ffn_compact_ref
from repro.parallel.collectives import combine_from_rows
compact = np.asarray(
    expert_ffn_gather_compact(rows, wg, wu, wd, offs, gs, capacity=16))
oracle = np.asarray(expert_ffn_compact_ref(rows, wg, wu, wd, offs, gs, 16))
for off, cnt in zip(np.asarray(offs), np.asarray(gs)):
    np.testing.assert_allclose(
        compact[off:off+cnt], oracle[off:off+cnt], rtol=1e-5, atol=1e-5)
yf = jnp.asarray(oracle).at[23].set(jnp.nan)  # garbage in a dropped row
cmb = combine_from_rows(
    yf, jnp.asarray([[0], [5], [23]]), jnp.asarray([[True], [True], [False]]),
    jnp.ones((3, 1)))
assert np.isfinite(np.asarray(cmb)).all(), "dropped-row garbage leaked into combine"

# fully-fused single-kernel FFN: all three matmuls in one Pallas call, the
# SwiGLU hidden tile never leaves VMEM — live rows must match both the
# two-kernel gather+scatter composition and the oracle
from repro.kernels.gmm.ops import expert_ffn_fused
from repro.kernels.gmm.ref import expert_ffn_fused_ref
fused = np.asarray(
    expert_ffn_fused(rows, wg, wu, wd, offs, gs, capacity=16))
fref = np.asarray(expert_ffn_fused_ref(rows, wg, wu, wd, offs, gs, 16))
for off, cnt in zip(np.asarray(offs), np.asarray(gs)):
    np.testing.assert_allclose(
        fused[off:off+cnt], compact[off:off+cnt], rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        fused[off:off+cnt], fref[off:off+cnt], rtol=1e-5, atol=1e-5)

q = jax.random.normal(ks[0], (1, 32, 4, 16))
k = jax.random.normal(ks[1], (1, 32, 2, 16))
v = jax.random.normal(ks[2], (1, 32, 2, 16))
np.testing.assert_allclose(
    np.asarray(attend(q, k, v, causal=True)),
    np.asarray(gqa_attend(q, k, v, causal_mask(32))),
    rtol=2e-5, atol=2e-5)

valid = (jnp.arange(32)[None, :] < 20).astype(jnp.int32)
out = decode_attend(q[:, 0], k, v, valid)
assert np.isfinite(np.asarray(out)).all()

# paged decode: block-table walk over a shared page pool must match the
# dense masked path (same lengths, identity table)
from repro.kernels.registry import decode_attend_paged
from repro.kernels.flash_decode.ref import decode_ref
bs = 8
pool_k = k.reshape(4, bs, 2, 16)
pool_v = v.reshape(4, bs, 2, 16)
tables = jnp.arange(4, dtype=jnp.int32).reshape(1, 4)
lengths = jnp.asarray([20], jnp.int32)
np.testing.assert_allclose(
    np.asarray(decode_attend_paged(q[:, 0], pool_k, pool_v, tables, lengths)),
    np.asarray(decode_ref(q[:, 0], k, v, valid)),
    rtol=2e-5, atol=2e-5)

# flash-decode partials over two disjoint halves LSE-merge to the full path
from repro.kernels.registry import decode_attend_partials
a1, m1, l1 = decode_attend_partials(q[:, 0], k[:, :16], v[:, :16], valid[:, :16])
a2, m2, l2 = decode_attend_partials(q[:, 0], k[:, 16:], v[:, 16:], valid[:, 16:])
mm = jnp.maximum(m1, m2)
num = a1 * jnp.exp(m1 - mm)[..., None] + a2 * jnp.exp(m2 - mm)[..., None]
den = l1 * jnp.exp(m1 - mm) + l2 * jnp.exp(m2 - mm)
np.testing.assert_allclose(
    np.asarray(num / jnp.maximum(den, 1e-30)[..., None]),
    np.asarray(decode_ref(q[:, 0], k, v, valid)),
    rtol=2e-5, atol=2e-5)
print("kernel smoke OK")
EOF

echo "== serving chaos smoke (seeded fault injection) =="
python - <<'EOF'
# Continuous-batching loop under a seeded fault plan: pool pressure forces
# preemption, a NaN step forces a requeue-and-recompute — every request
# must finish with tokens bit-identical to its sequential fault-free run.
import dataclasses
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config, smoke
from repro.models import transformer as T
from repro.parallel.ctx import ParallelCtx
from repro.runtime.faults import Fault, FaultPlan, NAN_LOGITS, POOL_PRESSURE, POOL_RELEASE
from repro.runtime.scheduler import FINISHED, RequestScheduler
from repro.runtime.serve import Server, ServeConfig

cfg = smoke(get_config("llama3.2-1b"))
params = T.init_params(jax.random.PRNGKey(0), cfg)
ctx = ParallelCtx()
rng = np.random.default_rng(0)
prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
           for n in (5, 9, 6)]

def sched_for(batch, pool, faults=None):
    srv = Server(cfg, ctx, jax.tree.map(jnp.copy, params),
                 ServeConfig(max_seq=64, batch=batch, paged=True,
                             page_size=8, pool_pages=pool))
    return RequestScheduler(srv, faults=faults)

ref = []
for p in prompts:
    s = sched_for(1, 64)
    r = s.submit(p, max_new_tokens=6)
    s.run()
    assert r.state == FINISHED, (r.state, r.error)
    ref.append(list(r.tokens_out))

plan = FaultPlan([
    Fault(step=2, kind=POOL_PRESSURE, pages=4),
    Fault(step=3, kind=NAN_LOGITS, slots=(0,)),
    Fault(step=7, kind=POOL_RELEASE, pages=4),
])
s = sched_for(2, 8, faults=plan)
reqs = [s.submit(p, max_new_tokens=6, arrival=i) for i, p in enumerate(prompts)]
s.run()
assert s.n_preempted > 0, "fault plan should have forced a preemption"
for i, r in enumerate(reqs):
    assert r.state == FINISHED, (i, r.state, r.error)
    assert list(r.tokens_out) == ref[i], (i, r.tokens_out, ref[i])
print(f"chaos smoke OK ({s.n_preempted} preemptions, parity held)")
EOF

echo "== live stepped migration smoke (slice schedule + parity) =="
python - <<'EOF'
# Skewed router traffic trips the Eq. 2 trigger; the resulting migration
# must spread its weight copy over >= 3 decode ticks, commit only after the
# last slice, and leave the generated tokens bit-identical to both the
# instantaneous baseline (migration_slices=0) and the dense reference.
import dataclasses
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config, smoke
from repro.models import transformer as T
from repro.parallel.ctx import ParallelCtx
from repro.runtime.serve import Server, ServeConfig

cfg = dataclasses.replace(
    smoke(get_config("dbrx-132b")), n_experts=4, experts_per_token=2)
params = T.init_params(jax.random.PRNGKey(0), cfg)
router = np.asarray(params["layers"]["moe"]["router"])
scale = np.ones(router.shape[-1], router.dtype)
scale[[0, 1]] = 8.0  # sustained hot experts
params["layers"]["moe"]["router"] = jnp.asarray(router * scale)

def serve(**kw):
    srv = Server(cfg, ParallelCtx(capacity_factor=8.0),
                 jax.tree.map(jnp.copy, params),
                 ServeConfig(max_seq=32, batch=2, **kw))
    out = srv.generate(jnp.ones((2, 6), jnp.int32), 12)
    return srv, np.asarray(out)

vep = dict(slots_per_device=3, virtual_ep=4, alpha=0.1)
_, dense = serve()
inst_srv, inst = serve(migration_slices=0, **vep)
step_srv, stepped = serve(migration_slices=4, **vep)
assert inst_srv.migrations > 0 and step_srv.migrations > 0
np.testing.assert_array_equal(dense, inst)
np.testing.assert_array_equal(dense, stepped)
for rec in step_srv.driver.history:
    assert len(set(rec["issue_ticks"])) >= 3, rec
    assert rec["committed"] > max(rec["issue_ticks"]), rec
print(f"migration smoke OK ({step_srv.migrations} stepped migrations, "
      "parity held)")
EOF

echo "== crash-and-restore smoke (snapshot at step k, bit-identical resume) =="
python - <<'EOF'
# Kill the serving loop after step k via a crash_restart fault, rebuild a
# *fresh* Server + scheduler from the on-disk snapshot plus the params
# checkpoint, and require the concatenated pre/post-crash token streams to
# equal the uninterrupted run's, for every request (including one still
# QUEUED at the crash).
import dataclasses, os, tempfile
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config, smoke
from repro.models import transformer as T
from repro.parallel.ctx import ParallelCtx
from repro.runtime import snapshot as S
from repro.runtime.faults import CRASH_RESTART, Fault, FaultPlan, SimulatedCrash
from repro.runtime.scheduler import FINISHED, RequestScheduler
from repro.runtime.serve import Server, ServeConfig

cfg = dataclasses.replace(
    smoke(get_config("dbrx-132b")), n_experts=4, experts_per_token=2)
params = T.init_params(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(3)
prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
           for n in (5, 9, 4, 7)]
arrivals = [0, 1, 2, 6]   # the last request is still QUEUED at the crash
scfg = dict(max_seq=64, paged=True, page_size=8, pool_pages=10, alpha=0.1,
            slots_per_device=3, virtual_ep=4, batch=2)

def sched_for(faults=None):
    srv = Server(cfg, ParallelCtx(capacity_factor=8.0),
                 jax.tree.map(jnp.copy, params), ServeConfig(**scfg))
    s = RequestScheduler(srv, faults=faults)
    for p, a in zip(prompts, arrivals):
        s.submit(p, max_new_tokens=6, arrival=a)
    return s

ref = sched_for().run()

k = 4
path = os.path.join(tempfile.mkdtemp(), "snap.npz")
plan = FaultPlan([Fault(step=k, kind=CRASH_RESTART, path=path)])
s = sched_for(faults=plan)
try:
    s.run()
    raise SystemExit("crash fault never fired")
except SimulatedCrash as e:
    assert e.step == k and os.path.exists(path)
pre = {r.rid: list(r.tokens_out) for r in s.requests}

restored = S.restore_scheduler(
    path, cfg, ParallelCtx(capacity_factor=8.0),
    jax.tree.map(jnp.copy, params), faults=plan)
res = restored.run()
for rid, want in ref.items():
    got = np.asarray(res[rid])
    assert np.array_equal(got[:len(pre[rid])], pre[rid]), (rid, "prefix torn")
    assert np.array_equal(got, want), (rid, got, want)
assert all(r.state == FINISHED for r in restored.requests)
print(f"crash-restore smoke OK (killed at step {k}, "
      f"{len(prompts)} streams bit-identical)")
EOF

echo "== chunked-admission smoke (prefill lane in the decode step) =="
python - <<'EOF'
# A long prompt admits through the decode step's prefill lane (one chunk
# per tick) while a live batch keeps emitting tokens. The live streams never
# stall more than one tick, the newcomer's first token must land within
# ceil(len/chunk)+1 ticks of admission, every stream must be bit-identical
# to splice admission, and ONE compiled step program must have served
# idle, decode-only and decode+chunk ticks alike.
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config, smoke
from repro.models import transformer as T
from repro.parallel.ctx import ParallelCtx
from repro.runtime.scheduler import FINISHED, RequestScheduler
from repro.runtime.serve import Server, ServeConfig

cfg = smoke(get_config("llama3.2-1b"))
params = T.init_params(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)
prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
           for n in (4, 6, 40)]   # two live decoders, then one long prompt
CHUNK = 8

def run(prefill_chunk):
    srv = Server(cfg, ParallelCtx(), jax.tree.map(jnp.copy, params),
                 ServeConfig(max_seq=64, batch=3, paged=True, page_size=8,
                             pool_pages=32, prefill_chunk=prefill_chunk))
    s = RequestScheduler(srv)
    reqs = [s.submit(p, max_new_tokens=10, arrival=[0, 0, 2][i])
            for i, p in enumerate(prompts)]
    s.run()
    assert all(r.state == FINISHED for r in reqs), [r.state for r in reqs]
    return srv, s, reqs

srv_a, s_a, _ = run(None)
srv_b, s_b, reqs = run(CHUNK)
for rid, want in s_a.results().items():
    assert np.array_equal(s_b.results()[rid], want), (rid, "stream diverged")
stats = s_b.stats()
assert stats["max_stall_ticks"] == 0, stats  # O(1) inter-token gap, always
long = reqs[2]
ticks = long.first_token_step - long.admitted_step + 1
bound = -(-len(prompts[2]) // CHUNK) + 1
assert ticks <= bound, (ticks, bound)
assert srv_b._decode._cache_size() == 1, srv_b._decode._cache_size()
print(f"chunked-admission smoke OK (ttft {ticks} <= {bound} ticks, "
      f"stall 0, parity held, 1 program)")
EOF

echo "== chunked-EP overlap smoke (pipelined dispatch parity + exposed-comm accounting) =="
XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}" python - <<'EOF'
# ep_chunks must be a pure schedule knob: on a real 4-way all_to_all (8
# fake CPU devices, 2x4 mesh), skewed routing at capacity_factor=1.0 must
# produce bit-identical outputs for ep_chunks in {1, 2}, on prefill and
# decode shapes alike; the analytic exposed-comm schedule from
# bench_kernels must sit strictly below the single-shot baseline for
# every K > 1; and a chunk count that does not divide the expert-group
# count must fail loudly at ServeConfig construction.
import sys
import numpy as np, jax, jax.numpy as jnp
from repro.launch.mesh import make_mesh_compat
from repro.parallel.collectives import ep_moe_shardmap, uniform_placement
from repro.parallel.ctx import ParallelCtx

mesh = make_mesh_compat((2, 4), ("data", "model"))
ep, spd = 4, 2
e = ep * spd
d, f = 16, 32
slot_w = {
    "w_gate": jax.random.normal(jax.random.PRNGKey(1), (e, d, f)) * 0.1,
    "w_up": jax.random.normal(jax.random.PRNGKey(2), (e, d, f)) * 0.1,
    "w_down": jax.random.normal(jax.random.PRNGKey(3), (e, f, d)) * 0.1,
}
slot_of, n_rep = uniform_placement(e, e)
k = 2
hot = jnp.asarray([0] * 6 + [1] * 4 + list(range(e)))  # skewed routing pool
for shape in ((2, 8), (8, 1)):
    b, s = shape
    x = jax.random.normal(jax.random.PRNGKey(4), (b, s, d))
    ids = jax.random.choice(jax.random.PRNGKey(5), hot, (b, s, k))
    w = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(6), (b, s, k)), -1)
    with mesh:
        base = None
        for K in (1, 2):
            ctx = ParallelCtx(mesh=mesh, use_kernels=True, ep_chunks=K)
            out = np.asarray(ep_moe_shardmap(
                x, ids, w, slot_w, slot_of, n_rep, ctx, 1.0, spd,
                decode=(s == 1)))
            assert np.all(np.isfinite(out))
            if base is None:
                base = out
            else:
                np.testing.assert_array_equal(
                    out, base,
                    err_msg=f"shape={shape} ep_chunks={K}: chunked dispatch "
                    "diverged from the single-shot path")

sys.path.insert(0, "benchmarks")
from bench_kernels import ep_chunk_cell_accounting
_, _, per_k = ep_chunk_cell_accounting(
    "smoke_skewed", 4, 4, 64, 128, 256, (1, 2, 4), False)
exposed = {int(kk): acc["exposed_comm_ms"] for kk, acc in per_k.items()}
assert exposed[2] < exposed[1] and exposed[4] < exposed[1], exposed

from repro.runtime.serve import ServeConfig
try:
    ServeConfig(max_seq=32, batch=2, slots_per_device=3, ep_chunks=2)
    raise SystemExit("ep_chunks=2 with 3 expert groups should have raised")
except ValueError as err:
    assert "ep_chunks" in str(err), err
print(f"chunked-EP smoke OK (bit parity K=2 on a 2x4 mesh, "
      f"exposed_comm_ms {exposed})")
EOF

echo "== kernel-dispatch bench smoke (interpret mode) =="
python benchmarks/bench_kernels.py --smoke > /dev/null
echo "bench smoke OK"

echo "== bench baseline regression check (deterministic columns) =="
python benchmarks/bench_kernels.py --check BENCH_kernels.json
echo "bench baseline OK"

echo "ALL CHECKS PASSED"
