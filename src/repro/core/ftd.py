"""Full Token Domain (FTD) analysis framework (paper Section IV-A).

The FTD of a device is the minimal set of devices that collectively hold
tokens from all TP groups. Its geometry predicts the MoE all-to-all cost
through three lenses the paper analyses:

* **hops** — mean pairwise Manhattan distance between FTD members
  (uniform access probability among the other members),
* **congestion** — FTD bounding boxes that overlap force routed traffic of
  different FTDs through shared links,
* **imbalance** — hot experts inside FTD-intersection regions amplify the
  shared-link pressure (worst-case analysis).
"""

from __future__ import annotations

import dataclasses
import itertools

import numpy as np

from repro.core.er_mapping import Mapping


@dataclasses.dataclass(frozen=True)
class FTDStats:
    avg_hops: float              # mean pairwise hop distance within FTDs
    max_hops: int                # diameter of the widest FTD
    avg_bbox_area: float         # mean bounding-box area
    n_intersecting_pairs: int    # FTD pairs with overlapping bounding boxes
    intersection_area: float     # total pairwise bbox overlap area


def _bbox(coords: list[tuple[int, int]]) -> tuple[int, int, int, int]:
    rs = [r for r, _ in coords]
    cs = [c for _, c in coords]
    return min(rs), min(cs), max(rs), max(cs)


def _bbox_overlap(a, b) -> int:
    r0 = max(a[0], b[0])
    c0 = max(a[1], b[1])
    r1 = min(a[2], b[2])
    c1 = min(a[3], b[3])
    if r1 < r0 or c1 < c0:
        return 0
    return (r1 - r0 + 1) * (c1 - c0 + 1)


def ftd_stats(mapping: Mapping) -> FTDStats:
    topo = mapping.topo
    hop_sum, hop_n, hop_max = 0.0, 0, 0
    areas = []
    boxes = []
    for devs in mapping.ftds:
        coords = [topo.coord(d) for d in devs]
        for a, b in itertools.combinations(coords, 2):
            h = topo.hops(a, b)
            hop_sum += h
            hop_n += 1
            hop_max = max(hop_max, h)
        box = _bbox(coords)
        boxes.append(box)
        areas.append((box[2] - box[0] + 1) * (box[3] - box[1] + 1))

    n_inter, inter_area = 0, 0.0
    for a, b in itertools.combinations(boxes, 2):
        ov = _bbox_overlap(a, b)
        if ov:
            n_inter += 1
            inter_area += ov
    return FTDStats(
        avg_hops=hop_sum / max(hop_n, 1),
        max_hops=hop_max,
        avg_bbox_area=float(np.mean(areas)),
        n_intersecting_pairs=n_inter,
        intersection_area=inter_area,
    )
