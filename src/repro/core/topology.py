"""Mesh / multi-wafer network topology model.

The paper's WSC platforms are 2-D meshes of dies; multi-WSC systems stitch
several wafers edge-to-edge through border connectors. This module provides:

* device coordinates and (directed) link enumeration,
* deterministic dimension-ordered (XY) routing,
* per-link traffic accumulation for arbitrary src->dst traffic matrices —
  the primitive every collective/migration cost model is built on,
* hop distances (Manhattan within a wafer, border-crossing across wafers).

Wafers are laid out in a row: wafer w occupies columns [w*W, (w+1)*W).
Cross-wafer links exist between every pair of horizontally adjacent border
devices, matching the paper's "one-border cross-wafer bandwidth".
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Iterator

import numpy as np

Coord = tuple[int, int]          # (row, col) in the global grid
Link = tuple[int, int]           # (src_device_id, dst_device_id), directed


@dataclasses.dataclass(frozen=True)
class MeshTopology:
    """A grid of devices: ``n_wafers`` wafers of ``rows x cols`` each.

    Device ids are row-major over the *global* grid of shape
    ``(rows, n_wafers * cols)``.
    """

    rows: int
    cols: int
    n_wafers: int = 1

    # -- basic geometry ------------------------------------------------

    @property
    def global_cols(self) -> int:
        return self.cols * self.n_wafers

    @property
    def n_devices(self) -> int:
        return self.rows * self.global_cols

    def device_id(self, coord: Coord) -> int:
        r, c = coord
        return r * self.global_cols + c

    def coord(self, device_id: int) -> Coord:
        return divmod(device_id, self.global_cols)

    def wafer_of(self, coord: Coord) -> int:
        return coord[1] // self.cols

    def coords(self) -> Iterator[Coord]:
        for r in range(self.rows):
            for c in range(self.global_cols):
                yield (r, c)

    def is_cross_wafer(self, link: Link) -> bool:
        (r1, c1), (r2, c2) = self.coord(link[0]), self.coord(link[1])
        return c1 // self.cols != c2 // self.cols

    # -- links -----------------------------------------------------------

    @functools.cached_property
    def links(self) -> list[Link]:
        """All directed nearest-neighbour links, in a fixed order."""
        out: list[Link] = []
        for r in range(self.rows):
            for c in range(self.global_cols):
                u = self.device_id((r, c))
                if c + 1 < self.global_cols:
                    v = self.device_id((r, c + 1))
                    out.extend([(u, v), (v, u)])
                if r + 1 < self.rows:
                    v = self.device_id((r + 1, c))
                    out.extend([(u, v), (v, u)])
        return out

    @functools.cached_property
    def link_index(self) -> dict[Link, int]:
        return {l: i for i, l in enumerate(self.links)}

    @property
    def n_links(self) -> int:
        return len(self.links)

    # -- distance / routing ------------------------------------------------

    def hops(self, a: Coord, b: Coord) -> int:
        """Manhattan hop count between two devices (XY route length)."""
        return abs(a[0] - b[0]) + abs(a[1] - b[1])

    def route(self, src: Coord, dst: Coord) -> list[Link]:
        """Dimension-ordered (X then Y) route as a list of directed links."""
        path: list[Link] = []
        r, c = src
        step = 1 if dst[1] > c else -1
        while c != dst[1]:
            nxt = (r, c + step)
            path.append((self.device_id((r, c)), self.device_id(nxt)))
            c += step
        step = 1 if dst[0] > r else -1
        while r != dst[0]:
            nxt = (r + step, c)
            path.append((self.device_id((r, c)), self.device_id(nxt)))
            r += step
        return path

    # -- traffic accounting --------------------------------------------------

    def link_loads(self, traffic: dict[tuple[int, int], float]) -> np.ndarray:
        """Accumulate a traffic matrix onto per-link byte counts.

        ``traffic`` maps (src_device_id, dst_device_id) -> bytes. Routes are
        XY-deterministic. Returns an array of shape (n_links,).
        """
        loads = np.zeros(self.n_links)
        idx = self.link_index
        for (s, d), vol in traffic.items():
            if s == d or vol == 0.0:
                continue
            for link in self.route(self.coord(s), self.coord(d)):
                loads[idx[link]] += vol
        return loads

    def max_hops(self, traffic: dict[tuple[int, int], float]) -> int:
        """Longest route length among non-zero traffic entries."""
        h = 0
        for (s, d), vol in traffic.items():
            if s != d and vol > 0.0:
                h = max(h, self.hops(self.coord(s), self.coord(d)))
        return h

    # -- heat maps (for the cold/hot link analysis of Section V) -------------

    def load_grid(self, loads: np.ndarray) -> dict[Link, float]:
        """Expose per-link loads keyed by link for inspection/plotting."""
        return {l: float(loads[i]) for i, l in enumerate(self.links)}
