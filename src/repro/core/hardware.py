"""Hardware platform models.

Two families of constants live here:

* Paper-fidelity platforms (WSC, DGX-B200, NVL72) used by the analytical
  evaluator to reproduce the paper's figures (Section VI setup: each WSC die
  is B200-equivalent; Dojo-style interconnect numbers).
* The TPU v5e target used by the roofline analysis of the executable
  framework (constants fixed by the task spec: 197 TFLOP/s bf16, 819 GB/s
  HBM, ~50 GB/s/link ICI).

All bandwidths are bytes/second, latencies in seconds, compute in FLOP/s.
"""

from __future__ import annotations

import dataclasses

TB = 1e12
GB = 1e9
US = 1e-6


@dataclasses.dataclass(frozen=True)
class DeviceSpec:
    """Per-device compute/memory capability."""

    name: str
    flops: float           # peak FLOP/s at the evaluation precision
    hbm_bytes: float       # memory capacity
    hbm_bw: float          # memory bandwidth, bytes/s
    # Sustained efficiency knobs used by the analytical compute model.
    flops_efficiency: float = 0.7
    hbm_efficiency: float = 0.8

    def compute_time(self, flops: float, bytes_moved: float) -> float:
        """Roofline execution-time estimate for one kernel invocation."""
        return max(
            flops / (self.flops * self.flops_efficiency),
            bytes_moved / (self.hbm_bw * self.hbm_efficiency),
        )


@dataclasses.dataclass(frozen=True)
class LinkSpec:
    """One network link class: per-direction bandwidth and per-hop latency."""

    bw: float              # bytes/s, per direction
    latency: float         # seconds per hop (link + protocol)


@dataclasses.dataclass(frozen=True)
class PlatformSpec:
    """A deployable platform = device spec + network link classes.

    ``intra`` is the dense local network (on-wafer d2d / NVLink / ICI),
    ``inter`` the cross-group network (cross-wafer border / InfiniBand /
    DCI). For single-tier platforms ``inter`` simply equals ``intra``.
    """

    name: str
    device: DeviceSpec
    intra: LinkSpec
    inter: LinkSpec
    group_size: int        # devices inside one high-bw island (node/wafer/pod)


# --- Paper Section VI-A platform setup -------------------------------------
# Each WSC die is assumed B200-equivalent: 2250 TFLOPS FP16, 180 GB HBM at
# 8 TB/s. Die-to-die bidirectional bandwidth 8 TB/s (=> 4 TB/s per
# direction), one-border cross-wafer 9 TB/s (=> 4.5 TB/s per direction).
B200_DIE = DeviceSpec(
    name="B200",
    flops=2250e12,
    hbm_bytes=180 * GB,
    hbm_bw=8 * TB,
)

WSC = PlatformSpec(
    name="WSC",
    device=B200_DIE,
    intra=LinkSpec(bw=4 * TB, latency=0.05 * US),
    inter=LinkSpec(bw=4.5 * TB, latency=0.2 * US),
    group_size=64,  # one 8x8 wafer
)

# DGX B200: 8 GPUs per node on NVLink5 (1.8 TB/s bidir => 0.9 TB/s per
# direction), nodes joined by 400 GB/s InfiniBand with ~2 us latency.
DGX = PlatformSpec(
    name="DGX",
    device=B200_DIE,
    intra=LinkSpec(bw=0.9 * TB, latency=0.3 * US),
    inter=LinkSpec(bw=0.05 * TB, latency=2.0 * US),
    group_size=8,
)

# NVL72: 72 dies behind a unified NVLink switch fabric.
NVL72 = PlatformSpec(
    name="NVL72",
    device=B200_DIE,
    intra=LinkSpec(bw=0.9 * TB, latency=0.3 * US),
    inter=LinkSpec(bw=0.9 * TB, latency=0.3 * US),
    group_size=72,
)

# --- TPU v5e target (executable framework roofline) ------------------------
TPU_V5E = DeviceSpec(
    name="TPUv5e",
    flops=197e12,          # bf16
    hbm_bytes=16 * GB,
    hbm_bw=819 * GB,
)

TPU_POD = PlatformSpec(
    name="TPUv5e-pod",
    device=TPU_V5E,
    intra=LinkSpec(bw=50 * GB, latency=1.0 * US),   # ICI per link
    inter=LinkSpec(bw=12.5 * GB, latency=10.0 * US),  # cross-pod DCI
    group_size=256,  # 16x16 torus
)

PLATFORMS = {p.name: p for p in (WSC, DGX, NVL72, TPU_POD)}
