"""Synthetic expert-load traces with the paper's statistical structure.

Section V-B observes: (a) per-scenario expert popularity is *stable* after a
brief warm-up (intrinsically popular experts + domain-specific experts),
(b) production serving sees *cyclically evolving scenario mixtures* (Azure
arrival traces), inducing slow-varying device-load ratios.

We generate loads accordingly: each scenario draws a fixed Dirichlet expert-
popularity vector per layer; a mixed trace blends scenarios with slowly
rotating weights; per-iteration loads are multinomial draws, giving both the
stable ratios of Fig. 12 and the drift that forces continuous rebalancing.
Deterministic under a seed.
"""

from __future__ import annotations

import dataclasses

import numpy as np

SCENARIOS = ("chat", "coding", "math", "privacy")


@dataclasses.dataclass
class LoadTrace:
    """loads[t, e] = token count routed to expert e at iteration t."""

    loads: np.ndarray
    scenario: str

    @property
    def n_iterations(self) -> int:
        return self.loads.shape[0]

    @property
    def n_experts(self) -> int:
        return self.loads.shape[1]


def scenario_popularity(
    n_experts: int, scenario: str, seed: int = 0, concentration: float = 0.05
) -> np.ndarray:
    """Stable expert-popularity vector for one scenario (sums to 1).

    Low Dirichlet concentration yields the skewed, peaky distributions the
    paper profiles — calibrated so that folding experts onto 8 devices gives
    peak device loads of ~2-3x the average (paper Fig. 12: up to 2.9x).
    """
    idx = SCENARIOS.index(scenario)
    rng = np.random.default_rng(seed * 1000 + idx)
    pop = rng.dirichlet(np.full(n_experts, concentration))
    # Intrinsic popularity bias shared across scenarios (paper cites [3]).
    shared = np.random.default_rng(seed).dirichlet(np.full(n_experts, 0.15))
    return 0.85 * pop + 0.15 * shared


def single_scenario_trace(
    n_experts: int,
    tokens_per_iter: int,
    n_iterations: int,
    scenario: str = "math",
    seed: int = 0,
) -> LoadTrace:
    pop = scenario_popularity(n_experts, scenario, seed)
    rng = np.random.default_rng(seed + 7)
    loads = rng.multinomial(tokens_per_iter, pop, size=n_iterations).astype(float)
    return LoadTrace(loads=loads, scenario=scenario)


def mixed_scenario_trace(
    n_experts: int,
    tokens_per_iter: int,
    n_iterations: int,
    period: int = 400,
    seed: int = 0,
) -> LoadTrace:
    """Cyclically drifting scenario mixture (Azure-style request pools)."""
    pops = np.stack(
        [scenario_popularity(n_experts, s, seed) for s in SCENARIOS]
    )  # (S, E)
    t = np.arange(n_iterations)[:, None]
    phases = np.linspace(0, 2 * np.pi, len(SCENARIOS), endpoint=False)[None, :]
    # Slowly rotating softmax mixture weights.
    logits = 1.5 * np.sin(2 * np.pi * t / period + phases)
    w = np.exp(logits)
    w /= w.sum(axis=1, keepdims=True)              # (T, S)
    probs = w @ pops                               # (T, E)
    rng = np.random.default_rng(seed + 13)
    loads = np.stack(
        [rng.multinomial(tokens_per_iter, probs[i]) for i in range(n_iterations)]
    ).astype(float)
    return LoadTrace(loads=loads, scenario="mixed")


def device_load_ratios(loads: np.ndarray, n_devices: int) -> np.ndarray:
    """Fold expert loads onto devices (expert e -> device e % n_devices),
    returning per-iteration device load / mean — the Fig. 12 quantity."""
    t, e = loads.shape
    dev = np.zeros((t, n_devices))
    for expert in range(e):
        dev[:, expert % n_devices] += loads[:, expert]
    mean = dev.mean(axis=1, keepdims=True)
    return dev / np.maximum(mean, 1e-12)
