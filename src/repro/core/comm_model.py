"""Analytical communication cost model (paper Eq. 1 + Section IV).

Every cost is built from the paper's latency law

    latency = (volume / bandwidth + link_latency) x hops          (Eq. 1)

applied per link class (intra-wafer vs cross-wafer), plus explicit per-link
traffic accounting on the mesh: a traffic matrix is routed XY-determin-
istically and accumulated per directed link, so congestion (the paper's
FTD-intersection effect) emerges from the placement instead of being an
assumed constant.

Mesh collectives:
* ``mesh_allreduce``     — ring reduce-scatter + all-gather over each TP
                           group's ring schedule (entwined rings are
                           time-staggered per the paper, so intersecting
                           ring edges do not contend).
* ``mesh_alltoall``      — MoE dispatch+combine confined to FTDs (with AG
                           retained) or spread to shard owners (no AG).
* ``hier_allreduce``     — HER-Mapping: intra-wafer reduce-scatter +
                           inter-wafer all-gather (Fig. 10(c)).

Switched-cluster references (DGX / NVL72):
* ``cluster_allreduce`` / ``cluster_alltoall`` — two-tier analytical
  models over NVLink islands joined by IB (or a single NVLink domain).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.er_mapping import Mapping
from repro.core.hardware import LinkSpec, PlatformSpec
from repro.core.topology import MeshTopology


@dataclasses.dataclass(frozen=True)
class CommResult:
    time: float                 # total estimated seconds
    transfer: float             # bandwidth component
    latency: float              # link-latency component
    max_link_bytes: float = 0.0
    link_loads: np.ndarray | None = None

    def __add__(self, other: "CommResult") -> "CommResult":
        loads = None
        if self.link_loads is not None and other.link_loads is not None:
            loads = self.link_loads + other.link_loads
        elif self.link_loads is not None:
            loads = self.link_loads
        elif other.link_loads is not None:
            loads = other.link_loads
        return CommResult(
            self.time + other.time,
            self.transfer + other.transfer,
            self.latency + other.latency,
            max(self.max_link_bytes, other.max_link_bytes),
            loads,
        )


ZERO = CommResult(0.0, 0.0, 0.0)


# ---------------------------------------------------------------------------
# link-class helpers
# ---------------------------------------------------------------------------

def _link_specs(topo: MeshTopology, platform: PlatformSpec) -> tuple[np.ndarray, np.ndarray]:
    """Per-link (bw, latency) arrays, honouring cross-wafer link class."""
    bw = np.empty(topo.n_links)
    lat = np.empty(topo.n_links)
    for i, l in enumerate(topo.links):
        spec: LinkSpec = platform.inter if topo.is_cross_wafer(l) else platform.intra
        bw[i] = spec.bw
        lat[i] = spec.latency
    return bw, lat


def route_traffic(
    topo: MeshTopology,
    traffic: dict[tuple[int, int], float],
    platform: PlatformSpec,
) -> tuple[np.ndarray, float, float]:
    """Route a traffic matrix.

    Returns (per-link byte loads, max route latency, traffic-weighted mean
    hop count). The mean hop count is the Eq. 1 store-and-forward
    amplification: a message on an h-hop path pays its bandwidth term h
    times (the paper's ``x hops`` factor)."""
    bw, lat = _link_specs(topo, platform)
    del bw
    loads = np.zeros(topo.n_links)
    idx = topo.link_index
    max_lat = 0.0
    vol_sum = 0.0
    vol_hops = 0.0
    for (s, d), vol in traffic.items():
        if s == d or vol <= 0.0:
            continue
        route = topo.route(topo.coord(s), topo.coord(d))
        route_lat = 0.0
        for link in route:
            li = idx[link]
            loads[li] += vol
            route_lat += lat[li]
        max_lat = max(max_lat, route_lat)
        vol_sum += vol
        vol_hops += vol * len(route)
    mean_hops = vol_hops / vol_sum if vol_sum else 0.0
    return loads, max_lat, mean_hops


def _congested_time(
    topo: MeshTopology,
    platform: PlatformSpec,
    loads: np.ndarray,
    max_route_lat: float,
    mean_hops: float,
) -> CommResult:
    bw, _ = _link_specs(topo, platform)
    per_link = loads / bw
    # Bottleneck link x store-and-forward amplification (Eq. 1's hop factor
    # on the bandwidth term; congestion already lives in the max).
    transfer = float(per_link.max(initial=0.0)) * max(mean_hops, 1.0)
    return CommResult(
        time=transfer + max_route_lat,
        transfer=transfer,
        latency=max_route_lat,
        max_link_bytes=float(loads.max(initial=0.0)),
        link_loads=loads,
    )


def _route_time(
    topo: MeshTopology, platform: PlatformSpec, src: int, dst: int, vol: float
) -> float:
    """Eq. 1 for a single transfer with per-link classes:
    sum over links of (vol/bw_l + lat_l)."""
    t = 0.0
    for link in topo.route(topo.coord(src), topo.coord(dst)):
        spec = platform.inter if topo.is_cross_wafer(link) else platform.intra
        t += vol / spec.bw + spec.latency
    return t


# ---------------------------------------------------------------------------
# mesh all-reduce (ring / entwined ring)
# ---------------------------------------------------------------------------

def mesh_allreduce(
    mapping: Mapping,
    platform: PlatformSpec,
    bytes_per_device: float,
    retain_ag: bool = True,
    groups: list[list[int]] | None = None,
) -> CommResult:
    """Ring all-reduce over every TP group's ring, concurrently.

    Per phase (reduce-scatter, all-gather) there are ``n - 1`` steps; each
    step moves one ``bytes/n`` chunk along every ring edge. Entwined rings
    (ER) have multi-hop edges; intersecting edges of different rings are
    time-staggered (paper Section IV-B2), so the step time is the slowest
    single edge transfer, not a contended one.

    ``groups`` overrides the reduction domains (default: the TP groups);
    the ESP combine passes the FTDs here — compact 1-hop tiles under ER.
    """
    topo = mapping.topo
    groups = groups if groups is not None else mapping.tp_groups
    n = len(groups[0])
    if n == 1:
        return ZERO
    chunk = bytes_per_device / n
    phases = 2 if retain_ag else 1
    steps = phases * (n - 1)

    # Slowest ring edge across all groups (Eq. 1, mixed link classes).
    step_time = 0.0
    for devs in groups:
        for i in range(len(devs)):
            a, b = devs[i], devs[(i + 1) % len(devs)]
            step_time = max(step_time, _route_time(topo, platform, a, b, chunk))

    # Heatmap: every ring edge carries ``steps`` chunks over the run.
    traffic: dict[tuple[int, int], float] = {}
    for devs in groups:
        for i in range(len(devs)):
            a, b = devs[i], devs[(i + 1) % len(devs)]
            traffic[(a, b)] = traffic.get((a, b), 0.0) + chunk * steps
    loads, _, _ = route_traffic(topo, traffic, platform)

    total = steps * step_time
    # Split transfer/latency components for reporting.
    lat_part = 0.0
    for devs in groups:
        for i in range(len(devs)):
            a, b = devs[i], devs[(i + 1) % len(devs)]
            h = topo.hops(topo.coord(a), topo.coord(b))
            lat_part = max(lat_part, h * platform.intra.latency)
    lat_total = steps * lat_part
    return CommResult(
        time=total,
        transfer=total - lat_total,
        latency=lat_total,
        max_link_bytes=float(loads.max(initial=0.0)),
        link_loads=loads,
    )


def hier_allreduce(
    mapping: Mapping,
    platform: PlatformSpec,
    bytes_per_device: float,
) -> CommResult:
    """HER-Mapping all-reduce: intra-wafer reduce-scatter, then inter-wafer
    exchange of the scattered shards over the border links (Fig. 10(c)).

    After phase 1 each device holds a distinct reduced shard, so phase 2
    moves only ``bytes/tp_local`` per device across wafers, instead of
    dragging full ring chunks over the border ``tp - 1`` times.
    """
    topo = mapping.topo
    if topo.n_wafers == 1:
        return mesh_allreduce(mapping, platform, bytes_per_device)
    n_w = topo.n_wafers
    m = mapping.tp // n_w                      # wafer-local ring size
    if m < 1:
        raise ValueError("tp smaller than wafer count")

    # Phase 1: intra-wafer ring reduce-scatter over each wafer-local segment.
    chunk = bytes_per_device / m
    step_time = 0.0
    traffic: dict[tuple[int, int], float] = {}
    for g in range(mapping.dp):
        devs = mapping.tp_groups[g]
        for w in range(n_w):
            seg = devs[w * m : (w + 1) * m]
            for i in range(len(seg) - 1):
                a, b = seg[i], seg[i + 1]
                step_time = max(step_time, _route_time(topo, platform, a, b, chunk))
                traffic[(a, b)] = traffic.get((a, b), 0.0) + chunk * (m - 1)
    phase1 = (m - 1) * step_time

    # Phase 2: inter-wafer all-gather(+reduce) of corresponding shards:
    # ring over the ``n_w`` wafer-replicas of each shard, 2(n_w - 1) steps.
    shard = bytes_per_device / m
    step2 = 0.0
    for g in range(mapping.dp):
        devs = mapping.tp_groups[g]
        for i in range(m):
            for w in range(n_w - 1):
                a = devs[w * m + (i if w % 2 == 0 else m - 1 - i)]
                b = devs[(w + 1) * m + (i if (w + 1) % 2 == 0 else m - 1 - i)]
                step2 = max(step2, _route_time(topo, platform, a, b, shard))
                traffic[(a, b)] = traffic.get((a, b), 0.0) + shard * 2 * (n_w - 1)
    phase2 = 2 * (n_w - 1) * step2

    loads, max_lat, _ = route_traffic(topo, traffic, platform)
    total = phase1 + phase2
    return CommResult(
        time=total,
        transfer=total - max_lat,
        latency=max_lat,
        max_link_bytes=float(loads.max(initial=0.0)),
        link_loads=loads,
    )


# ---------------------------------------------------------------------------
# mesh all-to-all (MoE dispatch + combine)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class A2AWorkload:
    tokens_per_group: int       # tokens held by one TP group (full set, post AG)
    token_bytes: int            # hidden * bytes_per_element
    topk: int                   # experts activated per token
    device_load: np.ndarray | None = None  # per-device receive weight, mean ~1


def _a2a_traffic(
    mapping: Mapping, wl: A2AWorkload, retain_ag: bool
) -> dict[tuple[int, int], float]:
    topo = mapping.topo
    n = topo.n_devices
    total_dispatch = mapping.dp * wl.tokens_per_group * wl.topk  # token copies
    base_recv = total_dispatch / n
    load = (
        wl.device_load
        if wl.device_load is not None
        else np.ones(n)
    )

    traffic: dict[tuple[int, int], float] = {}

    def add(s: int, d: int, vol: float) -> None:
        if s != d and vol > 0:
            traffic[(s, d)] = traffic.get((s, d), 0.0) + vol

    if retain_ag:
        # Each destination fetches tokens of group g from the member of g in
        # its own FTD (nearest source, guaranteed by AG).
        for devs in mapping.ftds:
            for dst in devs:
                recv = base_recv * load[dst] * wl.token_bytes
                per_group = recv / mapping.dp
                for src in devs:
                    if mapping.group_of[src] != mapping.group_of[dst]:
                        add(src, dst, per_group)
    else:
        # Without AG, token shards live on their reduce-scatter owners:
        # fetch uniformly from every member of every group.
        for dst in range(n):
            recv = base_recv * load[dst] * wl.token_bytes
            per_member = recv / (mapping.dp * mapping.tp)
            for g in range(mapping.dp):
                for src in mapping.tp_groups[g]:
                    add(src, dst, per_member)
    return traffic


def mesh_alltoall(
    mapping: Mapping,
    platform: PlatformSpec,
    wl: A2AWorkload,
    retain_ag: bool = True,
) -> CommResult:
    """Dispatch + combine all-to-all on the mesh (two symmetric phases)."""
    topo = mapping.topo
    dispatch = _a2a_traffic(mapping, wl, retain_ag)
    combine = {(d, s): v for (s, d), v in dispatch.items()}
    r1 = _congested_time(topo, platform, *route_traffic(topo, dispatch, platform))
    r2 = _congested_time(topo, platform, *route_traffic(topo, combine, platform))
    return r1 + r2


# ---------------------------------------------------------------------------
# switched-cluster references (DGX / NVL72)
# ---------------------------------------------------------------------------

def cluster_allreduce(
    platform: PlatformSpec, n_devices: int, bytes_per_device: float
) -> CommResult:
    """Two-tier ring all-reduce on NVLink islands joined by an IB fabric.

    ``n_devices`` is the reduction domain (a TP group) — callers pass the
    TP size, which deployments keep inside one NVLink island."""
    s = min(platform.group_size, n_devices)
    k = max(n_devices // s, 1)
    intra_t = 2 * (s - 1) / s * bytes_per_device / platform.intra.bw
    intra_l = 2 * (s - 1) * platform.intra.latency
    inter_t = 2 * (k - 1) / k * bytes_per_device / platform.inter.bw
    inter_l = 2 * (k - 1) * platform.inter.latency
    return CommResult(
        time=intra_t + intra_l + inter_t + inter_l,
        transfer=intra_t + inter_t,
        latency=intra_l + inter_l,
    )


def cluster_alltoall(
    platform: PlatformSpec,
    n_devices: int,
    per_device_bytes: float,
    imbalance: float = 1.0,
    hier_factor: float = 2.0,
) -> CommResult:
    """Dispatch+combine all-to-all: every device exchanges
    ``per_device_bytes`` spread uniformly over all peers; cross-island
    traffic rides the (slow) inter fabric. ``hier_factor`` models the
    hierarchical intra-node aggregation of DeepSpeed-MoE-style systems
    (paper baseline [46]): duplicate token copies to the same remote node
    are merged before crossing IB."""
    s = min(platform.group_size, n_devices)
    frac_inter = (n_devices - s) / n_devices / max(hier_factor, 1.0)
    frac_intra = (s - 1) / n_devices
    one_phase_t = imbalance * per_device_bytes * max(
        frac_inter / platform.inter.bw, frac_intra / platform.intra.bw
    )
    lat = platform.inter.latency if n_devices > s else platform.intra.latency
    return CommResult(
        time=2 * (one_phase_t + lat),
        transfer=2 * one_phase_t,
        latency=2 * lat,
    )


# ---------------------------------------------------------------------------
# hot / cold link analysis (Section V-A)
# ---------------------------------------------------------------------------

def cold_links(loads: np.ndarray, frac: float = 0.05) -> np.ndarray:
    """Boolean mask of links whose load is below ``frac`` of the max."""
    peak = loads.max(initial=0.0)
    if peak == 0.0:
        return np.ones_like(loads, dtype=bool)
    return loads <= frac * peak


def link_heatmaps(
    mapping: Mapping,
    platform: PlatformSpec,
    bytes_per_device: float,
    wl: A2AWorkload,
) -> tuple[np.ndarray, np.ndarray]:
    """(all-reduce loads, all-to-all loads) per link — Fig. 11(a)(b)."""
    ar = mesh_allreduce(mapping, platform, bytes_per_device)
    a2a = mesh_alltoall(mapping, platform, wl)
    assert ar.link_loads is not None and a2a.link_loads is not None
    return ar.link_loads, a2a.link_loads
