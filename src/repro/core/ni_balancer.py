"""Non-invasive Balancer — trigger rule (Eq. 2) and placement algorithms.

Implements:

* :class:`BalancerState` — per-layer expert→device placement with shadow
  slots, replica counts ``Num_e``, historical load EMA ``Load_e`` and device
  heats ``Heat_d = Σ Load_e / Num_e``.
* :func:`topology_aware_balance` — the paper's Algorithm 1: pick the most
  popular expert on the hottest device, replicate it to the *topologically
  nearest* device whose heat stays below the current max.
* :func:`greedy_balance` — the EPLB-style baseline: hottest expert to the
  globally coldest device, distance-blind.
* :func:`should_trigger` — Eq. 2: cumulative per-layer imbalance above
  ``alpha`` and time-since-migration above ``beta`` (``beta = 0`` for the
  non-invasive mode).

The balancer is deliberately framework-agnostic: it reasons over abstract
device ids + a hop-distance callable, so the same code drives both the
analytical simulator and the executable JAX serving path (where the
resulting replica sets reprogram the token router).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from repro.parallel.placement import PlacementError, PlacementTable

Migration = tuple[int, int, int]  # (expert, src_device, dst_device)


@dataclasses.dataclass
class BalancerState:
    """Expert placement for one MoE layer.

    Since the placement-table unification the state no longer owns its own
    ``replicas`` device lists: it reads (and mutates) placement exclusively
    through the shared :class:`~repro.parallel.placement.PlacementTable` —
    the same table whose committed half routes tokens in the jitted decode
    step. ``replicas`` is a derived *planning* view (committed + in-flight
    replicas), so Algorithm 1 never re-plans a migration whose slices are
    still landing. The load EMA, dead set and straggler slowdowns remain
    balancer-local (they are heat inputs, not placement).
    """

    n_experts: int
    n_devices: int
    slots_per_device: int                      # native + shadow capacity
    table: PlacementTable
    load_ema: np.ndarray                       # Load_e, EMA of token counts
    ema_decay: float = 0.8
    dead: set[int] = dataclasses.field(default_factory=set)
    # Straggler penalty: effective heat multiplier per device (EMA of
    # step-time ratio vs median; 1.0 = healthy).
    slowdown: np.ndarray | None = None

    @classmethod
    def initial(
        cls, n_experts: int, n_devices: int, slots_per_device: int
    ) -> "BalancerState":
        if n_experts > n_devices * slots_per_device:
            raise ValueError("not enough slots for native experts")
        table = PlacementTable.round_robin(
            n_experts, n_devices, slots_per_device
        )
        return cls(
            n_experts=n_experts,
            n_devices=n_devices,
            slots_per_device=slots_per_device,
            table=table,
            load_ema=np.ones(n_experts) / n_experts,
        )

    # -- derived quantities ---------------------------------------------------

    @property
    def replicas(self) -> list[list[int]]:
        """replicas[e] = devices hosting expert e (first = native home),
        including in-flight (reserved, not yet routed-to) replicas."""
        return self.table.all_replica_devices()

    def num_replicas(self) -> np.ndarray:
        return np.array([len(r) for r in self.replicas])

    def device_experts(self) -> list[list[int]]:
        out: list[list[int]] = [[] for _ in range(self.n_devices)]
        for e, devs in enumerate(self.replicas):
            for d in devs:
                out[d].append(e)
        return out

    def slots_used(self) -> np.ndarray:
        return self.table.slots_used().astype(np.int64)

    def heats(self) -> np.ndarray:
        """Heat_d = Σ_e on d Load_e / Num_e, with straggler penalty."""
        heat = np.zeros(self.n_devices)
        for e, devs in enumerate(self.replicas):
            share = self.load_ema[e] / len(devs)
            for d in devs:
                heat[d] += share
        if self.slowdown is not None:
            heat = heat * self.slowdown
        for d in self.dead:
            heat[d] = np.inf
        return heat

    def observe(self, loads: np.ndarray) -> None:
        """Fold one iteration's per-expert token counts into the EMA."""
        total = loads.sum()
        if total > 0:
            self.load_ema = (
                self.ema_decay * self.load_ema
                + (1 - self.ema_decay) * loads / total
            )

    def device_token_share(self) -> np.ndarray:
        """Expected fraction of dispatched tokens landing on each device
        (mean-normalised) — feeds A2AWorkload.device_load."""
        heat = np.zeros(self.n_devices)
        for e, devs in enumerate(self.replicas):
            share = self.load_ema[e] / len(devs)
            for d in devs:
                heat[d] += share
        mean = heat[heat < np.inf].mean() if len(heat) else 1.0
        return heat / max(mean, 1e-12)

    def mark_dead(self, device: int) -> None:
        self.dead.add(device)

    def revive(self, device: int) -> None:
        """Re-admit a previously dead device into planning: clear its dead
        flag (heat becomes finite again) and reset any straggler penalty.
        Placement is untouched — the device re-enters routing only when
        replica copies commit through the migration path."""
        self.dead.discard(device)
        if self.slowdown is not None:
            self.slowdown[device] = 1.0

    def drop_device(self, device: int) -> int:
        """Forget a dead device's replicas wherever another replica
        survives, so routing never targets it again. Experts whose *only*
        copy sits on ``device`` keep that entry (every expert must retain
        >= 1 replica; run ``evacuate`` first so no such orphan exists).
        Returns the number of experts that dropped a replica."""
        return self.table.drop_device(device)

    def apply(self, mig: Migration) -> None:
        """Instantaneously commit a planned migration into the shared
        table (simulation / evacuation fast-forward; the live serving path
        goes through the MigrationDriver's reserve -> slices -> commit)."""
        e, src, dst = mig
        if src not in self.replicas[e]:
            raise PlacementError(
                f"migration {mig}: source device {src} hosts no replica "
                f"of expert {e}"
            )
        if self.table.apply(e, dst) is None:
            raise PlacementError(
                f"migration {mig}: destination {dst} cannot take a replica "
                f"of expert {e} (no free slot, already hosting, or replica "
                f"cap)"
            )


# ---------------------------------------------------------------------------
# Eq. 2 trigger
# ---------------------------------------------------------------------------

def imbalance_degree(loads_per_layer: Sequence[np.ndarray]) -> float:
    """Σ_i (max(load_i) - mean(load_i)) / mean(load_i) over layers."""
    total = 0.0
    for loads in loads_per_layer:
        mu = loads.mean()
        if mu > 0:
            total += (loads.max() - mu) / mu
    return total


def should_trigger(
    loads_per_layer: Sequence[np.ndarray],
    alpha: float,
    dt_since_migration: float,
    beta: float = 0.0,
) -> bool:
    """Paper Eq. 2 (``beta = 0`` for the non-invasive balancer)."""
    return imbalance_degree(loads_per_layer) > alpha and dt_since_migration > beta


# ---------------------------------------------------------------------------
# placement algorithms
# ---------------------------------------------------------------------------

def topology_aware_balance(
    state: BalancerState,
    distance: Callable[[int, int], float],
    max_migrations: int | None = None,
) -> list[Migration]:
    """Paper Algorithm 1.

    Repeatedly: find the hottest device, its most loaded (per-replica)
    expert, the set of devices that would stay below the current max heat
    after adopting a replica — and copy to the topologically *nearest* one.
    Terminates when no such device (with a free slot) exists.
    """
    migs: list[Migration] = []
    # Work on copies so planning does not mutate live state.
    replicas = [list(r) for r in state.replicas]
    used = state.slots_used().copy()
    load = state.load_ema

    def heats() -> np.ndarray:
        h = np.zeros(state.n_devices)
        for e, devs in enumerate(replicas):
            share = load[e] / len(devs)
            for d in devs:
                h[d] += share
        if state.slowdown is not None:
            h = h * state.slowdown
        for d in state.dead:
            h[d] = np.inf
        return h

    while max_migrations is None or len(migs) < max_migrations:
        heat = heats()
        # Dead devices carry infinite heat so *candidate* filtering shuns
        # them, but they must not win the hottest-device argmax: their
        # replicas are already dropped from routing, so planning against
        # them wedges the balancer forever after any death.
        finite = np.where(np.isfinite(heat), heat, -np.inf)
        hottest = int(np.argmax(finite))
        if not np.isfinite(heat[hottest]):
            break
        on_hot = [e for e in range(state.n_experts) if hottest in replicas[e]]
        if not on_hot:
            break
        src_e = max(on_hot, key=lambda e: load[e] / len(replicas[e]))
        share = load[src_e] / len(replicas[src_e])
        # After replication the share drops; candidate heat must stay below
        # the current max for the move to reduce peak heat.
        new_share = load[src_e] / (len(replicas[src_e]) + 1)
        cold = [
            d
            for d in range(state.n_devices)
            if d not in replicas[src_e]
            and d not in state.dead
            and heat[d] + new_share < heat[hottest]
            and used[d] < state.slots_per_device
        ]
        if not cold:
            break
        dst = min(cold, key=lambda d: distance(hottest, d))
        replicas[src_e].append(dst)
        used[dst] += 1
        migs.append((src_e, hottest, dst))
        del share
    return migs


def greedy_balance(
    state: BalancerState,
    max_migrations: int | None = None,
) -> list[Migration]:
    """EPLB-style baseline: hottest expert → globally coldest device,
    ignoring topology (migration distance unbounded)."""

    def distance(_a: int, _b: int) -> float:
        return 0.0

    # Same peak-reduction loop, but destination = globally coldest device.
    migs: list[Migration] = []
    replicas = [list(r) for r in state.replicas]
    used = state.slots_used().copy()
    load = state.load_ema

    def heats() -> np.ndarray:
        h = np.zeros(state.n_devices)
        for e, devs in enumerate(replicas):
            share = load[e] / len(devs)
            for d in devs:
                h[d] += share
        for d in state.dead:
            h[d] = np.inf
        return h

    while max_migrations is None or len(migs) < max_migrations:
        heat = heats()
        finite = np.where(np.isfinite(heat), heat, -np.inf)
        hottest = int(np.argmax(finite))   # dead (inf) devices can't win
        if not np.isfinite(heat[hottest]):
            break
        on_hot = [e for e in range(state.n_experts) if hottest in replicas[e]]
        if not on_hot:
            break
        src_e = max(on_hot, key=lambda e: load[e] / len(replicas[e]))
        new_share = load[src_e] / (len(replicas[src_e]) + 1)
        order = np.argsort(heat)
        dst = None
        for d in order:
            d = int(d)
            if (
                d not in replicas[src_e]
                and d not in state.dead
                and used[d] < state.slots_per_device
                and heat[d] + new_share < heat[hottest]
            ):
                dst = d
                break
        if dst is None:
            break
        replicas[src_e].append(dst)
        used[dst] += 1
        migs.append((src_e, hottest, dst))
    del distance
    return migs


def prune_replicas(state: BalancerState, frac: float = 0.5) -> int:
    """Reclaim shadow slots: drop the last replica of any expert whose
    per-replica load has fallen below ``frac`` of the mean device heat
    (the "continuous fine-tuning of slot assignments" of Section V-B).
    Returns the number of reclaimed slots."""
    heats = state.heats()
    finite = heats[np.isfinite(heats)]
    mean_heat = finite.mean() if len(finite) else 0.0
    n = 0
    table = state.table
    for e in range(state.n_experts):
        while (
            int(table.n_replicas[e]) > 1
            and state.load_ema[e] / int(table.n_replicas[e]) < frac * mean_heat
        ):
            table.remove_replica(e, int(table.n_replicas[e]) - 1)
            n += 1
    return n


def evacuate(
    state: BalancerState,
    device: int,
    distance: Callable[[int, int], float],
) -> list[Migration]:
    """Availability evacuation after a device failure: every expert whose
    only live home is ``device`` gets a replica on the nearest device with
    a free slot (Algorithm 1 optimizes load, not availability — this is the
    fault-tolerance companion operation)."""
    state.mark_dead(device)
    used = state.slots_used()
    migs: list[Migration] = []
    for e in range(state.n_experts):
        live = [d for d in state.replicas[e] if d not in state.dead]
        if live:
            continue
        candidates = [
            d
            for d in range(state.n_devices)
            if d not in state.dead and used[d] < state.slots_per_device
        ]
        if not candidates:
            break
        dst = min(candidates, key=lambda d: distance(device, d))
        mig = (e, device, dst)
        state.apply(mig)
        used[dst] += 1
        migs.append(mig)
    return migs


def revival_plan(
    state: BalancerState,
    device: int,
    distance: Callable[[int, int], float],
    max_seed: int | None = None,
) -> list[Migration]:
    """Seed a just-revived (blank-HBM) device with expert replicas.

    The availability inverse of :func:`evacuate`: greedily give ``device``
    a replica of the expert with the highest per-replica load, sourced
    from its topologically nearest live host, as long as the move still
    reduces the global peak heat. ``state.revive(device)`` must already
    have run; the returned plan is fed to the stepped migration driver, so
    nothing routes to ``device`` until each copy's last slice commits.
    """
    if device in state.dead:
        raise PlacementError(f"device {device} is still marked dead")
    migs: list[Migration] = []
    replicas = [list(r) for r in state.replicas]
    used = state.slots_used().copy()
    load = state.load_ema

    def heats() -> np.ndarray:
        h = np.zeros(state.n_devices)
        for e, devs in enumerate(replicas):
            share = load[e] / len(devs)
            for d in devs:
                h[d] += share
        if state.slowdown is not None:
            h = h * state.slowdown
        for d in state.dead:
            h[d] = np.inf
        return h

    while used[device] < state.slots_per_device:
        if max_seed is not None and len(migs) >= max_seed:
            break
        heat = heats()
        finite = np.where(np.isfinite(heat), heat, -np.inf)
        peak = float(np.max(finite))
        # Candidate experts: not already on the device, below replica cap,
        # and splitting their load onto one more replica must not push the
        # revived device past the current peak (else the move cannot help).
        cands = [
            e
            for e in range(state.n_experts)
            if device not in replicas[e]
            and len(replicas[e]) < state.table.r_max
            and any(d not in state.dead for d in replicas[e])
        ]
        cands = [
            e
            for e in cands
            if heat[device] + load[e] / (len(replicas[e]) + 1) < peak
        ]
        if not cands:
            break
        e = max(cands, key=lambda e: load[e] / len(replicas[e]))
        live = [d for d in replicas[e] if d not in state.dead]
        src = min(live, key=lambda d: distance(d, device))
        replicas[e].append(device)
        used[device] += 1
        migs.append((e, src, device))
    return migs


# ---------------------------------------------------------------------------
# router integration: split tokens across replicas
# ---------------------------------------------------------------------------

def replica_shares(state: BalancerState) -> list[np.ndarray]:
    """Per-expert token split across its replicas (uniform — each replica
    takes 1/Num_e of the expert's traffic)."""
    return [np.full(len(r), 1.0 / len(r)) for r in state.replicas]
