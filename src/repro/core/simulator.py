"""Profile-and-simulate engine (paper Section VI methodology).

Replaces the paper's ASTRA-sim backend with our analytical models:
per-iteration MoE inference time is assembled layer by layer from

* attention compute (roofline over DeviceSpec),
* attention all-reduce (mesh ring / entwined ring / hierarchical, or
  switched-cluster reference),
* MoE all-to-all dispatch+combine (FTD-confined mesh model or cluster),
* expert compute (max over devices, honouring load imbalance, replicas and
  ESP sharding),
* PipeMoE-style communication/computation pipelining with ``stages``
  micro-batches,
* an optional migration stream (NI-Balancer) riding cold-link slack.

``run_serving_trace`` drives the whole loop over a load trace: EMA load
observation -> Eq. 2 trigger -> balance plan -> migration engine -> layer
times, reproducing Figs. 15/16/17.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import comm_model as cm
from repro.core.er_mapping import Mapping
from repro.core.hardware import PlatformSpec
from repro.core.migration import MigrationEngine
from repro.core.ni_balancer import (
    BalancerState,
    greedy_balance,
    should_trigger,
    topology_aware_balance,
)
from repro.core.traces import LoadTrace
from repro.core.workloads import SimModelSpec


@dataclasses.dataclass
class IterationBreakdown:
    attn_compute: float
    allreduce: float
    alltoall: float
    moe_compute: float
    migration_exposed: float
    total: float

    @staticmethod
    def zeros() -> "IterationBreakdown":
        return IterationBreakdown(0, 0, 0, 0, 0, 0)


def _overlap(comp: float, comm: float, stages: int) -> float:
    """PipeMoE-style pipelined overlap with ``stages`` micro-batches: the
    longer stream hides the shorter except for one stage's worth."""
    if stages <= 1:
        return comp + comm
    longer, shorter = max(comp, comm), min(comp, comm)
    return longer + shorter / stages


@dataclasses.dataclass
class WSCSystem:
    """A (multi-)wafer system under a given mapping."""

    platform: PlatformSpec
    mapping: Mapping
    hierarchical: bool = False        # HER-Mapping all-reduce
    retain_ag: bool = True

    @property
    def n_devices(self) -> int:
        return self.mapping.topo.n_devices

    def allreduce(self, bytes_per_device: float) -> cm.CommResult:
        if self.hierarchical and self.mapping.topo.n_wafers > 1:
            return cm.hier_allreduce(self.mapping, self.platform, bytes_per_device)
        return cm.mesh_allreduce(
            self.mapping, self.platform, bytes_per_device, self.retain_ag
        )

    def esp_allreduce(self, bytes_per_device: float) -> cm.CommResult:
        """ESP communication (paper §VI-B5): the cluster-wide all-to-all is
        eliminated; what remains is a token gather + partial-sum combine
        *within* each FTD (two ring phases) — compact 1-hop tiles under
        ER-Mapping, spread multi-hop rings under baseline placement."""
        return cm.mesh_allreduce(
            self.mapping, self.platform, bytes_per_device,
            retain_ag=True, groups=self.mapping.ftds,
        )

    def alltoall(self, wl: cm.A2AWorkload) -> cm.CommResult:
        return cm.mesh_alltoall(self.mapping, self.platform, wl, self.retain_ag)

    def distance(self, a: int, b: int) -> float:
        topo = self.mapping.topo
        return topo.hops(topo.coord(a), topo.coord(b))


@dataclasses.dataclass
class ClusterSystem:
    """Switched reference system (DGX / NVL72)."""

    platform: PlatformSpec
    n_devices: int
    tp: int = 8

    def allreduce(self, bytes_per_device: float) -> cm.CommResult:
        # TP group = the reduction domain (kept inside an NVLink island).
        return cm.cluster_allreduce(self.platform, self.tp, bytes_per_device)

    def esp_allreduce(self, bytes_per_device: float) -> cm.CommResult:
        return self.allreduce(bytes_per_device)

    def alltoall(self, wl: cm.A2AWorkload) -> cm.CommResult:
        # Each TP rank dispatches its group's tokens once: per-device egress.
        per_dev = wl.tokens_per_group * wl.topk * wl.token_bytes / self.tp
        imb = 1.0
        if wl.device_load is not None:
            imb = float(np.max(wl.device_load))
        return cm.cluster_alltoall(self.platform, self.n_devices, per_dev, imb)

    def distance(self, a: int, b: int) -> float:
        s = self.platform.group_size
        return 0.0 if a // s == b // s else 1.0


# ---------------------------------------------------------------------------
# one iteration
# ---------------------------------------------------------------------------

def simulate_iteration(
    model: SimModelSpec,
    system,
    tokens_per_group: int,
    tp: int,
    state: BalancerState | None = None,
    stages: int = 4,
    migration_exposed: float = 0.0,
    engine: MigrationEngine | None = None,
) -> IterationBreakdown:
    """Latency of one decode/prefill iteration over all sparse layers."""
    dev = system.platform.device
    n = system.n_devices
    dp = n // tp

    # --- attention phase ---------------------------------------------------
    # Each TP rank computes tokens_per_group tokens over 1/tp of the heads.
    attn_flops = tokens_per_group * model.attn_flops_token / tp
    attn_bytes = model.attn_params * 2 / tp  # FP16 attention weights
    attn_comp = dev.compute_time(attn_flops, attn_bytes)
    ar = system.allreduce(tokens_per_group * model.token_bytes)
    attn_phase = _overlap(attn_comp, ar.time, stages)

    # --- MoE phase -----------------------------------------------------------
    device_load = state.device_token_share() if state is not None else None
    wl = cm.A2AWorkload(
        tokens_per_group=tokens_per_group,
        token_bytes=model.token_bytes,
        topk=model.topk,
        device_load=device_load,
    )
    if model.n_experts < n:
        # ESP regime (paper §VI-B5): experts sharded across devices; tokens
        # stay put, so the all-to-all is *eliminated* and an extra
        # all-reduce (partial-sum combine within EP groups = FTDs) dominates.
        a2a = system.esp_allreduce(tokens_per_group * model.token_bytes)
    else:
        a2a = system.alltoall(wl)

    # Expert compute: tokens land per device proportionally to its heat.
    total_dispatch = dp * tokens_per_group * model.topk
    mean_tokens = total_dispatch / n
    max_share = float(np.max(device_load)) if device_load is not None else 1.0
    tokens_hot = mean_tokens * max_share
    if model.n_experts >= n:
        experts_per_dev = model.n_experts / n
        weight_bytes = experts_per_dev * model.expert_bytes
        flops = tokens_hot * model.expert_flops_token
    else:
        # ESP: each expert sharded over n/E devices (Section VI-B5).
        shard = model.n_experts / n
        weight_bytes = model.expert_bytes * shard
        flops = tokens_hot * model.expert_flops_token * shard
    moe_comp = dev.compute_time(flops, weight_bytes)
    moe_phase = _overlap(moe_comp, a2a.time, stages)

    # --- migration stream -------------------------------------------------------
    if engine is not None:
        engine.step_iteration(
            attn_phase,
            moe_phase,
            ar.link_loads if hasattr(ar, "link_loads") else None,
            a2a.link_loads if hasattr(a2a, "link_loads") else None,
        )

    per_layer = attn_phase + moe_phase
    total = model.layers_sparse * per_layer + migration_exposed
    return IterationBreakdown(
        attn_compute=model.layers_sparse * attn_comp,
        allreduce=model.layers_sparse * ar.time,
        alltoall=model.layers_sparse * a2a.time,
        moe_compute=model.layers_sparse * moe_comp,
        migration_exposed=migration_exposed,
        total=total,
    )


# ---------------------------------------------------------------------------
# trace-driven serving loop (Figs. 15/16)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ServingResult:
    iteration_times: np.ndarray
    peak_over_mean: np.ndarray        # device load imbalance per iteration
    exposed_overhead: float           # total migration stall time
    migrations: int
    breakdown_last: IterationBreakdown


def run_serving_trace(
    model: SimModelSpec,
    system,
    trace: LoadTrace,
    tokens_per_group: int,
    tp: int,
    balancer: str = "none",           # none|greedy|topo|topo_ni
    alpha: float = 2.0,
    beta_iters: int = 10,
    slots_per_device: int | None = None,
    stages: int = 4,
) -> ServingResult:
    n = system.n_devices
    n_exp = trace.n_experts
    slots = slots_per_device or (max(n_exp // n, 1) + 1)
    state = BalancerState.initial(n_exp, n, slots)
    mode = "noninvasive" if balancer == "topo_ni" else "invasive"
    engine = None
    if balancer != "none" and hasattr(system, "mapping"):
        engine = MigrationEngine(
            system.mapping, system.platform, model.expert_bytes, mode=mode
        )

    times = []
    imb = []
    total_exposed = 0.0
    n_migs = 0
    last_mig_iter = -(10**9)
    bd = IterationBreakdown.zeros()
    per_trigger = max(n // 8, 4)   # bounded agility per trigger

    for t in range(trace.n_iterations):
        loads = trace.loads[t]
        state.observe(loads)

        exposed = 0.0
        if balancer != "none" and should_trigger(
            [loads], alpha, t - last_mig_iter, 0 if balancer == "topo_ni" else beta_iters
        ):
            from repro.core.ni_balancer import prune_replicas

            prune_replicas(state)
            if balancer == "greedy":
                plan = greedy_balance(state, max_migrations=per_trigger)
            else:
                plan = topology_aware_balance(
                    state, system.distance, max_migrations=per_trigger
                )
            if plan:
                last_mig_iter = t
                n_migs += len(plan)
                if engine is not None:
                    exposed = engine.submit(plan)
                for m in plan:
                    state.apply(m)
        total_exposed += exposed

        # NOTE: the load-aware state drives compute/imbalance for EVERY
        # policy (including "none") — policies differ only in migrations.
        bd = simulate_iteration(
            model,
            system,
            tokens_per_group,
            tp,
            state=state,
            stages=stages,
            migration_exposed=exposed,
            engine=engine,
        )
        times.append(bd.total)
        share = state.device_token_share()
        imb.append(float(np.max(share)))

    return ServingResult(
        iteration_times=np.array(times),
        peak_over_mean=np.array(imb),
        exposed_overhead=total_exposed,
        migrations=n_migs,
        breakdown_last=bd,
    )
