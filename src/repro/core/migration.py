"""Expert-migration decomposition and cold-link scheduling (Section V-A).

Under ER-Mapping the hot/cold link sets of the two collectives are
complementary: the all-reduce keeps FTD-*connection* links busy (ring
edges) while intra-FTD links idle; the all-to-all is confined inside FTDs
while inter-FTD links idle. A migration therefore decomposes into

    Local (intra-FTD, runs during the attention/all-reduce phase)
  → Global (inter-FTD, runs during the MoE/all-to-all phase)
  → Local (intra-FTD)

steps that ride whatever per-link slack the concurrent collective leaves.

:class:`MigrationEngine` executes submitted migrations over successive
inference iterations:

* ``noninvasive``   — steps consume only link *slack*
  (``phase_time * bw - collective_load``); zero exposed latency by
  construction, but a migration may take several iterations to land.
* ``invasive``      — the migration interrupts inference; its full Eq. 1
  route time is exposed on the critical path (the EPLB-style baseline).
* both honour topology (route lengths) for the transfer times.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.comm_model import _route_time
from repro.core.er_mapping import Mapping
from repro.core.hardware import PlatformSpec
from repro.core.ni_balancer import Migration


@dataclasses.dataclass
class MigStep:
    kind: str            # "local" | "global"
    src: int
    dst: int
    nbytes: float
    sent: float = 0.0

    @property
    def done(self) -> bool:
        return self.sent >= self.nbytes - 1e-9


@dataclasses.dataclass
class InFlight:
    mig: Migration
    steps: list[MigStep]
    step_idx: int = 0

    @property
    def done(self) -> bool:
        return self.step_idx >= len(self.steps)

    @property
    def current(self) -> MigStep:
        return self.steps[self.step_idx]


def decompose(
    mig: Migration, mapping: Mapping, expert_bytes: float
) -> list[MigStep]:
    """Split one expert migration into Local/Global steps (Fig. 11(d))."""
    _, src, dst = mig
    topo = mapping.topo
    f_src, f_dst = int(mapping.ftd_of[src]), int(mapping.ftd_of[dst])
    if f_src == f_dst:
        return [MigStep("local", src, dst, expert_bytes)]
    # Exit through the source-FTD member closest to the destination, enter
    # through the destination-FTD member closest to the source.
    dc, sc = topo.coord(dst), topo.coord(src)
    exit_d = min(mapping.ftds[f_src], key=lambda d: topo.hops(topo.coord(d), dc))
    entry_d = min(mapping.ftds[f_dst], key=lambda d: topo.hops(topo.coord(d), sc))
    steps: list[MigStep] = []
    if exit_d != src:
        steps.append(MigStep("local", src, exit_d, expert_bytes))
    steps.append(MigStep("global", exit_d, entry_d, expert_bytes))
    if entry_d != dst:
        steps.append(MigStep("local", entry_d, dst, expert_bytes))
    return steps


class MigrationEngine:
    """Executes migrations across iterations; accounts exposed latency."""

    def __init__(
        self,
        mapping: Mapping,
        platform: PlatformSpec,
        expert_bytes: float,
        mode: str = "noninvasive",
    ):
        assert mode in ("noninvasive", "invasive")
        self.mapping = mapping
        self.platform = platform
        self.expert_bytes = expert_bytes
        self.mode = mode
        self.in_flight: list[InFlight] = []
        self.completed: list[Migration] = []
        self.total_exposed = 0.0

    # -- submission -----------------------------------------------------------

    def submit(self, migs: list[Migration]) -> float:
        """Queue migrations. Invasive mode returns the exposed stall time
        (inference interrupted while weights move, Eq. 1 route time,
        serialized); non-invasive returns 0 and the engine drains the queue
        on subsequent iterations' cold links."""
        if self.mode == "invasive":
            exposed = 0.0
            for m in migs:
                _, src, dst = m
                exposed += _route_time(
                    self.mapping.topo, self.platform, src, dst, self.expert_bytes
                )
                self.completed.append(m)
            self.total_exposed += exposed
            return exposed
        for m in migs:
            self.in_flight.append(
                InFlight(m, decompose(m, self.mapping, self.expert_bytes))
            )
        return 0.0

    # -- per-iteration drain ----------------------------------------------------

    def _phase_budgets(
        self, phase_time: float, collective_loads: np.ndarray | None
    ) -> np.ndarray:
        """Per-link byte budget left over by the concurrent collective."""
        topo = self.mapping.topo
        bw = np.empty(topo.n_links)
        for i, l in enumerate(topo.links):
            spec = (
                self.platform.inter
                if topo.is_cross_wafer(l)
                else self.platform.intra
            )
            bw[i] = spec.bw
        budget = phase_time * bw
        if collective_loads is not None:
            budget = np.maximum(budget - collective_loads, 0.0)
        return budget

    def _drain(self, kind: str, budget: np.ndarray) -> None:
        topo = self.mapping.topo
        idx = topo.link_index
        for fl in self.in_flight:
            if fl.done:
                continue
            step = fl.current
            if step.kind != kind:
                continue
            links = [idx[l] for l in topo.route(topo.coord(step.src), topo.coord(step.dst))]
            if not links:
                step.sent = step.nbytes
            else:
                avail = float(min(budget[li] for li in links))
                send = min(avail, step.nbytes - step.sent)
                if send <= 0:
                    continue
                for li in links:
                    budget[li] -= send
                step.sent += send
            while not fl.done and fl.current.done:
                fl.step_idx += 1

    def step_iteration(
        self,
        attn_phase_time: float,
        moe_phase_time: float,
        ar_loads: np.ndarray | None = None,
        a2a_loads: np.ndarray | None = None,
    ) -> list[Migration]:
        """Advance all in-flight migrations by one inference iteration.

        Local steps ride all-reduce slack during the attention phase;
        Global steps ride all-to-all slack during the MoE phase. Returns
        migrations that completed this iteration.
        """
        if self.mode == "invasive" or not self.in_flight:
            return []
        local_budget = self._phase_budgets(attn_phase_time, ar_loads)
        self._drain("local", local_budget)
        global_budget = self._phase_budgets(moe_phase_time, a2a_loads)
        self._drain("global", global_budget)

        done = [fl.mig for fl in self.in_flight if fl.done]
        self.completed.extend(done)
        self.in_flight = [fl for fl in self.in_flight if not fl.done]
        return done

    @property
    def pending(self) -> int:
        return len(self.in_flight)
