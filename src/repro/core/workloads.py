"""Paper Table I model specifications for the analytical evaluator.

These are the MoE models the paper evaluates; expert byte sizes follow the
paper's INT8-linears assumption (bytes ~= params). ``expert_flops_token`` is
the standard 2 FLOPs/param/token for the three expert matrices.
"""

from __future__ import annotations

import dataclasses

MB = 1e6


@dataclasses.dataclass(frozen=True)
class SimModelSpec:
    name: str
    total_params: float
    layers_sparse: int
    layers_total: int
    d_model: int
    expert_params: float          # params of ONE expert (gate+up+down)
    n_experts: int
    topk: int
    # dense-path attention params per layer (q,k,v,o with GQA folded in)
    attn_params: float

    @property
    def expert_bytes(self) -> float:
        return self.expert_params  # INT8 weights (paper Section VI-A)

    @property
    def expert_flops_token(self) -> float:
        return 2.0 * self.expert_params

    @property
    def token_bytes(self) -> int:
        return self.d_model * 2   # FP16 activations / communications

    @property
    def attn_flops_token(self) -> float:
        return 2.0 * self.attn_params


def _attn_params(d_model: int, n_heads: int, n_kv: int, head_dim: int | None = None) -> float:
    hd = head_dim or d_model // n_heads
    q = d_model * n_heads * hd
    kv = 2 * d_model * n_kv * hd
    o = n_heads * hd * d_model
    return float(q + kv + o)


DEEPSEEK_V3 = SimModelSpec(
    name="DeepSeek-V3",
    total_params=671e9,
    layers_sparse=58,
    layers_total=61,
    d_model=7168,
    expert_params=42 * MB,
    n_experts=256,
    topk=8,
    attn_params=_attn_params(7168, 128, 128, 128),  # MLA approximated dense
)

QWEN3_235B = SimModelSpec(
    name="Qwen3-235B",
    total_params=235e9,
    layers_sparse=94,
    layers_total=94,
    d_model=4096,
    expert_params=18 * MB,
    n_experts=128,
    topk=8,
    attn_params=_attn_params(4096, 64, 4, 128),
)

DEEPSEEK_V2 = SimModelSpec(
    name="DeepSeek-V2",
    total_params=236e9,
    layers_sparse=59,
    layers_total=60,
    d_model=5120,
    expert_params=23 * MB,
    n_experts=160,
    topk=6,
    attn_params=_attn_params(5120, 128, 128, 128),
)

DBRX = SimModelSpec(
    name="DBRX",
    total_params=132e9,
    layers_sparse=40,
    layers_total=40,
    d_model=6144,
    expert_params=189 * MB,
    n_experts=16,
    topk=4,
    attn_params=_attn_params(6144, 48, 8, 128),
)

MIXTRAL_8X22B = SimModelSpec(
    name="Mixtral-8x22B",
    total_params=141e9,
    layers_sparse=56,
    layers_total=56,
    d_model=6144,
    expert_params=288 * MB,
    n_experts=8,
    topk=2,
    attn_params=_attn_params(6144, 48, 8, 128),
)

PAPER_MODELS = {
    m.name: m
    for m in (DEEPSEEK_V3, QWEN3_235B, DEEPSEEK_V2, DBRX, MIXTRAL_8X22B)
}
