"""Entwined Ring Mapping (paper Section IV).

A *mapping* assigns every device of a 2-D mesh a (TP-group, rank) pair for
the attention layers; the MoE layer's experts live one-per-device (or
several, or sharded — that is orthogonal to the mapping and handled by the
cost/compute models).

Two placements are implemented:

* ``baseline_mapping`` — each TP group occupies a contiguous block of the
  mesh (the standard cluster practice the paper compares against,
  Fig. 8(b)). FTDs are the sets of devices at equal block offsets: large
  bounding boxes that all overlap in the mesh centre.
* ``er_mapping`` — TP groups are entwined: the mesh is cut into compact
  tiles of ``dp`` devices, each tile holding exactly one member of every TP
  group (Fig. 8(c)). Each tile *is* an FTD: minimal area, zero overlap. The
  TP all-reduce becomes entwined multi-hop rings over tiles (Fig. 8(d)).

``hierarchical`` (HER-Mapping, Fig. 10(c)) splits the all-reduce of
multi-wafer systems into intra-wafer reduce-scatter + inter-wafer
all-gather; the placement is per-wafer ER with groups striped across
wafers. The ``Mapping`` object only records placement + ring schedules;
costs live in ``comm_model``.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.topology import Coord, MeshTopology


# ---------------------------------------------------------------------------
# grid ring helpers
# ---------------------------------------------------------------------------

def grid_cycle(h: int, w: int) -> list[Coord]:
    """A Hamiltonian cycle over an ``h x w`` grid with unit steps.

    Exists whenever ``h`` or ``w`` is even (and for the degenerate 1-D
    cases). For odd x odd grids we return a snake *path*; the ring's closing
    step is then longer — the cost model charges it honestly.
    """
    if h == 1 or w == 1:
        return [(r, c) for r in range(h) for c in range(w)]
    if h % 2 == 0:
        # right along row 0, snake down columns w-1..1, return up column 0.
        cyc: list[Coord] = [(0, c) for c in range(w)]
        for r in range(1, h):
            cols = range(w - 1, 0, -1) if r % 2 == 1 else range(1, w)
            cyc.extend((r, c) for c in cols)
        cyc.extend((r, 0) for r in range(h - 1, 0, -1))
        return cyc
    if w % 2 == 0:
        return [(c, r) for (r, c) in grid_cycle(w, h)]
    # odd x odd: boustrophedon path (not a perfect cycle).
    path: list[Coord] = []
    for r in range(h):
        cols = range(w) if r % 2 == 0 else range(w - 1, -1, -1)
        path.extend((r, c) for c in cols)
    return path


def factor_pair(n: int, max_h: int, max_w: int) -> tuple[int, int]:
    """Factor ``n = h * w`` with ``h | max_h`` and ``w | max_w``, preferring
    the most square pair (minimal ``h + w``)."""
    best: tuple[int, int] | None = None
    for h in range(1, n + 1):
        if n % h:
            continue
        w = n // h
        if max_h % h or max_w % w:
            continue
        if best is None or h + w < sum(best):
            best = (h, w)
    if best is None:
        raise ValueError(f"cannot tile {n} devices into {max_h}x{max_w} mesh")
    return best


# ---------------------------------------------------------------------------
# Mapping
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Mapping:
    """Placement of ``dp`` TP groups x ``tp`` ranks onto a mesh."""

    topo: MeshTopology
    dp: int
    tp: int
    name: str
    # tp_groups[g] = device ids of group g in *ring order*.
    tp_groups: list[list[int]]
    # ftds[f] = device ids of FTD f (one member per TP group).
    ftds: list[list[int]]

    def __post_init__(self) -> None:
        n = self.topo.n_devices
        self.group_of = np.full(n, -1, dtype=np.int64)
        self.rank_of = np.full(n, -1, dtype=np.int64)
        self.ftd_of = np.full(n, -1, dtype=np.int64)
        for g, devs in enumerate(self.tp_groups):
            for r, d in enumerate(devs):
                self.group_of[d] = g
                self.rank_of[d] = r
        for f, devs in enumerate(self.ftds):
            for d in devs:
                self.ftd_of[d] = f
        assert (self.group_of >= 0).all(), "every device must be in a TP group"
        assert (self.ftd_of >= 0).all(), "every device must be in an FTD"

    # -- ring schedule ------------------------------------------------------

    def ring_hop_distances(self, g: int) -> list[int]:
        """Hop distance of every consecutive (cyclic) edge of group ``g``'s
        ring. The all-reduce step time scales with the max of these."""
        devs = self.tp_groups[g]
        coords = [self.topo.coord(d) for d in devs]
        return [
            self.topo.hops(coords[i], coords[(i + 1) % len(coords)])
            for i in range(len(coords))
        ]

    def max_ring_hop(self) -> int:
        return max(max(self.ring_hop_distances(g)) for g in range(self.dp))

    # -- device order for jax.make_mesh -------------------------------------

    def device_order(self) -> np.ndarray:
        """(dp, tp) array of device ids: feed ``devices[order]`` to
        ``jax.sharding.Mesh`` so the logical ("data","model") axes land on
        the physical placement this mapping describes."""
        return np.array(self.tp_groups, dtype=np.int64)


# ---------------------------------------------------------------------------
# constructors
# ---------------------------------------------------------------------------

def baseline_mapping(topo: MeshTopology, dp: int, tp: int) -> Mapping:
    """Contiguous-block placement (Fig. 8(b))."""
    if dp * tp != topo.n_devices:
        raise ValueError(f"dp*tp={dp * tp} != devices={topo.n_devices}")
    bh, bw = factor_pair(tp, topo.rows, topo.global_cols)
    grid_h, grid_w = topo.rows // bh, topo.global_cols // bw

    tp_groups: list[list[int]] = []
    for gr in range(grid_h):
        for gc in range(grid_w):
            ring = grid_cycle(bh, bw)
            devs = [
                topo.device_id((gr * bh + r, gc * bw + c)) for (r, c) in ring
            ]
            tp_groups.append(devs)

    # FTD f = devices at equal offset in every block.
    ftds: list[list[int]] = []
    for r in range(bh):
        for c in range(bw):
            ftds.append(
                [
                    topo.device_id((gr * bh + r, gc * bw + c))
                    for gr in range(grid_h)
                    for gc in range(grid_w)
                ]
            )
    return Mapping(topo, dp, tp, "baseline", tp_groups, ftds)


def er_mapping(topo: MeshTopology, dp: int, tp: int) -> Mapping:
    """Entwined placement (Fig. 8(c)): compact disjoint FTD tiles."""
    if dp * tp != topo.n_devices:
        raise ValueError(f"dp*tp={dp * tp} != devices={topo.n_devices}")
    th, tw = factor_pair(dp, topo.rows, topo.global_cols)
    grid_h, grid_w = topo.rows // th, topo.global_cols // tw  # tile grid
    if grid_h * grid_w != tp:
        raise ValueError("tile grid does not match tp")

    tile_ring = grid_cycle(grid_h, grid_w)  # ring order over tiles
    tp_groups = []
    for a in range(th):
        for b in range(tw):
            devs = [
                topo.device_id((t_r * th + a, t_c * tw + b))
                for (t_r, t_c) in tile_ring
            ]
            tp_groups.append(devs)

    ftds = []
    for t_r in range(grid_h):
        for t_c in range(grid_w):
            ftds.append(
                [
                    topo.device_id((t_r * th + a, t_c * tw + b))
                    for a in range(th)
                    for b in range(tw)
                ]
            )
    return Mapping(topo, dp, tp, "er", tp_groups, ftds)


def hierarchical_er_mapping(topo: MeshTopology, dp: int, tp: int) -> Mapping:
    """HER-Mapping for multi-wafer systems (Fig. 10(c)).

    Placement: every wafer is ER-mapped with ``dp`` tiles whose members are
    the wafer-local ranks of each group; group ranks are striped across
    wafers so the inter-wafer all-gather runs on the border links. The ring
    order interleaves wafer-local segments so consecutive wafer-crossing
    edges appear exactly ``n_wafers - 1`` times per ring.
    """
    if topo.n_wafers == 1:
        return er_mapping(topo, dp, tp)
    if dp * tp != topo.n_devices:
        raise ValueError(f"dp*tp={dp * tp} != devices={topo.n_devices}")
    if tp % topo.n_wafers:
        raise ValueError("tp must be divisible by the wafer count")
    local_tp = tp // topo.n_wafers
    wafer = MeshTopology(topo.rows, topo.cols, 1)
    local = er_mapping(wafer, dp, local_tp)

    tp_groups: list[list[int]] = [[] for _ in range(dp)]
    for w in range(topo.n_wafers):
        for g in range(dp):
            seg = [
                topo.device_id((wafer.coord(d)[0], wafer.coord(d)[1] + w * topo.cols))
                for d in local.tp_groups[g]
            ]
            # Snake alternate wafers so the ring closes over the border.
            tp_groups[g].extend(seg if w % 2 == 0 else seg[::-1])

    ftds: list[list[int]] = []
    for w in range(topo.n_wafers):
        for f in local.ftds:
            ftds.append(
                [
                    topo.device_id((wafer.coord(d)[0], wafer.coord(d)[1] + w * topo.cols))
                    for d in f
                ]
            )
    m = Mapping(topo, dp, tp, "her", tp_groups, ftds)
    return m


MAPPINGS = {
    "baseline": baseline_mapping,
    "er": er_mapping,
    "her": hierarchical_er_mapping,
}
