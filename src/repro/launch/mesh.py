"""Production mesh construction (+ ER-Mapping device placement).

``make_production_mesh`` is the canonical entry (16x16 per pod; 2 pods for
multi-pod). ``make_er_mesh`` applies the paper's Entwined Ring Mapping as a
*device-order permutation*: the logical ("data","model") axes are identical,
but TP groups land entwined on the physical torus so the model-axis rings
and the EP all-to-all traffic follow the paper's placement (the hop-distance
model this induces also drives the serving-side balancer — see
docs/serving.md, "Placement & topology").

Functions, not module constants — importing this module never touches jax
device state.
"""

from __future__ import annotations

import jax
import numpy as np


def _axis_type_kwargs(n: int) -> dict:
    """``axis_types=(Auto, ...)`` on jax versions that have it, {} otherwise
    (jax <= 0.4.x meshes are implicitly all-Auto)."""
    at = getattr(jax.sharding, "AxisType", None)
    return {"axis_types": (at.Auto,) * n} if at is not None else {}


def make_mesh_compat(shape, axes):
    """``jax.make_mesh`` with explicit Auto axis types where supported; the
    portable spelling for every mesh this repo builds (launchers + tests)."""
    try:
        return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))
    except TypeError:  # old make_mesh without axis_types
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_er_mesh(*, multi_pod: bool = False, mapping: str = "er"):
    """Production mesh with baseline/ER/HER physical placement.

    Each pod's 256 devices form a 16x16 grid; the chosen mapping's
    ``device_order()`` (dp=16 groups x tp=16 ranks) permutes them before the
    Mesh is built, so logical coordinates ("data" g, "model" r) sit at the
    physical position the paper's mapping prescribes.
    """
    from repro.core.er_mapping import MAPPINGS
    from repro.core.topology import MeshTopology

    topo = MeshTopology(16, 16)
    m = MAPPINGS[mapping](topo, 16, 16)
    order = m.device_order()                  # (16, 16) device ids in pod
    devices = np.array(jax.devices())
    n_pods = 2 if multi_pod else 1
    if devices.size < n_pods * 256:
        raise ValueError(
            f"need {n_pods * 256} devices, have {devices.size} "
            "(set XLA_FLAGS=--xla_force_host_platform_device_count=512)"
        )
    pods = []
    for p in range(n_pods):
        pod_devs = devices[p * 256 : (p + 1) * 256]
        pods.append(pod_devs[order])
    arr = np.stack(pods) if multi_pod else pods[0]
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    try:
        return jax.sharding.Mesh(arr, axes, **_axis_type_kwargs(len(axes)))
    except TypeError:  # old Mesh without axis_types
        return jax.sharding.Mesh(arr, axes)


def make_test_mesh(data: int = 2, model: int = 4):
    """Small mesh for CPU tests (requires forced host device count)."""
    return make_mesh_compat((data, model), ("data", "model"))
