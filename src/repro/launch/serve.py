"""Serving driver: batched generation with the NI-Balancer active.

Example (CPU, 8 fake devices):
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.serve --arch dbrx-132b --smoke \
      --requests 4 --prompt-len 16 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, smoke as smoke_cfg
from repro.kernels.registry import parse_use_kernels
from repro.launch.mesh import make_mesh_compat
from repro.core.topology import MeshTopology
from repro.models import transformer as T
from repro.parallel.ctx import ParallelCtx
from repro.runtime.data import request_stream
from repro.runtime.serve import ServeConfig, Server


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--batches", type=int, default=3)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--alpha", type=float, default=0.5)
    ap.add_argument(
        "--use-kernels", default="auto", choices=("auto", "on", "off"),
        help="Pallas kernel dispatch: auto=TPU only, on=everywhere "
        "(interpret off-TPU), off=einsum reference paths",
    )
    ap.add_argument(
        "--paged", action="store_true",
        help="paged KV cache: shared page pool + per-request block tables "
        "(decode HBM tracks live context, not max_seq)",
    )
    ap.add_argument("--page-size", type=int, default=128)
    ap.add_argument(
        "--pool-pages", type=int, default=None,
        help="oversubscribe the page pool (default: fully backed)",
    )
    ap.add_argument(
        "--ep-chunks", type=int, default=1,
        help="pipeline the EP dispatch/combine all_to_all legs against the "
        "fused expert FFN in this many expert-group chunks (must divide "
        "slots-per-device; 1 = single-shot dispatch)",
    )
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_cfg(cfg)

    uk = parse_use_kernels(args.use_kernels)
    n_dev = len(jax.devices())
    if n_dev > 1:
        m = max(d for d in (2, 4, 8, 16) if n_dev % d == 0 and d <= n_dev)
        mesh = make_mesh_compat((n_dev // m, m), ("data", "model"))
        ctx = ParallelCtx(mesh=mesh, capacity_factor=4.0, use_kernels=uk)
        # ER-Mapping hop distance on a model-axis ring mesh (for Algorithm 1).
        rows = int(np.sqrt(m)) if int(np.sqrt(m)) ** 2 == m else 1
        topo = MeshTopology(rows, m // rows)
        dist = lambda a, b: topo.hops(topo.coord(a), topo.coord(b))
    else:
        mesh = None
        ctx = ParallelCtx(use_kernels=uk)
        dist = None

    params = T.init_params(jax.random.PRNGKey(0), cfg)
    scfg = ServeConfig(
        max_seq=args.max_seq,
        batch=args.requests,
        slots_per_device=args.slots,
        alpha=args.alpha,
        paged=args.paged,
        page_size=args.page_size,
        pool_pages=args.pool_pages,
        ep_chunks=args.ep_chunks,
    )
    cm = mesh if mesh is not None else _null()
    with cm:
        server = Server(cfg, ctx, params, scfg, distance=dist)
        stream = request_stream(cfg.vocab_size, args.requests, args.prompt_len)
        for i, prompt in zip(range(args.batches), stream):
            embeds = None
            if cfg.frontend_stub:
                embeds = (
                    jax.random.normal(
                        jax.random.PRNGKey(i),
                        (args.requests, cfg.frontend_tokens, cfg.d_model),
                    )
                    * 0.02
                )
            t0 = time.time()
            out = server.generate(prompt, args.gen, embeds=embeds)
            dt = time.time() - t0
            tps = args.requests * args.gen / dt
            print(
                f"batch {i}: generated {out.shape} in {dt:.2f}s "
                f"({tps:.1f} tok/s), migrations so far: {server.migrations}"
            )
    print("done")


class _null:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()
