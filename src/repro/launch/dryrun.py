import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry run: lower + compile every (arch x shape x mesh) cell.

For each cell the appropriate step function is jitted against
ShapeDtypeStruct stand-ins (zero allocation):

* ``train_*``   -> ``make_train_step`` (fwd+bwd+AdamW, remat over layers)
* ``prefill_*`` -> ``transformer.prefill``
* ``decode_*`` / ``long_*`` -> ``transformer.decode_step`` (one token
  against a seq_len KV/state cache)

and we record ``compiled.memory_analysis()`` / ``cost_analysis()`` plus
collective bytes parsed from the post-SPMD HLO — the inputs to the §Roofline
analysis. Meshes: 16x16 ("data","model") single pod and 2x16x16
("pod","data","model"); optionally with the paper's ER-Mapping placement
permutation (--mapping er).

Usage:
  python -m repro.launch.dryrun --arch all --shape all --mesh both \
      --out results/dryrun
"""

import argparse
import functools
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, get_config, shapes_for
from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as T
from repro.parallel.ctx import ParallelCtx
from repro.parallel.sharding import (
    batch_spec_for,
    cache_specs,
    params_specs,
    state_specs,
    to_shardings,
)
from repro.launch.mesh import make_er_mesh, make_production_mesh
from repro.runtime.optimizer import AdamWConfig, adamw_init
from repro.runtime.train import make_train_step

from jax.sharding import PartitionSpec as P

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_DEF_RE = re.compile(
    r"(%[\w.\-]+)\s*=\s*(?:\()?([a-z][a-z0-9]*)\[([0-9,]*)\]"
)
_COLL_RE = re.compile(
    r"=\s+(?:\()?[a-z0-9]+\[[0-9,]*\][^=]*?\s"
    r"(all-reduce|all-gather|all-to-all|reduce-scatter|collective-permute)"
    r"(-start)?\((?P<args>[^)]*)\)"
)
_ARG_RE = re.compile(r"%[\w.\-]+")


def _bytes_of(dt: str, dims: str) -> int:
    if dt not in DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * DTYPE_BYTES[dt]


def collective_bytes(hlo: str) -> dict:
    """Sum *operand* bytes of every collective op in post-SPMD HLO text.

    HLO text doesn't inline operand types, so first build an SSA-name ->
    byte-size map from every definition line, then resolve collective
    operands through it. ``-done`` ops are skipped (their operand is the
    in-flight ``-start`` token, not fresh traffic). Values are PER-DEVICE
    (the compiled module is the per-device SPMD program).
    """
    sizes: dict[str, int] = {}
    for m in _DEF_RE.finditer(hlo):
        sizes[m.group(1)] = _bytes_of(m.group(2), m.group(3))
    out: dict[str, float] = {}
    for m in _COLL_RE.finditer(hlo):
        op = m.group(1)
        total = 0
        for arg in _ARG_RE.findall(m.group("args")):
            total += sizes.get(arg, 0)
        # wire-faithful weighting: ring all-reduce moves ~2x its operand
        # bytes (reduce-scatter + all-gather); the others move ~1x.
        if op == "all-reduce":
            total *= 2
        out[op] = out.get(op, 0) + total
    out["total"] = sum(out.values())
    return out


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; never allocated)
# ---------------------------------------------------------------------------

PARAM_DTYPE = jnp.bfloat16


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Model inputs for one workload cell."""
    b = shape.global_batch
    s = shape.seq_len
    tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
    specs = {}
    if shape.kind == "train":
        specs["tokens"] = tok
        specs["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    elif shape.kind == "prefill":
        specs["tokens"] = tok
    else:  # decode: one new token against a seq_len cache
        specs["tokens"] = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    if cfg.frontend_stub:
        specs["embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend_tokens, cfg.d_model), PARAM_DTYPE
        )
    return specs


def make_ctx(mesh, multi_pod: bool, batch: int, probe: bool = False) -> ParallelCtx:
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    n = 1
    for a in batch_axes:
        n *= mesh.shape[a]
    if batch % n:
        batch_axes = ()  # replicate tiny batches (long_500k B=1)
    return ParallelCtx(
        mesh=mesh,
        batch_axes=batch_axes,
        model_axis="model",
        remat=not probe,
        # §Perf iteration 2: 1.25 is the production sweet spot — dispatch
        # drops are negligible post-balancing while bucket-proportional
        # FLOPs and combine-psum bytes scale linearly with this.
        capacity_factor=1.25,
        # Probe mode: unrolled layer loops + dense attention so the cost
        # analysis counts every FLOP (while bodies are visited once); Pallas
        # custom calls are opaque to cost_analysis, so kernels stay off too.
        full_unroll=probe,
        force_dense_attn=probe,
        use_kernels=False if probe else "auto",
        # §Perf iteration 5 (REFUTED): seq-parallel residual constraints do
        # not convert the TP all-reduces into reduce-scatters under this
        # GSPMD version and add a small all-gather — kept off.
        seq_parallel_acts=False,
    )


# ---------------------------------------------------------------------------
# per-cell lowering
# ---------------------------------------------------------------------------

def lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, multi_pod: bool, probe: bool = False):
    ctx = make_ctx(mesh, multi_pod, shape.global_batch, probe)
    rng = jax.random.PRNGKey(0)

    params_sh = jax.eval_shape(
        functools.partial(T.init_params, cfg=cfg, dtype=PARAM_DTYPE), rng
    )
    p_specs = params_specs(cfg, params_sh, ctx)
    inputs = input_specs(cfg, shape)
    in_batch_spec = batch_spec_for(shape.global_batch, ctx)

    def tok_spec(x):
        return P(*([in_batch_spec] + [None] * (len(x.shape) - 1)))

    batch_specs = {k: tok_spec(v) for k, v in inputs.items()}

    if shape.kind == "train":
        opt_sh = jax.eval_shape(adamw_init, params_sh)
        state_sh = {"params": params_sh, "opt": opt_sh}
        st_specs = state_specs(cfg, state_sh, ctx)
        opt = AdamWConfig(total_steps=10_000)
        step = make_train_step(cfg, ctx, opt)
        jfn = jax.jit(
            step,
            in_shardings=(
                to_shardings(mesh, st_specs),
                to_shardings(mesh, batch_specs),
            ),
            donate_argnums=(0,),
        )
        args = (state_sh, inputs)
    elif shape.kind == "prefill":
        def pf(params, batch):
            return T.prefill(
                params,
                batch["tokens"],
                cfg,
                ctx,
                embeds=batch.get("embeds"),
                max_seq=shape.seq_len,
                dtype=PARAM_DTYPE,
            )
        cache_sh = jax.eval_shape(
            functools.partial(
                T.init_cache, cfg, shape.global_batch, shape.seq_len, PARAM_DTYPE
            )
        )
        c_specs = cache_specs(cfg, cache_sh, ctx, shape.global_batch)
        del cache_sh
        jfn = jax.jit(
            pf,
            in_shardings=(
                to_shardings(mesh, p_specs),
                to_shardings(mesh, batch_specs),
            ),
            out_shardings=(None, to_shardings(mesh, c_specs)),
        )
        args = (params_sh, inputs)
    else:  # decode
        cache_sh = jax.eval_shape(
            functools.partial(
                T.init_cache, cfg, shape.global_batch, shape.seq_len, PARAM_DTYPE
            )
        )
        c_specs = cache_specs(cfg, cache_sh, ctx, shape.global_batch)

        def dec(params, batch, cache):
            logits, new_cache, _stats = T.decode_step(
                params, batch["tokens"], cache, cfg, ctx
            )
            return logits, new_cache

        jfn = jax.jit(
            dec,
            in_shardings=(
                to_shardings(mesh, p_specs),
                to_shardings(mesh, batch_specs),
                to_shardings(mesh, c_specs),
            ),
            out_shardings=(None, to_shardings(mesh, c_specs)),
            donate_argnums=(2,),
        )
        args = (params_sh, inputs, cache_sh)

    t0 = time.time()
    lowered = jfn.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    return lowered, compiled, t_lower, t_compile


def analyze(compiled) -> dict:
    out = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        out["flops"] = float(ca.get("flops", -1))
        out["bytes_accessed"] = float(ca.get("bytes accessed", -1))
    except Exception as e:  # pragma: no cover
        out["cost_error"] = repr(e)
    try:
        ma = compiled.memory_analysis()
        for k in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "generated_code_size_in_bytes",
        ):
            if hasattr(ma, k):
                out[k] = int(getattr(ma, k))
    except Exception as e:  # pragma: no cover
        out["memory_error"] = repr(e)
    try:
        out["collectives"] = collective_bytes(compiled.as_text())
    except Exception as e:  # pragma: no cover
        out["collective_error"] = repr(e)
    return out


def layer_units(cfg: ModelConfig) -> float:
    """Scan trip count driving cost extrapolation (XLA's cost analysis
    visits a while body once, so loop costs must be scaled by hand)."""
    if cfg.block_pattern == "zamba":
        return cfg.n_layers / cfg.attn_every
    if cfg.block_pattern == "xlstm":
        return cfg.n_layers / 4
    return float(cfg.n_layers)


def with_units(cfg: ModelConfig, u: int) -> ModelConfig:
    import dataclasses

    if cfg.block_pattern == "zamba":
        return dataclasses.replace(cfg, n_layers=u * cfg.attn_every)
    if cfg.block_pattern == "xlstm":
        return dataclasses.replace(cfg, n_layers=4 * u)
    if cfg.block_pattern == "encdec":
        return dataclasses.replace(cfg, n_layers=u, n_encoder_layers=u)
    return dataclasses.replace(cfg, n_layers=u)


def run_cell(arch: str, shape_name: str, mesh_kind: str, mapping: str) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind}
    if shape_name == "long_500k" and not cfg.subquadratic:
        rec["status"] = "SKIP (full attention cannot fit the 524k context)"
        return rec
    multi_pod = mesh_kind == "multi"
    mesh = (
        make_er_mesh(multi_pod=multi_pod, mapping=mapping)
        if mapping != "none"
        else make_production_mesh(multi_pod=multi_pod)
    )
    try:
        with mesh:
            lowered, compiled, t_lower, t_compile = lower_cell(
                cfg, shape, mesh, multi_pod
            )
            rec.update(analyze(compiled))
            rec["t_lower_s"] = round(t_lower, 1)
            rec["t_compile_s"] = round(t_compile, 1)
            rec["n_devices"] = mesh.size
            rec["units"] = layer_units(cfg)
            del lowered, compiled
            # Layer-count probes: XLA cost analysis counts a scan body once,
            # so per-unit costs come from the u=2 minus u=1 delta.
            if rec["units"] > 2:
                for tag, u in (("probe1", 1), ("probe2", 2)):
                    _, c2, *_ = lower_cell(
                        with_units(cfg, u), shape, mesh, multi_pod, probe=True
                    )
                    a = analyze(c2)
                    rec[tag] = {
                        "flops": a.get("flops"),
                        "bytes_accessed": a.get("bytes_accessed"),
                        "collectives": a.get("collectives"),
                    }
                    del c2
            rec["status"] = "OK"
    except Exception as e:
        rec["status"] = f"FAIL: {type(e).__name__}"
        rec["error"] = traceback.format_exc()[-2000:]
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--mapping", default="er", choices=["er", "baseline", "none"])
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    archs = list(ARCHS) if args.arch == "all" else args.arch.split(",")
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    os.makedirs(args.out, exist_ok=True)

    for arch in archs:
        cfg = get_config(arch)
        shapes = (
            [s.name for s in shapes_for(cfg)] + (
                ["long_500k"] if not cfg.subquadratic else []
            )
            if args.shape == "all"
            else args.shape.split(",")
        )
        for shape_name in shapes:
            for mesh_kind in meshes:
                fname = os.path.join(
                    args.out, f"{arch}__{shape_name}__{mesh_kind}.json"
                )
                if os.path.exists(fname):
                    print(f"[skip existing] {fname}")
                    continue
                t0 = time.time()
                rec = run_cell(arch, shape_name, mesh_kind, args.mapping)
                rec["t_total_s"] = round(time.time() - t0, 1)
                with open(fname, "w") as f:
                    json.dump(rec, f, indent=1)
                coll = rec.get("collectives", {}).get("total", 0)
                print(
                    f"{arch:22s} {shape_name:12s} {mesh_kind:6s} "
                    f"{rec['status']:8s} flops={rec.get('flops', 0):.3g} "
                    f"coll={coll / 1e9:.2f}GB t={rec['t_total_s']}s",
                    flush=True,
                )


if __name__ == "__main__":
    main()
