"""Training driver: config-driven, fault-tolerant, mesh-aware.

Runs any ``--arch`` (full or ``--smoke`` reduction) on whatever devices
exist: single CPU for local runs, a forced host-device mesh for rehearsal,
or a real pod slice. Features wired in:

* deterministic resumable data pipeline (cursor in the checkpoint),
* async checkpointing every ``--ckpt-every`` steps + restore-on-start
  (elastic: restoring onto a different mesh re-places host arrays),
* straggler watch via StepTimer,
* optional DiLoCo-style compressed cross-pod sync every ``--pod-sync``
  steps when the mesh has a "pod" axis.

Example (CPU):
  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --smoke --steps 100 --batch 8 --seq 128
"""

from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, smoke as smoke_cfg
from repro.kernels.registry import parse_use_kernels
from repro.launch.mesh import make_mesh_compat
from repro.parallel.ctx import ParallelCtx
from repro.parallel.sharding import state_specs, to_shardings
from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.data import DataConfig, SyntheticLM
from repro.runtime.elastic import StepTimer
from repro.runtime.optimizer import AdamWConfig
from repro.runtime.train import init_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--pod-sync", type=int, default=0)
    ap.add_argument("--mesh", default="auto", help="auto|DxM e.g. 2x4")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument(
        "--use-kernels", default="auto", choices=("auto", "on", "off"),
        help="Pallas kernel dispatch: auto=TPU only, on=everywhere "
        "(interpret off-TPU), off=einsum reference paths",
    )
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_cfg(cfg)

    uk = parse_use_kernels(args.use_kernels)
    n_dev = len(jax.devices())
    if args.mesh != "auto":
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = make_mesh_compat((d, m), ("data", "model"))
        ctx = ParallelCtx(mesh=mesh, use_kernels=uk)
    elif n_dev > 1:
        m = 1
        while n_dev % (m * 2) == 0 and m * 2 <= 8:
            m *= 2
        mesh = make_mesh_compat((n_dev // m, m), ("data", "model"))
        ctx = ParallelCtx(mesh=mesh, use_kernels=uk)
    else:
        mesh = None
        ctx = ParallelCtx(use_kernels=uk)

    opt = AdamWConfig(lr=args.lr, warmup_steps=20, total_steps=args.steps)
    state = init_state(jax.random.PRNGKey(0), cfg)
    step_fn = make_train_step(cfg, ctx, opt)
    if mesh is not None:
        specs = state_specs(cfg, state, ctx)
        state = jax.device_put(state, to_shardings(mesh, specs))
        step_fn = jax.jit(step_fn, donate_argnums=(0,))
    else:
        step_fn = jax.jit(step_fn, donate_argnums=(0,))

    data = SyntheticLM(DataConfig(cfg.vocab_size, args.batch, args.seq))
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if mgr and mgr.latest() is not None:
        state, meta = mgr.restore(state)
        start = meta.get("data_step", meta["step"]) or 0
        print(f"[restore] resumed from step {start}")

    timer = StepTimer()
    ctxmgr = mesh if mesh is not None else _null()
    with ctxmgr:
        for step in range(start, args.steps):
            batch = data.batch_at(step)
            with timer:
                state, met = step_fn(state, batch)
                jax.block_until_ready(met["loss"])
            if timer.is_straggling:
                print(f"[straggler] step {step} took {timer.ratio:.2f}x EMA")
            if args.pod_sync and mesh is not None and "pod" in mesh.shape:
                if (step + 1) % args.pod_sync == 0:
                    from repro.parallel.grad_compress import compressed_pod_mean

                    state["params"] = compressed_pod_mean(state["params"], mesh)
            if step % args.log_every == 0 or step == args.steps - 1:
                print(
                    f"step {step:5d} loss {float(met['loss']):.4f} "
                    f"ce {float(met['ce']):.4f} gnorm {float(met['grad_norm']):.3f} "
                    f"lr {float(met['lr']):.2e} {timer.last:.2f}s"
                )
            if mgr and (step + 1) % args.ckpt_every == 0:
                mgr.async_save(step + 1, state, extra={"data_step": step + 1})
    if mgr:
        mgr.wait()
        mgr.save(args.steps, state, extra={"data_step": args.steps})
        print(f"[ckpt] final at {args.steps}")


class _null:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()
