"""Shared layer primitives: norms, RoPE, SwiGLU MLP, initializers."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel.ctx import ParallelCtx


def normal_init(key, shape, scale: float = 0.02, dtype=jnp.float32):
    return scale * jax.random.normal(key, shape, dtype=dtype)


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * w


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    freqs = rope_freqs(x.shape[-1], theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs    # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]                          # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP (TP-sharded hidden dim)
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, dtype=jnp.float32) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": normal_init(k1, (d_model, d_ff), dtype=dtype),
        "w_up": normal_init(k2, (d_model, d_ff), dtype=dtype),
        "w_down": normal_init(k3, (d_ff, d_model), dtype=dtype),
    }


def mlp_apply(p: dict, x: jax.Array, ctx: ParallelCtx) -> jax.Array:
    h = jnp.einsum("...d,df->...f", x, p["w_gate"])
    u = jnp.einsum("...d,df->...f", x, p["w_up"])
    h = ctx.shard(jax.nn.silu(h) * u, *(None,) * (x.ndim - 1), ctx.model_axis)
    return jnp.einsum("...f,fd->...d", h, p["w_down"])
