"""State-space / recurrent blocks: Mamba2 (zamba2) and xLSTM (mLSTM+sLSTM).

All blocks expose three entry points with a common cache convention:

* ``*_apply(p, x, ...)``         — full-sequence train/prefill; returns
  ``(y, final_state)`` so prefill can seed the decode cache.
* ``*_decode(p, x1, state, ...)``— one-token step, O(1) in context length
  (this is what makes the ``long_500k`` cell run for these families).

Time recurrences use ``jax.lax.scan`` over the sequence; the carries are
the decode states, so prefill/decode consistency is by construction.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import normal_init

CONV_W = 4  # causal conv width (Mamba2)


# ---------------------------------------------------------------------------
# Mamba2 (simplified SSD: scalar decay per head, shared B/C group)
# ---------------------------------------------------------------------------

def mamba_dims(cfg: ModelConfig) -> tuple[int, int, int, int]:
    d_inner = 2 * cfg.d_model
    head = 64 if d_inner % 64 == 0 else d_inner
    n_heads = d_inner // head
    return d_inner, head, n_heads, cfg.ssm_state


def mamba_init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    d_inner, _, n_heads, n = mamba_dims(cfg)
    ks = jax.random.split(key, 5)
    return {
        # Separate projections (not one fused w_in) so each output dim
        # shards cleanly on the model axis without split-boundary reshards.
        "w_z": normal_init(ks[0], (d, d_inner), dtype=dtype),
        "w_xbc": normal_init(ks[3], (d, d_inner + 2 * n), dtype=dtype),
        "w_dt": normal_init(ks[4], (d, n_heads), dtype=dtype),
        "conv_w": normal_init(ks[1], (CONV_W, d_inner + 2 * n), dtype=dtype),
        "conv_b": jnp.zeros((d_inner + 2 * n,), dtype=dtype),
        "a_log": jnp.zeros((n_heads,), dtype=jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), dtype=jnp.float32),
        "d_skip": jnp.ones((n_heads,), dtype=jnp.float32),
        "w_out": normal_init(ks[2], (d_inner, d), dtype=dtype),
        "norm_w": jnp.ones((d_inner,), dtype=dtype),
    }


def _mamba_proj(p: dict, x: jax.Array, cfg: ModelConfig):
    z = jnp.einsum("btd,de->bte", x, p["w_z"])
    xbc = jnp.einsum("btd,de->bte", x, p["w_xbc"])
    dt = jnp.einsum("btd,de->bte", x, p["w_dt"])
    return z, xbc, dt


def _conv_causal(p: dict, xbc: jax.Array, conv_state: jax.Array | None):
    """Depthwise causal conv over time; returns output + new conv state."""
    pad = (
        conv_state
        if conv_state is not None
        else jnp.zeros((xbc.shape[0], CONV_W - 1, xbc.shape[-1]), xbc.dtype)
    )
    full = jnp.concatenate([pad, xbc], axis=1)
    out = sum(
        full[:, i : i + xbc.shape[1]] * p["conv_w"][i] for i in range(CONV_W)
    ) + p["conv_b"]
    new_state = full[:, -(CONV_W - 1) :]
    return jax.nn.silu(out), new_state


def _ssm_scan(p, xh, b, c, dt, cfg, state0):
    """h_t = exp(A dt_t) h_{t-1} + dt_t x_t B_t^T ; y_t = h_t C_t + D x_t."""
    _, head, n_heads, n = mamba_dims(cfg)
    bt, t = xh.shape[0], xh.shape[1]
    a = -jnp.exp(p["a_log"])                                     # (H,)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,T,H)
    xh = xh.reshape(bt, t, n_heads, head)

    def step(h, inp):
        x_t, b_t, c_t, dt_t = inp                                # (B,H,hd),(B,N),(B,N),(B,H)
        decay = jnp.exp(a * dt_t)[..., None, None]               # (B,H,1,1)
        upd = (dt_t[..., None] * x_t)[..., None] * b_t[:, None, None, :]
        h = decay * h + upd                                      # (B,H,hd,N)
        y = jnp.einsum("bhdn,bn->bhd", h, c_t)
        return h, y

    xs = (
        xh.transpose(1, 0, 2, 3).astype(jnp.float32),
        b.transpose(1, 0, 2).astype(jnp.float32),
        c.transpose(1, 0, 2).astype(jnp.float32),
        dt.transpose(1, 0, 2),
    )
    h_last, ys = jax.lax.scan(step, state0, xs)
    y = ys.transpose(1, 0, 2, 3)                                 # (B,T,H,hd)
    y = y + p["d_skip"][:, None] * xh.astype(jnp.float32)
    return y.reshape(bt, t, -1), h_last


def mamba_state_init(cfg: ModelConfig, batch: int) -> dict:
    d_inner, head, n_heads, n = mamba_dims(cfg)
    return {
        "conv": jnp.zeros((batch, CONV_W - 1, d_inner + 2 * n), jnp.float32),
        "ssm": jnp.zeros((batch, n_heads, head, n), jnp.float32),
    }


def mamba_apply(p: dict, x: jax.Array, cfg: ModelConfig, state: dict | None = None):
    d_inner, _, _, n = mamba_dims(cfg)
    if state is None:
        state = mamba_state_init(cfg, x.shape[0])
    z, xbc, dt = _mamba_proj(p, x, cfg)
    xbc, conv_state = _conv_causal(p, xbc, state["conv"])
    xh, b, c = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)
    y, h_last = _ssm_scan(p, xh, b, c, dt, cfg, state["ssm"])
    y = y.astype(x.dtype) * jax.nn.silu(z)
    # RMS-norm before out-proj (Mamba2 style).
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), -1, keepdims=True)
    y = (y * jax.lax.rsqrt(var + 1e-5).astype(y.dtype)) * p["norm_w"]
    out = jnp.einsum("bte,ed->btd", y, p["w_out"])
    return out, {"conv": conv_state, "ssm": h_last}


def mamba_decode(p: dict, x1: jax.Array, state: dict, cfg: ModelConfig):
    """x1: (B, 1, d) — one token; O(1) state update."""
    return mamba_apply(p, x1, cfg, state)


# ---------------------------------------------------------------------------
# xLSTM: mLSTM (matrix memory) and sLSTM (scalar memory) blocks
# ---------------------------------------------------------------------------

def xlstm_dims(cfg: ModelConfig) -> tuple[int, int]:
    return cfg.n_heads, cfg.d_model // cfg.n_heads


def mlstm_init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    h, _ = xlstm_dims(cfg)
    ks = jax.random.split(key, 3)
    return {
        "w_qkv": normal_init(ks[0], (d, 3 * d), dtype=dtype),
        "w_gates": normal_init(ks[1], (d, 2 * h), dtype=dtype, scale=0.01),
        "b_gates": jnp.zeros((2 * h,), dtype=jnp.float32),
        "w_out": normal_init(ks[2], (d, d), dtype=dtype),
        "norm_w": jnp.ones((d,), dtype=dtype),
    }


def mlstm_state_init(cfg: ModelConfig, batch: int) -> dict:
    h, hd = xlstm_dims(cfg)
    return {
        "C": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, h, hd), jnp.float32),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
    }


def mlstm_apply(p: dict, x: jax.Array, cfg: ModelConfig, state: dict | None = None):
    bsz, t, d = x.shape
    h, hd = xlstm_dims(cfg)
    if state is None:
        state = mlstm_state_init(cfg, bsz)
    qkv = jnp.einsum("btd,de->bte", x, p["w_qkv"]).reshape(bsz, t, 3, h, hd)
    q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
    gates = jnp.einsum("btd,de->bte", x, p["w_gates"]).astype(jnp.float32) + p["b_gates"]
    log_i, log_f = gates[..., :h], jax.nn.log_sigmoid(gates[..., h:])

    def step(carry, inp):
        c_s, n_s, m_s = carry
        q_t, k_t, v_t, li, lf = inp                       # (B,H,hd)x3, (B,H)x2
        m_new = jnp.maximum(lf + m_s, li)
        f_t = jnp.exp(lf + m_s - m_new)[..., None]
        i_t = jnp.exp(li - m_new)[..., None]
        k32, v32 = k_t.astype(jnp.float32), v_t.astype(jnp.float32)
        c_s = f_t[..., None] * c_s + i_t[..., None] * (
            v32[..., :, None] * k32[..., None, :]
        )
        n_s = f_t * n_s + i_t * k32
        q32 = q_t.astype(jnp.float32) / jnp.sqrt(hd)
        num = jnp.einsum("bhvk,bhk->bhv", c_s, q32)
        den = jnp.abs(jnp.einsum("bhk,bhk->bh", n_s, q32))
        y = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
        return (c_s, n_s, m_new), y

    xs = (
        q.transpose(1, 0, 2, 3),
        k.transpose(1, 0, 2, 3),
        v.transpose(1, 0, 2, 3),
        log_i.transpose(1, 0, 2),
        log_f.transpose(1, 0, 2),
    )
    (c_s, n_s, m_s), ys = jax.lax.scan(step, (state["C"], state["n"], state["m"]), xs)
    y = ys.transpose(1, 0, 2, 3).reshape(bsz, t, d).astype(x.dtype)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), -1, keepdims=True)
    y = (y * jax.lax.rsqrt(var + 1e-5).astype(y.dtype)) * p["norm_w"]
    out = jnp.einsum("btd,de->bte", y, p["w_out"])
    return out, {"C": c_s, "n": n_s, "m": m_s}


def slstm_init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    h, hd = xlstm_dims(cfg)
    ks = jax.random.split(key, 4)
    return {
        "w_in": normal_init(ks[0], (d, 4 * d), dtype=dtype),        # z,i,f,o
        "r_block": normal_init(ks[1], (h, hd, 4 * hd), dtype=dtype, scale=0.01),
        "b_in": jnp.zeros((4 * d,), dtype=jnp.float32),
        "w_out": normal_init(ks[2], (d, d), dtype=dtype),
        "norm_w": jnp.ones((d,), dtype=dtype),
    }


def slstm_state_init(cfg: ModelConfig, batch: int) -> dict:
    h, hd = xlstm_dims(cfg)
    return {
        "c": jnp.zeros((batch, h, hd), jnp.float32),
        "n": jnp.ones((batch, h, hd), jnp.float32),
        "m": jnp.zeros((batch, h, hd), jnp.float32),
        "h": jnp.zeros((batch, h, hd), jnp.float32),
    }


def slstm_apply(p: dict, x: jax.Array, cfg: ModelConfig, state: dict | None = None):
    bsz, t, d = x.shape
    h, hd = xlstm_dims(cfg)
    if state is None:
        state = slstm_state_init(cfg, bsz)
    wx = jnp.einsum("btd,de->bte", x, p["w_in"]).astype(jnp.float32) + p["b_in"]
    wx = wx.reshape(bsz, t, h, 4 * hd)

    def step(carry, wx_t):
        c_s, n_s, m_s, h_s = carry
        rec = jnp.einsum("bhk,hke->bhe", h_s, p["r_block"].astype(jnp.float32))
        z, i, f, o = jnp.split(wx_t + rec, 4, axis=-1)     # (B,H,hd) each
        li, lf = i, jax.nn.log_sigmoid(f)
        m_new = jnp.maximum(lf + m_s, li)
        i_t = jnp.exp(li - m_new)
        f_t = jnp.exp(lf + m_s - m_new)
        c_s = f_t * c_s + i_t * jnp.tanh(z)
        n_s = f_t * n_s + i_t
        h_s = jax.nn.sigmoid(o) * c_s / jnp.maximum(n_s, 1e-6)
        return (c_s, n_s, m_new, h_s), h_s

    (c_s, n_s, m_s, h_s), ys = jax.lax.scan(
        step, (state["c"], state["n"], state["m"], state["h"]), wx.transpose(1, 0, 2, 3)
    )
    y = ys.transpose(1, 0, 2, 3).reshape(bsz, t, d).astype(x.dtype)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), -1, keepdims=True)
    y = (y * jax.lax.rsqrt(var + 1e-5).astype(y.dtype)) * p["norm_w"]
    out = jnp.einsum("btd,de->bte", y, p["w_out"])
    return out, {"c": c_s, "n": n_s, "m": m_s, "h": h_s}
