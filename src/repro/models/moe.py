"""MoE layer: router, expert FFNs, and three parallel implementations.

* ``dense``  — reference oracle: every expert computed for every token,
  masked combine. Exact; used by tests and tiny smoke configs.
* ``ep``     — expert parallelism: shard_map all_to_all dispatch into
  fixed-capacity per-slot buckets (the paper's deployment; supports shadow
  replicas via the traced placement table).
* ``esp``    — expert-sharding parallelism (paper §VI-B5): every device
  holds a 1/tp slice of *all* experts' FFN dims; tokens are bucketed by
  expert locally (no all-to-all) and partial sums all-reduce over the model
  axis. Used when ``n_experts`` doesn't divide the EP axis (Mixtral/DBRX on
  wide meshes) — exactly the regime the paper assigns to ESP.

The auxiliary load-balancing loss (Switch-style) is returned alongside.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import registry
from repro.models.layers import normal_init
from repro.parallel.collectives import (
    bucket_capacity,
    bucket_combine,
    bucket_dispatch,
    combine_from_rows,
    dispatch_metadata,
    ep_moe_local,
    ep_moe_shardmap,
    esp_expert_ffn,
    kept_counts,
    tiled_placement,
    uniform_placement,
    validate_ep_chunks,
)
from repro.parallel.ctx import ParallelCtx
from repro.parallel.placement import PlacementTable


def moe_init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    e, d, f = cfg.n_experts, cfg.d_model, cfg.moe_d_ff_
    kr, kg, ku, kd = jax.random.split(key, 4)
    return {
        "router": normal_init(kr, (d, e), dtype=jnp.float32),  # fp32 router
        "w_gate": normal_init(kg, (e, d, f), dtype=dtype),
        "w_up": normal_init(ku, (e, d, f), dtype=dtype),
        "w_down": normal_init(kd, (e, f, d), dtype=dtype),
    }


def route(
    p: dict, x: jax.Array, cfg: ModelConfig
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Top-k routing. Returns (expert_ids, weights, aux_loss)."""
    logits = jnp.einsum("...d,de->...e", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, cfg.experts_per_token)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    # Switch-style aux loss: E * sum_e fraction_tokens_e * mean_prob_e.
    e = cfg.n_experts
    one_hot = jax.nn.one_hot(ids, e, dtype=jnp.float32)     # (..., k, E)
    frac = jnp.mean(jnp.sum(one_hot, axis=-2).reshape(-1, e), axis=0)
    mean_prob = jnp.mean(probs.reshape(-1, e), axis=0)
    aux = e * jnp.sum(frac * mean_prob)
    return ids, weights.astype(x.dtype), aux


def zero_aux(cfg: ModelConfig) -> dict:
    """Aux accumulator template (works for dense archs too)."""
    return {
        "loss": jnp.zeros((), jnp.float32),
        "counts": jnp.zeros((max(cfg.n_experts, 1),), jnp.float32),
    }


def _aux(loss, ids, cfg: ModelConfig) -> dict:
    counts = jnp.bincount(
        ids.reshape(-1), length=max(cfg.n_experts, 1)
    ).astype(jnp.float32)
    return {"loss": loss, "counts": counts}


# ---------------------------------------------------------------------------
# dense oracle
# ---------------------------------------------------------------------------

def _mask_ids(ids, token_mask, cfg: ModelConfig):
    """Route masked tokens (empty serving slots) to the out-of-range expert
    id E: every dispatch drops the sentinel (and ``one_hot`` zeroes it), so
    dead batch rows consume no bucket capacity, contribute zero output and
    never pollute the balancer's expert counts."""
    if token_mask is None:
        return ids
    return jnp.where(token_mask[..., None], ids, cfg.n_experts)


def moe_dense(
    p: dict, x: jax.Array, cfg: ModelConfig, ctx: ParallelCtx, token_mask=None
):
    ids, w, aux = route(p, x, cfg)
    ids = _mask_ids(ids, token_mask, cfg)
    h = jnp.einsum("...d,edf->...ef", x, p["w_gate"])
    u = jnp.einsum("...d,edf->...ef", x, p["w_up"])
    y = jnp.einsum("...ef,efd->...ed", jax.nn.silu(h) * u, p["w_down"])
    mask = jax.nn.one_hot(ids, cfg.n_experts, dtype=w.dtype)       # (...,k,E)
    comb = jnp.einsum("...ke,...k->...e", mask, w)
    out = jnp.einsum("...ed,...e->...d", y, comb)
    return out, _aux(aux, ids, cfg)


# ---------------------------------------------------------------------------
# ESP: expert-sharded FFN, local bucketing, all-reduce combine
# ---------------------------------------------------------------------------

def moe_esp(
    p: dict, x: jax.Array, cfg: ModelConfig, ctx: ParallelCtx, token_mask=None
):
    """Experts' hidden dims sharded over the model axis (GSPMD handles the
    partial-sum all-reduce of w_down). Tokens are bucketed per expert so
    FLOPs stay ~topk * capacity_factor, not n_experts.

    Dispatch is *group-local*: tokens are reshaped so the leading group dim
    aligns with the batch sharding, each data shard sorts/scatters only its
    own tokens, and every bucket tensor keeps the group dim sharded. Without
    this, GSPMD replicates the global buckets across all data rows —
    redundant expert FLOPs x n_batch and a giant dispatch all-gather (see
    EXPERIMENTS.md §Perf, mixtral hillclimb)."""
    ids, w, aux = route(p, x, cfg)
    ids = _mask_ids(ids, token_mask, cfg)
    b, s, d = x.shape
    k = cfg.experts_per_token
    e = cfg.n_experts
    # Validate up front so a bad chunk count fails loudly on every branch;
    # only the no-mesh fused branch below actually pipelines (the padded /
    # meshed layouts keep the single-shot grouped FFN).
    kc = validate_ep_chunks(getattr(ctx, "ep_chunks", 1), where="moe_esp")
    if kc > 1:
        validate_ep_chunks(kc, e, where="moe_esp n_experts")
    groups = ctx.n_batch if (ctx.mesh is not None and b % ctx.n_batch == 0) else 1
    n_loc = (b // groups) * s
    cap = bucket_capacity(n_loc, k, ctx.capacity_factor, e)

    f = cfg.moe_d_ff_
    if (
        ctx.mesh is None
        and ctx.kernels_on
        and registry.can_gmm_gather(cap, d, f, registry.default_interpret())
    ):
        # Fused dispatch-gather path (single group, no mesh): the gather
        # GMM reads token rows straight from the flat activations via
        # per-expert offsets, and the scatter epilogue (compact_out) writes
        # the down-projection back at the same offsets — neither the
        # (E, cap, d) dispatch buffer nor the padded FFN output is ever
        # materialized; the combine gathers each kept copy's row through
        # the same metadata. fused=True additionally collapses the three
        # matmuls into one kernel when can_gmm_fused accepts the shapes,
        # keeping the (E, cap, F) hidden tensor in VMEM (registry falls
        # back to the gather+scatter pair otherwise).
        ids2 = ids.reshape(b * s, k)
        row_ids, offsets, counts, slots, keep = dispatch_metadata(ids2, e, cap)
        rows = x.reshape(b * s, d)[row_ids]
        # ep_chunks on the no-mesh path: split the experts into kc chunks
        # and run the fused row FFN per chunk over sliced offsets/counts/
        # weights — the offsets stay absolute into the one flat rows array,
        # so each chunk's call writes its buckets' segments at the same
        # coordinates the single-shot call would. The chunk outputs are
        # merged by each row's owning expert chunk (a select, no
        # arithmetic), and the ONE combine below is untouched — the result
        # is bit-identical to ep_chunks=1.
        epc = e // kc

        def chunk_ffn(c):
            ws = slice(c * epc, (c + 1) * epc)
            return registry.expert_ffn_from_rows(
                rows,
                p["w_gate"][ws],
                p["w_up"][ws],
                p["w_down"][ws],
                offsets[ws],
                counts[ws],
                capacity=cap,
                enabled=True,
                compact_out=True,
                fused=True,
            )

        y = chunk_ffn(0)
        if kc > 1:
            # Owning bucket of each compacted row (offsets are the buckets'
            # first rows); rows past the live span — sentinel copies — map
            # to the last chunk and are never addressed by the combine.
            r_idx = jnp.arange(rows.shape[0], dtype=jnp.int32)
            owner = jnp.searchsorted(offsets, r_idx, side="right") - 1
            owner_c = jnp.clip(owner, 0, e - 1) // epc
            for c in range(1, kc):
                y = jnp.where((owner_c == c)[:, None], chunk_ffn(c), y)
        out = combine_from_rows(
            y, offsets[ids2] + slots, keep, w.reshape(b * s, k)
        )
        return out.reshape(b, s, d), _aux(aux, ids, cfg)

    bspec = ctx.batch_spec
    xg = ctx.shard(x.reshape(groups, n_loc, d), bspec, None, None)
    idg = ids.reshape(groups, n_loc, k)
    wtg = w.reshape(groups, n_loc, k)
    bufs, slots, keep = jax.vmap(
        lambda xx, ii: bucket_dispatch(xx, ii, e, cap)
    )(xg, idg)
    bufs = ctx.shard(bufs, bspec, None, None, None)     # (G, E, cap, d)
    tp = ctx.n_model
    kernel_ok = ctx.kernels_on and (
        ctx.mesh is None
        or (d % tp == 0 and f % tp == 0 and groups % ctx.n_batch == 0)
    )
    if kernel_ok:
        # Count-aware kernel path: the ragged GMM skips capacity rows past
        # each bucket's fill, so FFN FLOPs track tokens actually routed.
        counts = jax.vmap(lambda ii, kk: kept_counts(ii, kk, e))(idg, keep)
        y = esp_expert_ffn(
            bufs, counts, p["w_gate"], p["w_up"], p["w_down"], ctx
        )
    else:
        wg = ctx.shard(p["w_gate"], None, None, ctx.model_axis)
        wu = ctx.shard(p["w_up"], None, None, ctx.model_axis)
        wd = ctx.shard(p["w_down"], None, ctx.model_axis, None)
        h = jnp.einsum("gecd,edf->gecf", bufs, wg)
        u = jnp.einsum("gecd,edf->gecf", bufs, wu)
        h = ctx.shard(jax.nn.silu(h) * u, bspec, None, None, ctx.model_axis)
        y = jnp.einsum("gecf,efd->gecd", h, wd)
    # Reduce-scatter (d-sharded) instead of a full all-reduce of the padded
    # buckets; the all-gather happens after combine, on the much smaller
    # per-token tensor (§Perf iteration 3).
    y = ctx.shard(y, bspec, None, None, ctx.model_axis)
    out = jax.vmap(bucket_combine)(y, idg, slots, keep, wtg)
    out = ctx.shard(out, bspec, None, None)
    return out.reshape(b, s, d), _aux(aux, ids, cfg)


# ---------------------------------------------------------------------------
# EP via shard_map (paper-faithful dispatch)
# ---------------------------------------------------------------------------

def moe_ep(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    ctx: ParallelCtx,
    placement: PlacementTable | tuple[jax.Array, jax.Array] | None = None,
    slot_weights: dict | None = None,
    slots_per_device: int | None = None,
    token_mask=None,
):
    """Expert-parallel dispatch over the model axis (or, with no mesh, the
    local single-process equivalent — see ``ep_moe_local``).

    ``placement`` is a :class:`PlacementTable` (the serving substrate; its
    committed :meth:`~PlacementTable.device_view` is what routes) or a bare
    ``(slot_of, n_replicas)`` pair; default = native homes. For serving
    with shadow slots the Server owns ``slot_weights`` (n_slots rows,
    possibly > n_experts) and updates replica rows out-of-band; the
    default materializes slots from the logical experts (slot i = expert
    i % E)."""
    ep = ctx.n_model
    e = cfg.n_experts
    n_rows = p["w_gate"].shape[0]  # physical slot rows (>= n_experts when
    # the Server pre-expanded shadow slots)
    tiled = False
    if slot_weights is None:
        slots_per_device = slots_per_device or max(-(-n_rows // ep), 1)
        n_slots = ep * slots_per_device
        if n_slots < n_rows:
            raise ValueError(
                f"slots_per_device={slots_per_device} gives {n_slots} physical "
                f"slots < {n_rows} weight rows — experts would be dropped; "
                f"need at least ceil(n_rows / ep) = {-(-n_rows // ep)}"
            )
        if n_slots == n_rows:
            slot_weights = p  # slot i holds weight row i (identity)
        else:
            # Wrap-around shadow slots: slot i holds weight row i % n_rows
            # (covers both n_rows % ep != 0 and an explicitly larger
            # slots_per_device).
            reps = -(-n_slots // n_rows)
            slot_weights = {
                k2: jnp.tile(p[k2], (reps, 1, 1))[:n_slots]
                for k2 in ("w_gate", "w_up", "w_down")
            }
            tiled = True
    n_slots = ep * slots_per_device
    if isinstance(placement, PlacementTable):
        placement = placement.device_view()   # committed routing view only
    if placement is None:
        if tiled:
            # The tile above put weight row ``s % n_rows`` on slot ``s`` —
            # the default placement must route expert e to exactly those
            # slots (every s with s % n_rows == e), or the wrap-around
            # shadow slots would hold live weights that never see traffic
            # while still inflating the capacity denominator.
            slot_of, n_replicas = tiled_placement(e, n_rows, n_slots)
        else:
            slot_of, n_replicas = uniform_placement(e, n_slots)
    else:
        slot_of, n_replicas = placement

    ids, w, aux = route(p, x, cfg)
    ids = _mask_ids(ids, token_mask, cfg)
    if ctx.mesh is None:
        out = ep_moe_local(
            x,
            ids,
            w,
            slot_weights,
            slot_of,
            n_replicas,
            ctx,
            ctx.capacity_factor,
            n_slots,
        )
    else:
        out = ep_moe_shardmap(
            x,
            ids,
            w,
            slot_weights,
            slot_of,
            n_replicas,
            ctx,
            ctx.capacity_factor,
            slots_per_device,
            decode=x.shape[1] == 1,
        )
    return out, _aux(aux, ids, cfg)


def moe_apply(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    ctx: ParallelCtx,
    placement=None,
    token_mask=None,
):
    """``token_mask`` (bool, broadcastable to ``x.shape[:-1]``): False rows
    are dead serving slots — they route nowhere (no bucket capacity spent,
    zero MoE output, excluded from the balancer counts)."""
    impl = ctx.moe_impl
    if impl == "auto":
        if ctx.mesh is None:
            impl = "dense"
        elif cfg.n_experts % ctx.n_model == 0:
            # E/D >= 1: expert parallelism (decode uses owned-token dispatch).
            impl = "ep"
        else:
            # E/D < 1: ESP — the paper's choice for few-large-expert models.
            impl = "esp"
    if impl == "dense":
        return moe_dense(p, x, cfg, ctx, token_mask=token_mask)
    if impl == "esp":
        return moe_esp(p, x, cfg, ctx, token_mask=token_mask)
    if impl == "ep":
        return moe_ep(p, x, cfg, ctx, placement, token_mask=token_mask)
    raise ValueError(f"unknown moe impl {impl!r}")
