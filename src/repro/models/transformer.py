"""Model assembly: init / forward (train) / prefill / decode for every
assigned architecture family.

Block patterns (``cfg.block_pattern``):

* ``attn``   — decoder-only transformer; per-layer FFN is dense SwiGLU or
  MoE (``cfg.n_experts > 0``). Layers are *stacked* and driven by
  ``lax.scan`` so HLO size is independent of depth.
* ``zamba``  — units of ``attn_every`` Mamba2 layers followed by one
  invocation of a single *shared* attention+MLP block (Zamba2 signature);
  trailing Mamba2 layers close the stack.
* ``xlstm``  — units of 3 mLSTM + 1 sLSTM blocks (requires depth % 4 == 0).
* ``encdec`` — bidirectional encoder over stub frontend embeddings +
  causal decoder with cross-attention (seamless-m4t).

Frontend stubs (``cfg.frontend_stub``): precomputed frame/patch embeddings
arrive as an input and are prepended (vlm) or encoded (audio).

Caches: a dict with per-pattern stacked leaves + scalar ``pos``; decode is
one token per call. SSM caches are O(1) in context length.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import ssm
from repro.models.attention import (
    PAGE_SIZE,
    attn_init,
    attention,
    cache_init,
    chunk_prefill_attention,
    cross_attention,
    cross_kv,
    decode_attention,
    is_paged,
    paged_cache_init,
    paged_prefill_fill,
)
from repro.models.layers import mlp_apply, mlp_init, normal_init, rms_norm
from repro.models.moe import moe_apply, moe_init, zero_aux
from repro.parallel.ctx import NO_MESH, ParallelCtx

XLSTM_UNIT_M = 3  # mLSTM blocks per unit (then 1 sLSTM)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _attn_block_init(key, cfg: ModelConfig, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "attn": attn_init(k1, cfg, dtype),
    }
    if cfg.is_moe:
        p["moe"] = moe_init(k2, cfg, dtype)
    else:
        p["mlp"] = mlp_init(k2, cfg.d_model, cfg.d_ff, dtype)
    return p


def _encdec_block_init(key, cfg: ModelConfig, dtype, cross: bool) -> dict:
    ks = jax.random.split(key, 3)
    p = {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "attn": attn_init(ks[0], cfg, dtype),
        "mlp": mlp_init(ks[1], cfg.d_model, cfg.d_ff, dtype),
    }
    if cross:
        p["ln_x"] = jnp.ones((cfg.d_model,), dtype)
        p["xattn"] = attn_init(ks[2], cfg, dtype)
    return p


def _stack(init_fn, key, n: int):
    keys = jax.random.split(key, max(n, 1))
    return jax.tree.map(lambda *xs: jnp.stack(xs), *[init_fn(k) for k in keys[:n]]) if n else None


def zamba_layout(cfg: ModelConfig) -> tuple[int, int]:
    """(n_units, n_trailing_mamba)."""
    u = cfg.n_layers // cfg.attn_every
    return u, cfg.n_layers - u * cfg.attn_every


def init_params(rng, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    keys = jax.random.split(rng, 8)
    d = cfg.d_model
    params: dict = {
        "embed": normal_init(keys[0], (cfg.vocab_size, d), dtype=dtype),
        "final_norm": jnp.ones((d,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = normal_init(keys[1], (d, cfg.vocab_size), dtype=dtype)

    pat = cfg.block_pattern
    if pat == "attn":
        params["layers"] = _stack(
            lambda k: _attn_block_init(k, cfg, dtype), keys[2], cfg.n_layers
        )
    elif pat == "zamba":
        u, r = zamba_layout(cfg)
        mamba_one = lambda k: {
            "ln": jnp.ones((d,), dtype),
            "mamba": ssm.mamba_init(k, cfg, dtype),
        }
        params["units"] = _stack(
            lambda k: _stack(mamba_one, k, cfg.attn_every), keys[2], u
        )
        params["trailing"] = _stack(mamba_one, keys[3], r)
        params["shared"] = _encdec_block_init(keys[4], cfg, dtype, cross=False)
    elif pat == "xlstm":
        assert cfg.n_layers % (XLSTM_UNIT_M + 1) == 0, "xlstm depth % 4 != 0"
        u = cfg.n_layers // (XLSTM_UNIT_M + 1)
        m_one = lambda k: {
            "ln": jnp.ones((d,), dtype),
            "m": ssm.mlstm_init(k, cfg, dtype),
        }
        s_one = lambda k: {
            "ln": jnp.ones((d,), dtype),
            "s": ssm.slstm_init(k, cfg, dtype),
        }
        params["units"] = {
            "m": _stack(lambda k: _stack(m_one, k, XLSTM_UNIT_M), keys[2], u),
            "s": _stack(s_one, keys[3], u),
        }
    elif pat == "encdec":
        params["encoder"] = _stack(
            lambda k: _encdec_block_init(k, cfg, dtype, cross=False),
            keys[2],
            cfg.n_encoder_layers,
        )
        params["layers"] = _stack(
            lambda k: _encdec_block_init(k, cfg, dtype, cross=True),
            keys[3],
            cfg.n_layers,
        )
        params["enc_norm"] = jnp.ones((d,), dtype)
    else:
        raise ValueError(pat)
    return params


# ---------------------------------------------------------------------------
# blocks (full-sequence)
# ---------------------------------------------------------------------------

def _attn_block(p, x, cfg, ctx, positions):
    sp = ctx.seq_spec  # seq-parallel residual stream (retained-AG pattern)
    x = ctx.shard(x, ctx.batch_spec, sp, None)
    o = attention(p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps), cfg, ctx, positions)
    h = x + ctx.shard(o, ctx.batch_spec, sp, None)
    z = rms_norm(h, p["ln2"], cfg.norm_eps)
    if cfg.is_moe:
        y, aux = moe_apply(p["moe"], z, cfg, ctx)
    else:
        y, aux = mlp_apply(p["mlp"], z, ctx), zero_aux(cfg)
    return h + ctx.shard(y, ctx.batch_spec, sp, None), aux


def _enc_block(p, x, cfg, ctx):
    h = x + attention(
        p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps), cfg, ctx, causal=False
    )
    return h + mlp_apply(p["mlp"], rms_norm(h, p["ln2"], cfg.norm_eps), ctx)


def _dec_block(p, x, kv, cfg, ctx, positions):
    h = x + attention(p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps), cfg, ctx, positions)
    h = h + cross_attention(p["xattn"], rms_norm(h, p["ln_x"], cfg.norm_eps), kv, cfg, ctx)
    return h + mlp_apply(p["mlp"], rms_norm(h, p["ln2"], cfg.norm_eps), ctx)


def _scan_layers(body, x, stacked, ctx: ParallelCtx, aux0=None):
    """Scan ``body`` over stacked layer params, accumulating aux pytrees."""
    fn = jax.checkpoint(body) if ctx.remat else body
    if aux0 is None:
        aux0 = jnp.zeros((), jnp.float32)

    def f(carry, inp):
        y, aux = fn(inp, carry[0])
        return (y, jax.tree.map(jnp.add, carry[1], aux)), None

    (x, aux), _ = jax.lax.scan(f, (x, aux0), stacked, unroll=ctx.full_unroll)
    return x, aux


# ---------------------------------------------------------------------------
# forward (train): full causal sequence -> logits
# ---------------------------------------------------------------------------

def _embed(params, tokens, cfg: ModelConfig, ctx: ParallelCtx):
    x = jnp.take(params["embed"], tokens, axis=0)
    return ctx.shard(x, ctx.batch_spec, None, None)


def _logits(params, x, cfg: ModelConfig, ctx: ParallelCtx):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    return ctx.shard(logits, ctx.batch_spec, None, ctx.model_axis)


def forward(
    params,
    tokens,
    cfg: ModelConfig,
    ctx: ParallelCtx = NO_MESH,
    embeds=None,
):
    """Full-sequence causal forward. ``embeds``: stub frontend embeddings —
    prepended (vlm) or encoded (audio enc-dec). Returns (logits, aux_loss);
    logits cover only the token positions."""
    x = _embed(params, tokens, cfg, ctx)
    b, s, _ = x.shape
    pat = cfg.block_pattern
    aux = zero_aux(cfg)

    if pat == "encdec":
        assert embeds is not None, "enc-dec needs frontend embeddings"
        mem = embeds
        for_enc = lambda p, m: (_enc_block(p, m, cfg, ctx), 0.0)
        mem, _ = _scan_layers(for_enc, mem, params["encoder"], ctx)
        mem = rms_norm(mem, params["enc_norm"], cfg.norm_eps)
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))

        def dec(p, h):
            kv = cross_kv(p["xattn"], mem, cfg, ctx)
            return _dec_block(p, h, kv, cfg, ctx, positions), zero_aux(cfg)

        x, aux = _scan_layers(dec, x, params["layers"], ctx, zero_aux(cfg))
        return _logits(params, x, cfg, ctx), aux

    n_front = 0
    if cfg.frontend_stub and embeds is not None:
        n_front = embeds.shape[1]
        x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)
        s = s + n_front
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    if pat == "attn":
        body = lambda p, h: _attn_block(p, h, cfg, ctx, positions)
        x, aux = _scan_layers(body, x, params["layers"], ctx, zero_aux(cfg))
    elif pat == "zamba":
        x, aux = _zamba_forward(params, x, cfg, ctx, positions)
    elif pat == "xlstm":
        x, aux = _xlstm_forward(params, x, cfg, ctx)
    else:
        raise ValueError(pat)

    if n_front:
        x = x[:, n_front:]
    return _logits(params, x, cfg, ctx), aux


def _zamba_forward(params, x, cfg, ctx, positions):
    shared = params["shared"]

    def unit(p_unit, h):
        def inner(pl, hh):
            out, _ = ssm.mamba_apply(pl["mamba"], rms_norm(hh, pl["ln"], cfg.norm_eps), cfg)
            return hh + out, 0.0

        h, _ = _scan_layers(inner, h, p_unit, ctx)
        h = h + attention(
            shared["attn"], rms_norm(h, shared["ln1"], cfg.norm_eps), cfg, ctx, positions
        )
        h = h + mlp_apply(shared["mlp"], rms_norm(h, shared["ln2"], cfg.norm_eps), ctx)
        return h, 0.0

    if params["units"] is not None:
        x, _ = _scan_layers(unit, x, params["units"], ctx)
    if params["trailing"] is not None:
        def inner_t(pl, hh):
            out, _ = ssm.mamba_apply(pl["mamba"], rms_norm(hh, pl["ln"], cfg.norm_eps), cfg)
            return hh + out, 0.0
        x, _ = _scan_layers(inner_t, x, params["trailing"], ctx)
    return x, zero_aux(cfg)


def _xlstm_forward(params, x, cfg, ctx):
    def unit(p_unit, h):
        def m_body(pl, hh):
            out, _ = ssm.mlstm_apply(pl["m"], rms_norm(hh, pl["ln"], cfg.norm_eps), cfg)
            return hh + out, 0.0

        h, _ = _scan_layers(m_body, h, p_unit["m"], ctx)
        ps = p_unit["s"]
        out, _ = ssm.slstm_apply(ps["s"], rms_norm(h, ps["ln"], cfg.norm_eps), cfg)
        return h + out, 0.0

    x, _ = _scan_layers(unit, x, params["units"], ctx)
    return x, zero_aux(cfg)


# ---------------------------------------------------------------------------
# decode: cache init / prefill / one-token step
# ---------------------------------------------------------------------------

def init_cache(
    cfg: ModelConfig,
    batch: int,
    max_seq: int,
    dtype=jnp.float32,
    paged: bool = False,
    page_size: int = PAGE_SIZE,
    n_pages: int | None = None,
) -> dict:
    """Decode cache sized for ``max_seq`` context.

    ``paged=True`` (``attn`` pattern only) swaps the dense per-layer
    ``(B, L, K, hd)`` k/v for a shared page pool + per-request block tables
    (`attention.paged_cache_init`): decode HBM traffic then tracks each
    request's live context, and an oversubscribed pool (``n_pages``) lets a
    serving-side allocator share pages across requests of varied lengths.
    """
    pat = cfg.block_pattern
    if paged and pat != "attn":
        raise ValueError(f"paged KV cache requires block_pattern='attn', got {pat}")
    cache: dict = {"pos": jnp.zeros((), jnp.int32)}
    if pat == "attn":
        one = (
            paged_cache_init(cfg, batch, max_seq, dtype, page_size, n_pages)
            if paged
            else cache_init(cfg, batch, max_seq, dtype)
        )
        cache["layers"] = jax.tree.map(
            lambda z: jnp.broadcast_to(z, (cfg.n_layers, *z.shape)).copy(), one
        )
    elif pat == "zamba":
        u, r = zamba_layout(cfg)
        st = ssm.mamba_state_init(cfg, batch)
        cache["units_ssm"] = jax.tree.map(
            lambda z: jnp.zeros((u, cfg.attn_every, *z.shape), z.dtype), st
        )
        cache["trailing_ssm"] = jax.tree.map(
            lambda z: jnp.zeros((r, *z.shape), z.dtype), st
        )
        one = cache_init(cfg, batch, max_seq, dtype)
        cache["shared_kv"] = jax.tree.map(
            lambda z: jnp.zeros((u, *z.shape), z.dtype), one
        )
    elif pat == "xlstm":
        u = cfg.n_layers // (XLSTM_UNIT_M + 1)
        ms = ssm.mlstm_state_init(cfg, batch)
        ss = ssm.slstm_state_init(cfg, batch)
        cache["m"] = jax.tree.map(
            lambda z: jnp.zeros((u, XLSTM_UNIT_M, *z.shape), z.dtype), ms
        )
        cache["s"] = jax.tree.map(lambda z: jnp.zeros((u, *z.shape), z.dtype), ss)
    elif pat == "encdec":
        one = cache_init(cfg, batch, max_seq, dtype)
        cache["layers"] = jax.tree.map(
            lambda z: jnp.broadcast_to(z, (cfg.n_layers, *z.shape)).copy(), one
        )
        h = cfg.head_dim_
        cache["cross_kv"] = (
            jnp.zeros((cfg.n_layers, batch, cfg.frontend_tokens, cfg.n_kv_heads, h), dtype),
            jnp.zeros((cfg.n_layers, batch, cfg.frontend_tokens, cfg.n_kv_heads, h), dtype),
        )
    return cache


def decode_step(
    params,
    token,                      # (B, 1) int32
    cache: dict,
    cfg: ModelConfig,
    ctx: ParallelCtx = NO_MESH,
    embeds=None,                # encdec: unused at decode (cross kv cached)
    placement=None,             # (slot_of, n_replicas) from the NI-Balancer
    slot_mask=None,             # (B,) bool — False = empty/released batch row
    chunk=None,                 # prefill-lane operand (see below); None = off
):
    """One serve step: consume one token, update the cache, emit logits.

    ``slot_mask`` marks live batch rows for continuous batching: masked
    rows still flow through the step (fixed shapes, no recompile) but are
    excluded from MoE routing, so a half-empty batch never spends expert
    bucket capacity on dead slots. Their logits are garbage by contract —
    the scheduler owns which rows mean anything.

    ``chunk`` adds the prefill lane (paged ``attn`` pattern only): a dict
    ``{"tokens": (1, C) int32, "table": (NB,) int32, "start": scalar,
    "length": scalar}`` carrying one fixed-size chunk of the admitting
    request's context. The chunk runs through every layer alongside the
    decode tokens — same weights, same placement, one compiled program —
    writing its K/V through ``table`` (see
    :func:`~repro.models.attention.chunk_prefill_attention`) and routing
    only its ``length`` valid rows through MoE. ``length = 0`` is the
    no-op chunk, so idle, decode-only and decode+chunk ticks all hit the
    same trace. ``stats["chunk_logits"]`` holds the last valid chunk
    position's logits ``(1, 1, V)``: on the final chunk these emit the
    request's first token, bit-identical to a whole-context prefill."""
    x = _embed(params, token, cfg, ctx)
    pos = cache["pos"]
    pat = cfg.block_pattern
    new_cache = dict(cache)
    if chunk is not None and pat != "attn":
        raise ValueError(
            f"chunked prefill requires block_pattern='attn', got {pat}"
        )

    aux = zero_aux(cfg)
    chunk_logits = None
    if pat == "attn":
        if chunk is not None:
            xc = _embed(params, chunk["tokens"], cfg, ctx)       # (1, C, d)
            n_chunk = chunk["tokens"].shape[1]
            cvalid = (jnp.arange(n_chunk) < chunk["length"])[None, :]

        def body(carry, inp):
            if chunk is None:
                h, a_sum = carry
            else:
                h, hc, a_sum = carry
            p_l, c_l = inp
            z = rms_norm(h, p_l["ln1"], cfg.norm_eps)
            o, c_new = decode_attention(p_l["attn"], z, c_l, pos, cfg, ctx)
            h = h + o
            z2 = rms_norm(h, p_l["ln2"], cfg.norm_eps)
            if cfg.is_moe:
                y, a = moe_apply(
                    p_l["moe"], z2, cfg, ctx, placement=placement,
                    token_mask=None if slot_mask is None else slot_mask[:, None],
                )
            else:
                y, a = mlp_apply(p_l["mlp"], z2, ctx), zero_aux(cfg)
            h = h + y
            if chunk is None:
                return (h, jax.tree.map(jnp.add, a_sum, a)), c_new
            # Prefill lane: the chunk flows through the same layer against
            # the pool the decode lane just wrote (disjoint pages).
            zc = rms_norm(hc, p_l["ln1"], cfg.norm_eps)
            oc, c_new = chunk_prefill_attention(
                p_l["attn"], zc, c_new, chunk["table"],
                chunk["start"], chunk["length"], cfg, ctx,
            )
            hc = hc + oc
            z2c = rms_norm(hc, p_l["ln2"], cfg.norm_eps)
            if cfg.is_moe:
                yc, ac = moe_apply(
                    p_l["moe"], z2c, cfg, ctx, placement=placement,
                    token_mask=cvalid,
                )
            else:
                yc, ac = mlp_apply(p_l["mlp"], z2c, ctx), zero_aux(cfg)
            hc = hc + yc
            a_sum = jax.tree.map(jnp.add, a_sum, jax.tree.map(jnp.add, a, ac))
            return (h, hc, a_sum), c_new

        if chunk is None:
            (x, aux), new_layers = jax.lax.scan(
                body,
                (x, zero_aux(cfg)),
                (params["layers"], cache["layers"]),
                unroll=ctx.full_unroll,
            )
        else:
            (x, xc, aux), new_layers = jax.lax.scan(
                body,
                (x, xc, zero_aux(cfg)),
                (params["layers"], cache["layers"]),
                unroll=ctx.full_unroll,
            )
            last = jnp.clip(chunk["length"] - 1, 0, n_chunk - 1)
            xl = jax.lax.dynamic_slice_in_dim(xc, last, 1, axis=1)
            chunk_logits = _logits(params, xl, cfg, ctx)
        new_cache["layers"] = new_layers

    elif pat == "zamba":
        x, new_cache = _zamba_decode(params, x, cache, cfg, ctx, pos)
    elif pat == "xlstm":
        x, new_cache = _xlstm_decode(params, x, cache, cfg, ctx)
    elif pat == "encdec":

        def body(carry, inp):
            h = carry
            p_l, c_l, kv_l = inp
            z = rms_norm(h, p_l["ln1"], cfg.norm_eps)
            o, c_new = decode_attention(p_l["attn"], z, c_l, pos, cfg, ctx)
            h = h + o
            h = h + cross_attention(
                p_l["xattn"], rms_norm(h, p_l["ln_x"], cfg.norm_eps), kv_l, cfg, ctx
            )
            h = h + mlp_apply(p_l["mlp"], rms_norm(h, p_l["ln2"], cfg.norm_eps), ctx)
            return h, c_new

        x, new_layers = jax.lax.scan(
            body,
            x,
            (params["layers"], cache["layers"], cache["cross_kv"]),
            unroll=ctx.full_unroll,
        )
        new_cache["layers"] = new_layers

    new_cache["pos"] = pos + 1
    stats = {"expert_counts": aux["counts"]}
    if chunk_logits is not None:
        stats["chunk_logits"] = chunk_logits
    return _logits(params, x, cfg, ctx), new_cache, stats


def _zamba_decode(params, x, cache, cfg, ctx, pos):
    shared = params["shared"]
    new_cache = dict(cache)

    def unit(carry, inp):
        h = carry
        p_unit, ssm_states, kv = inp

        def inner(hh, inp2):
            pl, st = inp2
            out, st_new = ssm.mamba_decode(
                pl["mamba"], rms_norm(hh, pl["ln"], cfg.norm_eps), st, cfg
            )
            return hh + out, st_new

        h, ssm_new = jax.lax.scan(inner, h, (p_unit, ssm_states))
        z = rms_norm(h, shared["ln1"], cfg.norm_eps)
        o, kv_new = decode_attention(shared["attn"], z, kv, pos, cfg, ctx)
        h = h + o
        h = h + mlp_apply(shared["mlp"], rms_norm(h, shared["ln2"], cfg.norm_eps), ctx)
        return h, (ssm_new, kv_new)

    if params["units"] is not None:
        x, (ssm_new, kv_new) = jax.lax.scan(
            unit,
            x,
            (params["units"], cache["units_ssm"], cache["shared_kv"]),
            unroll=ctx.full_unroll,
        )
        new_cache["units_ssm"] = ssm_new
        new_cache["shared_kv"] = kv_new
    if params["trailing"] is not None:

        def inner_t(hh, inp2):
            pl, st = inp2
            out, st_new = ssm.mamba_decode(
                pl["mamba"], rms_norm(hh, pl["ln"], cfg.norm_eps), st, cfg
            )
            return hh + out, st_new

        x, tr_new = jax.lax.scan(
            inner_t,
            x,
            (params["trailing"], cache["trailing_ssm"]),
            unroll=ctx.full_unroll,
        )
        new_cache["trailing_ssm"] = tr_new
    return x, new_cache


def _xlstm_decode(params, x, cache, cfg, ctx):
    new_cache = dict(cache)

    def unit(carry, inp):
        h = carry
        p_unit, m_states, s_state = inp

        def m_body(hh, inp2):
            pl, st = inp2
            out, st_new = ssm.mlstm_apply(
                pl["m"], rms_norm(hh, pl["ln"], cfg.norm_eps), cfg, st
            )
            return hh + out, st_new

        h, m_new = jax.lax.scan(m_body, h, (p_unit["m"], m_states))
        ps = p_unit["s"]
        out, s_new = ssm.slstm_apply(
            ps["s"], rms_norm(h, ps["ln"], cfg.norm_eps), cfg, s_state
        )
        return h + out, (m_new, s_new)

    x, (m_new, s_new) = jax.lax.scan(
        unit, x, (params["units"], cache["m"], cache["s"]), unroll=ctx.full_unroll
    )
    new_cache["m"] = m_new
    new_cache["s"] = s_new
    return x, new_cache


# ---------------------------------------------------------------------------
# prefill: full-sequence pass that also fills the decode cache
# ---------------------------------------------------------------------------

def prefill(
    params,
    tokens,
    cfg: ModelConfig,
    ctx: ParallelCtx = NO_MESH,
    embeds=None,
    max_seq: int | None = None,
    dtype=jnp.float32,
    paged: bool = False,
    page_size: int = PAGE_SIZE,
    n_pages: int | None = None,
    tables=None,               # (B, NB) int32 — allocator-provided block tables
    lengths=None,              # (B,) int32 — true per-request prompt lengths
):
    """Process the prompt; return (last-position logits, primed cache).

    Paged mode: ``tables`` lets a serving allocator place each request's
    blocks in a shared (possibly oversubscribed) pool; ``lengths`` marks
    true prompt lengths for right-padded ragged batches — pad positions
    fall outside each request's validity prefix and are overwritten as the
    request decodes.
    """
    b, s = tokens.shape
    pat = cfg.block_pattern
    x = _embed(params, tokens, cfg, ctx)
    if cfg.frontend_stub and embeds is not None and pat != "encdec":
        x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)
        s = x.shape[1]
    max_seq = max(max_seq or s, s)
    cache = init_cache(cfg, b, max_seq, dtype, paged, page_size, n_pages)
    if tables is not None:
        nl = cfg.n_layers
        cache["layers"]["tables"] = jnp.broadcast_to(
            tables.astype(jnp.int32), (nl, *tables.shape)
        ).copy()
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    if pat == "attn":

        def body(carry, inp):
            h, a_sum = carry
            p_l, c_l = inp
            z = rms_norm(h, p_l["ln1"], cfg.norm_eps)
            o, (k, v) = attention(p_l["attn"], z, cfg, ctx, positions, return_kv=True)
            h = h + o
            if is_paged(c_l):
                c_new = paged_prefill_fill(c_l, k, v, s, lengths)
            else:
                length = c_l["k"].shape[1]
                kk, vv = k[:, -length:], v[:, -length:]
                if cfg.sliding_window and s >= length:
                    # Align to the decode ring buffer: slot j holds pos%W == j.
                    kk = jnp.roll(kk, s % length, axis=1)
                    vv = jnp.roll(vv, s % length, axis=1)
                c_new = {
                    "k": jax.lax.dynamic_update_slice(c_l["k"], kk, (0, 0, 0, 0)),
                    "v": jax.lax.dynamic_update_slice(c_l["v"], vv, (0, 0, 0, 0)),
                }
            z2 = rms_norm(h, p_l["ln2"], cfg.norm_eps)
            if cfg.is_moe:
                y, a = moe_apply(p_l["moe"], z2, cfg, ctx)
            else:
                y, a = mlp_apply(p_l["mlp"], z2, ctx), zero_aux(cfg)
            return (h + y, jax.tree.map(jnp.add, a_sum, a)), c_new

        (x, _), new_layers = jax.lax.scan(
            body,
            (x, zero_aux(cfg)),
            (params["layers"], cache["layers"]),
            unroll=ctx.full_unroll,
        )
        cache["layers"] = new_layers

    elif pat in ("zamba", "xlstm"):
        x, cache = _ssm_prefill(params, x, cache, cfg, ctx, positions)
    elif pat == "encdec":
        assert embeds is not None
        mem = embeds
        for_enc = lambda p, m: (_enc_block(p, m, cfg, ctx), 0.0)
        mem, _ = _scan_layers(for_enc, mem, params["encoder"], ctx)
        mem = rms_norm(mem, params["enc_norm"], cfg.norm_eps)

        def body(carry, inp):
            h = carry
            p_l, c_l = inp
            kv = cross_kv(p_l["xattn"], mem, cfg, ctx)
            z = rms_norm(h, p_l["ln1"], cfg.norm_eps)
            o, (k, v) = attention(p_l["attn"], z, cfg, ctx, positions, return_kv=True)
            h = h + o
            c_new = {
                "k": jax.lax.dynamic_update_slice(c_l["k"], k, (0, 0, 0, 0)),
                "v": jax.lax.dynamic_update_slice(c_l["v"], v, (0, 0, 0, 0)),
            }
            h = h + cross_attention(
                p_l["xattn"], rms_norm(h, p_l["ln_x"], cfg.norm_eps), kv, cfg, ctx
            )
            h = h + mlp_apply(p_l["mlp"], rms_norm(h, p_l["ln2"], cfg.norm_eps), ctx)
            return h, (c_new, kv)

        x, (new_layers, kvs) = jax.lax.scan(
            body, x, (params["layers"], cache["layers"]), unroll=ctx.full_unroll
        )
        cache["layers"] = new_layers
        cache["cross_kv"] = kvs

    cache["pos"] = jnp.asarray(s, jnp.int32)
    if lengths is not None:
        # Ragged right-padded prompts: each request's next-token logits
        # live at its true last position, not the padded batch tail.
        last = jnp.clip(lengths.astype(jnp.int32) - 1, 0, s - 1)
        x = jnp.take_along_axis(x, last[:, None, None], axis=1)
    else:
        x = x[:, -1:]
    logits = _logits(params, x, cfg, ctx)
    return logits, cache


def _ssm_prefill(params, x, cache, cfg, ctx, positions):
    pat = cfg.block_pattern
    new_cache = dict(cache)
    if pat == "zamba":
        shared = params["shared"]

        def unit(carry, inp):
            h = carry
            p_unit, ssm_states, kv = inp

            def inner(hh, inp2):
                pl, st = inp2
                out, st_new = ssm.mamba_apply(
                    pl["mamba"], rms_norm(hh, pl["ln"], cfg.norm_eps), cfg, st
                )
                return hh + out, st_new

            h, ssm_new = jax.lax.scan(inner, h, (p_unit, ssm_states))
            z = rms_norm(h, shared["ln1"], cfg.norm_eps)
            o, (k, v) = attention(shared["attn"], z, cfg, ctx, positions, return_kv=True)
            h = h + o
            length = kv["k"].shape[1]
            kv_new = {
                "k": jax.lax.dynamic_update_slice(kv["k"], k[:, -length:], (0, 0, 0, 0)),
                "v": jax.lax.dynamic_update_slice(kv["v"], v[:, -length:], (0, 0, 0, 0)),
            }
            h = h + mlp_apply(shared["mlp"], rms_norm(h, shared["ln2"], cfg.norm_eps), ctx)
            return h, (ssm_new, kv_new)

        if params["units"] is not None:
            x, (ssm_new, kv_new) = jax.lax.scan(
                unit,
                x,
                (params["units"], cache["units_ssm"], cache["shared_kv"]),
                unroll=ctx.full_unroll,
            )
            new_cache["units_ssm"] = ssm_new
            new_cache["shared_kv"] = kv_new
        if params["trailing"] is not None:

            def inner_t(hh, inp2):
                pl, st = inp2
                out, st_new = ssm.mamba_apply(
                    pl["mamba"], rms_norm(hh, pl["ln"], cfg.norm_eps), cfg, st
                )
                return hh + out, st_new

            x, tr_new = jax.lax.scan(
                inner_t,
                x,
                (params["trailing"], cache["trailing_ssm"]),
                unroll=ctx.full_unroll,
            )
            new_cache["trailing_ssm"] = tr_new
        return x, new_cache

    # xlstm
    def unit(carry, inp):
        h = carry
        p_unit, m_states, s_state = inp

        def m_body(hh, inp2):
            pl, st = inp2
            out, st_new = ssm.mlstm_apply(
                pl["m"], rms_norm(hh, pl["ln"], cfg.norm_eps), cfg, st
            )
            return hh + out, st_new

        h, m_new = jax.lax.scan(m_body, h, (p_unit["m"], m_states))
        ps = p_unit["s"]
        out, s_new = ssm.slstm_apply(
            ps["s"], rms_norm(h, ps["ln"], cfg.norm_eps), cfg, s_state
        )
        return h + out, (m_new, s_new)

    x, (m_new, s_new) = jax.lax.scan(
        unit, x, (params["units"], cache["m"], cache["s"]), unroll=ctx.full_unroll
    )
    new_cache["m"] = m_new
    new_cache["s"] = s_new
    return x, new_cache
