"""GQA attention: full / sliding-window, train/prefill/decode, cross-attn.

Layout conventions: activations ``(batch, seq, d_model)``; q ``(B,S,H,hd)``;
k/v ``(B,S,K,hd)`` with ``K = n_kv_heads``. GQA is computed in grouped form
(no materialized head repetition). Softmax in fp32.

Decode caches:
* full attention — cache length = max seq, write at ``pos``;
* sliding window — ring buffer of length ``window``, write at ``pos % W``.

Sharding: heads (H and K) on the model axis, batch on the data axes. For
decode with ``seq_parallel_kv`` the cache's *sequence* dim rides the model
axis instead (flash-decode style) — see ``repro.parallel.collectives``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.kernels import registry
from repro.models.layers import apply_rope, normal_init
from repro.parallel.compat import shard_map
from repro.parallel.ctx import ParallelCtx

NEG_INF = -1e30


def attn_init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d, h = cfg.d_model, cfg.head_dim_
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": normal_init(kq, (d, cfg.n_heads * h), dtype=dtype),
        "wk": normal_init(kk, (d, cfg.n_kv_heads * h), dtype=dtype),
        "wv": normal_init(kv, (d, cfg.n_kv_heads * h), dtype=dtype),
        "wo": normal_init(ko, (cfg.n_heads * h, d), dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * h,), dtype=dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * h,), dtype=dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * h,), dtype=dtype)
    return p


def qkv_proj(
    p: dict, x: jax.Array, cfg: ModelConfig, ctx: ParallelCtx
) -> tuple[jax.Array, jax.Array, jax.Array]:
    b, s, _ = x.shape
    h = cfg.head_dim_
    q = jnp.einsum("bsd,de->bse", x, p["wq"])
    k = jnp.einsum("bsd,de->bse", x, p["wk"])
    v = jnp.einsum("bsd,de->bse", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, cfg.n_heads, h)
    k = k.reshape(b, s, cfg.n_kv_heads, h)
    v = v.reshape(b, s, cfg.n_kv_heads, h)
    q = ctx.shard(q, ctx.batch_spec, None, ctx.model_axis, None)
    k = ctx.shard(k, ctx.batch_spec, None, ctx.model_axis, None)
    v = ctx.shard(v, ctx.batch_spec, None, ctx.model_axis, None)
    return q, k, v


def out_proj(p: dict, o: jax.Array, ctx: ParallelCtx) -> jax.Array:
    b, s = o.shape[:2]
    o = o.reshape(b, s, -1)
    return jnp.einsum("bse,ed->bsd", o, p["wo"])


# ---------------------------------------------------------------------------
# reference attention math (grouped GQA, fp32 softmax)
# ---------------------------------------------------------------------------

def gqa_attend(
    q: jax.Array,      # (B, S, H, hd)
    k: jax.Array,      # (B, T, K, hd)
    v: jax.Array,      # (B, T, K, hd)
    mask: jax.Array | None,   # broadcastable to (B, 1, 1, S, T) or (S, T)
) -> jax.Array:
    b, s, nh, hd = q.shape
    nk = k.shape[2]
    g = nh // nk
    qg = q.reshape(b, s, nk, g, hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, s, nh, hd)


CHUNKED_KV_THRESHOLD = 2048  # switch to the online-softmax path beyond this


def chunked_gqa_attend(
    q: jax.Array,      # (B, S, H, hd)
    k: jax.Array,      # (B, T, K, hd)
    v: jax.Array,      # (B, T, K, hd)
    causal: bool,
    window: int,
    chunk: int = 1024,
) -> jax.Array:
    """Flash-style attention in pure jnp: scan over KV chunks with an online
    softmax, so the S x T score matrix is never materialized. This is the
    memory-feasible path for train_4k/prefill_32k at full scale (the Pallas
    kernel is the TPU-optimized equivalent; this one is backend-agnostic
    and differentiable)."""
    b, s, nh, hd = q.shape
    t, nk = k.shape[1], k.shape[2]
    g = nh // nk
    while t % chunk:
        chunk //= 2
    n_chunks = t // chunk
    qg = (q / jnp.sqrt(hd)).reshape(b, s, nk, g, hd)
    kc = k.reshape(b, n_chunks, chunk, nk, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, nk, hd).transpose(1, 0, 2, 3, 4)
    offset = t - s  # queries cover the tail of the key range

    def step(carry, inp):
        m_run, l_run, acc = carry
        k_blk, v_blk, j = inp
        sc = jnp.einsum("bskgd,btkd->bkgst", qg, k_blk).astype(jnp.float32)
        if causal:
            qpos = offset + jnp.arange(s)[:, None]
            kpos = j * chunk + jnp.arange(chunk)[None, :]
            mask = kpos <= qpos
            if window:
                mask = mask & (kpos > qpos - window)
            sc = jnp.where(mask[None, None, None], sc, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(sc, axis=-1))
        p = jnp.exp(sc - m_new[..., None])
        alpha = jnp.exp(m_run - m_new)
        l_new = l_run * alpha + jnp.sum(p, axis=-1)
        upd = jnp.einsum("bkgst,btkd->bskgd", p.astype(v_blk.dtype), v_blk)
        acc = acc * alpha.transpose(0, 3, 1, 2)[..., None].astype(acc.dtype) + upd
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, nk, g, s), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, nk, g, s), jnp.float32)
    acc0 = jnp.zeros((b, s, nk, g, hd), v.dtype)
    (m_f, l_f, acc), _ = jax.lax.scan(
        step, (m0, l0, acc0), (kc, vc, jnp.arange(n_chunks))
    )
    l_f = jnp.maximum(l_f, 1e-30).transpose(0, 3, 1, 2)[..., None]
    out = acc / l_f.astype(acc.dtype)
    return out.reshape(b, s, nh, hd)


def causal_mask(s: int, t: int | None = None, window: int = 0, offset: int = 0):
    """(S, T) boolean mask. ``offset`` = absolute position of query 0 minus
    position of key 0 (0 when q/k cover the same range)."""
    t = t or s
    qpos = jnp.arange(s)[:, None] + offset
    kpos = jnp.arange(t)[None, :]
    m = kpos <= qpos
    if window > 0:
        m &= kpos > qpos - window
    return m


# ---------------------------------------------------------------------------
# kernel dispatch (flash attention / flash decode via repro.kernels.registry)
# ---------------------------------------------------------------------------

def _flash_attend_eligible(q, k, ctx: ParallelCtx) -> bool:
    if not ctx.kernels_on or ctx.force_dense_attn:
        return False
    b, s, nh, hd = q.shape
    t, nkv = k.shape[1], k.shape[2]
    if not registry.can_flash_attend(
        s, t, nh, nkv, hd, registry.default_interpret()
    ):
        return False
    if ctx.mesh is None:
        return True
    # Under GSPMD the pallas_call must go through shard_map; the sharded
    # dims (batch, heads) have to divide their mesh axes.
    return nh % ctx.n_model == 0 and nkv % ctx.n_model == 0 and b % ctx.n_batch == 0


def _flash_attend(q, k, v, causal: bool, window: int, ctx: ParallelCtx):
    if ctx.mesh is None:
        return registry.attend(q, k, v, causal=causal, window=window)
    spec = P(ctx.batch_spec, None, ctx.model_axis, None)
    return shard_map(
        lambda qb, kb, vb: registry.attend(
            qb, kb, vb, causal=causal, window=window
        ),
        mesh=ctx.mesh,
        in_specs=(spec, spec, spec),
        out_specs=spec,
        check_vma=False,
    )(q, k, v)


def _flash_decode_eligible(q, k_cache, ctx: ParallelCtx) -> bool:
    if not ctx.kernels_on or ctx.force_dense_attn:
        return False
    b, _, nh, hd = q.shape
    t, nkv = k_cache.shape[1], k_cache.shape[2]
    if not registry.can_flash_decode(
        t, nh, nkv, hd, registry.default_interpret()
    ):
        return False
    if ctx.mesh is None:
        return True
    if ctx.seq_parallel_kv:
        # Cache seq dim rides the model axis; the flash-decode kernel
        # normalizes locally, so the cross-shard LSE merge stays with
        # ``seq_parallel_decode_attend`` (kernelizing it = open item).
        return False
    return nh % ctx.n_model == 0 and nkv % ctx.n_model == 0 and b % ctx.n_batch == 0


def _flash_decode(q, k_cache, v_cache, valid, ctx: ParallelCtx):
    """q: (B, 1, H, hd); valid: (B, L) -> (B, 1, H, hd)."""
    q1 = q[:, 0]
    if ctx.mesh is None:
        o = registry.decode_attend(q1, k_cache, v_cache, valid)
        return o[:, None]
    bspec, ax = ctx.batch_spec, ctx.model_axis
    o = shard_map(
        lambda qb, kb, vb, mb: registry.decode_attend(qb, kb, vb, mb),
        mesh=ctx.mesh,
        in_specs=(
            P(bspec, ax, None),
            P(bspec, None, ax, None),
            P(bspec, None, ax, None),
            P(bspec, None),
        ),
        out_specs=P(bspec, ax, None),
        check_vma=False,
    )(q1, k_cache, v_cache, valid)
    return o[:, None]


# ---------------------------------------------------------------------------
# train / prefill
# ---------------------------------------------------------------------------

def attention(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    ctx: ParallelCtx,
    positions: jax.Array | None = None,
    causal: bool = True,
    return_kv: bool = False,
):
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = qkv_proj(p, x, cfg, ctx)
    if cfg.rope_theta > 0:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if _flash_attend_eligible(q, k, ctx):
        o = _flash_attend(q, k, v, causal, cfg.sliding_window if causal else 0, ctx)
    elif s > CHUNKED_KV_THRESHOLD and not ctx.force_dense_attn:
        o = chunked_gqa_attend(q, k, v, causal, cfg.sliding_window)
    else:
        mask = causal_mask(s, window=cfg.sliding_window) if causal else None
        o = gqa_attend(q, k, v, mask)
    o = ctx.shard(o, ctx.batch_spec, None, ctx.model_axis, None)
    out = out_proj(p, o, ctx)
    if return_kv:
        return out, (k, v)
    return out


def cross_attention(
    p: dict,
    x: jax.Array,
    kv: tuple[jax.Array, jax.Array],
    cfg: ModelConfig,
    ctx: ParallelCtx,
) -> jax.Array:
    """Decoder cross-attention over precomputed encoder k/v (no mask)."""
    b, s, _ = x.shape
    h = cfg.head_dim_
    q = jnp.einsum("bsd,de->bse", x, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"]
    q = q.reshape(b, s, cfg.n_heads, h)
    q = ctx.shard(q, ctx.batch_spec, None, ctx.model_axis, None)
    o = gqa_attend(q, kv[0], kv[1], None)
    return out_proj(p, o, ctx)


def cross_kv(
    p: dict, memory: jax.Array, cfg: ModelConfig, ctx: ParallelCtx
) -> tuple[jax.Array, jax.Array]:
    b, t, _ = memory.shape
    h = cfg.head_dim_
    k = jnp.einsum("btd,de->bte", memory, p["wk"])
    v = jnp.einsum("btd,de->bte", memory, p["wv"])
    if cfg.qkv_bias:
        k, v = k + p["bk"], v + p["bv"]
    k = k.reshape(b, t, cfg.n_kv_heads, h)
    v = v.reshape(b, t, cfg.n_kv_heads, h)
    k = ctx.shard(k, ctx.batch_spec, None, ctx.model_axis, None)
    v = ctx.shard(v, ctx.batch_spec, None, ctx.model_axis, None)
    return k, v


# ---------------------------------------------------------------------------
# decode (single new token against a cache)
# ---------------------------------------------------------------------------

def cache_init(
    cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.float32
) -> dict:
    w = cfg.sliding_window or 0
    length = min(max_seq, w) if w else max_seq
    shape = (batch, length, cfg.n_kv_heads, cfg.head_dim_)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def cache_spec(cfg: ModelConfig, batch: int, max_seq: int, ctx: ParallelCtx):
    """PartitionSpec elements for one layer's k/v cache."""
    if ctx.seq_parallel_kv:
        return (ctx.batch_spec, ctx.model_axis, None, None)
    return (ctx.batch_spec, None, ctx.model_axis, None)


def decode_attention(
    p: dict,
    x: jax.Array,            # (B, 1, d)
    cache: dict,             # {"k","v"}: (B, L, K, hd)
    pos: jax.Array,          # scalar int32 — absolute position of new token
    cfg: ModelConfig,
    ctx: ParallelCtx,
) -> tuple[jax.Array, dict]:
    b = x.shape[0]
    h = cfg.head_dim_
    q, k_new, v_new = qkv_proj(p, x, cfg, ctx)
    posb = jnp.broadcast_to(pos, (b, 1))
    if cfg.rope_theta > 0:
        q = apply_rope(q, posb, cfg.rope_theta)
        k_new = apply_rope(k_new, posb, cfg.rope_theta)

    length = cache["k"].shape[1]
    w = cfg.sliding_window or 0
    slot = jnp.where(w > 0, pos % length, jnp.minimum(pos, length - 1))
    k_cache = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, slot, 0, 0))

    j = jnp.arange(length)
    if w > 0:
        # ring buffer: slot j holds absolute position pos - ((pos - j) % L);
        # negative => never written yet.
        slot_pos = pos - ((pos - j) % length)
        mask = slot_pos >= 0
    else:
        mask = j <= pos
    if _flash_decode_eligible(q, k_cache, ctx):
        valid = jnp.broadcast_to(mask[None, :], (b, length))
        o = _flash_decode(q, k_cache, v_cache, valid, ctx)
    else:
        o = gqa_attend(q, k_cache, v_cache, mask[None, None, None, None, :])
    o = ctx.shard(o, ctx.batch_spec, None, ctx.model_axis, None)
    out = out_proj(p, o, ctx)
    return out, {"k": k_cache, "v": v_cache}
