"""GQA attention: full / sliding-window, train/prefill/decode, cross-attn.

Layout conventions: activations ``(batch, seq, d_model)``; q ``(B,S,H,hd)``;
k/v ``(B,S,K,hd)`` with ``K = n_kv_heads``. GQA is computed in grouped form
(no materialized head repetition). Softmax in fp32.

Decode caches:
* full attention — cache length = max seq, write at ``pos``;
* sliding window — ring buffer of length ``window``, write at ``pos % W``.

Sharding: heads (H and K) on the model axis, batch on the data axes. For
decode with ``seq_parallel_kv`` the cache's *sequence* dim rides the model
axis instead (flash-decode style) — see ``repro.parallel.collectives``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.kernels import registry
from repro.models.layers import apply_rope, normal_init
from repro.parallel.collectives import seq_parallel_decode_attend
from repro.parallel.compat import shard_map
from repro.parallel.ctx import ParallelCtx

NEG_INF = -1e30


def attn_init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d, h = cfg.d_model, cfg.head_dim_
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": normal_init(kq, (d, cfg.n_heads * h), dtype=dtype),
        "wk": normal_init(kk, (d, cfg.n_kv_heads * h), dtype=dtype),
        "wv": normal_init(kv, (d, cfg.n_kv_heads * h), dtype=dtype),
        "wo": normal_init(ko, (cfg.n_heads * h, d), dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * h,), dtype=dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * h,), dtype=dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * h,), dtype=dtype)
    return p


def qkv_proj(
    p: dict, x: jax.Array, cfg: ModelConfig, ctx: ParallelCtx
) -> tuple[jax.Array, jax.Array, jax.Array]:
    b, s, _ = x.shape
    h = cfg.head_dim_
    q = jnp.einsum("bsd,de->bse", x, p["wq"])
    k = jnp.einsum("bsd,de->bse", x, p["wk"])
    v = jnp.einsum("bsd,de->bse", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, cfg.n_heads, h)
    k = k.reshape(b, s, cfg.n_kv_heads, h)
    v = v.reshape(b, s, cfg.n_kv_heads, h)
    q = ctx.shard(q, ctx.batch_spec, None, ctx.model_axis, None)
    k = ctx.shard(k, ctx.batch_spec, None, ctx.model_axis, None)
    v = ctx.shard(v, ctx.batch_spec, None, ctx.model_axis, None)
    return q, k, v


def out_proj(p: dict, o: jax.Array, ctx: ParallelCtx) -> jax.Array:
    b, s = o.shape[:2]
    o = o.reshape(b, s, -1)
    return jnp.einsum("bse,ed->bsd", o, p["wo"])


# ---------------------------------------------------------------------------
# reference attention math (grouped GQA, fp32 softmax)
# ---------------------------------------------------------------------------

def gqa_attend(
    q: jax.Array,      # (B, S, H, hd)
    k: jax.Array,      # (B, T, K, hd)
    v: jax.Array,      # (B, T, K, hd)
    mask: jax.Array | None,   # broadcastable to (B, 1, 1, S, T) or (S, T)
) -> jax.Array:
    b, s, nh, hd = q.shape
    nk = k.shape[2]
    g = nh // nk
    qg = q.reshape(b, s, nk, g, hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, s, nh, hd)


CHUNKED_KV_THRESHOLD = 2048  # switch to the online-softmax path beyond this


def chunked_gqa_attend(
    q: jax.Array,      # (B, S, H, hd)
    k: jax.Array,      # (B, T, K, hd)
    v: jax.Array,      # (B, T, K, hd)
    causal: bool,
    window: int,
    chunk: int = 1024,
) -> jax.Array:
    """Flash-style attention in pure jnp: scan over KV chunks with an online
    softmax, so the S x T score matrix is never materialized. This is the
    memory-feasible path for train_4k/prefill_32k at full scale (the Pallas
    kernel is the TPU-optimized equivalent; this one is backend-agnostic
    and differentiable)."""
    b, s, nh, hd = q.shape
    t, nk = k.shape[1], k.shape[2]
    g = nh // nk
    while t % chunk:
        chunk //= 2
    n_chunks = t // chunk
    qg = (q / jnp.sqrt(hd)).reshape(b, s, nk, g, hd)
    kc = k.reshape(b, n_chunks, chunk, nk, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, nk, hd).transpose(1, 0, 2, 3, 4)
    offset = t - s  # queries cover the tail of the key range

    def step(carry, inp):
        m_run, l_run, acc = carry
        k_blk, v_blk, j = inp
        sc = jnp.einsum("bskgd,btkd->bkgst", qg, k_blk).astype(jnp.float32)
        if causal:
            qpos = offset + jnp.arange(s)[:, None]
            kpos = j * chunk + jnp.arange(chunk)[None, :]
            mask = kpos <= qpos
            if window:
                mask = mask & (kpos > qpos - window)
            sc = jnp.where(mask[None, None, None], sc, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(sc, axis=-1))
        p = jnp.exp(sc - m_new[..., None])
        alpha = jnp.exp(m_run - m_new)
        l_new = l_run * alpha + jnp.sum(p, axis=-1)
        upd = jnp.einsum("bkgst,btkd->bskgd", p.astype(v_blk.dtype), v_blk)
        acc = acc * alpha.transpose(0, 3, 1, 2)[..., None].astype(acc.dtype) + upd
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, nk, g, s), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, nk, g, s), jnp.float32)
    acc0 = jnp.zeros((b, s, nk, g, hd), v.dtype)
    (m_f, l_f, acc), _ = jax.lax.scan(
        step, (m0, l0, acc0), (kc, vc, jnp.arange(n_chunks))
    )
    l_f = jnp.maximum(l_f, 1e-30).transpose(0, 3, 1, 2)[..., None]
    out = acc / l_f.astype(acc.dtype)
    return out.reshape(b, s, nh, hd)


def causal_mask(s: int, t: int | None = None, window: int = 0, offset: int = 0):
    """(S, T) boolean mask. ``offset`` = absolute position of query 0 minus
    position of key 0 (0 when q/k cover the same range)."""
    t = t or s
    qpos = jnp.arange(s)[:, None] + offset
    kpos = jnp.arange(t)[None, :]
    m = kpos <= qpos
    if window > 0:
        m &= kpos > qpos - window
    return m


# ---------------------------------------------------------------------------
# kernel dispatch (flash attention / flash decode via repro.kernels.registry)
# ---------------------------------------------------------------------------

def _flash_attend_eligible(q, k, ctx: ParallelCtx) -> bool:
    if not ctx.kernels_on or ctx.force_dense_attn:
        return False
    b, s, nh, hd = q.shape
    t, nkv = k.shape[1], k.shape[2]
    if not registry.can_flash_attend(
        s, t, nh, nkv, hd, registry.default_interpret()
    ):
        return False
    if ctx.mesh is None:
        return True
    # Under GSPMD the pallas_call must go through shard_map; the sharded
    # dims (batch, heads) have to divide their mesh axes. When kv heads
    # don't divide the model axis but the axis divides evenly *into* the
    # GQA groups (tp % nkv == 0 — Mixtral-style GQA on a wide TP axis),
    # the kv cache stays replicated and each rank slices the single kv
    # head its query-head block attends to (`_flash_attend` kv-rep body).
    tp = ctx.n_model
    if nh % tp or b % ctx.n_batch:
        return False
    return nkv % tp == 0 or tp % nkv == 0


def _flash_attend(q, k, v, causal: bool, window: int, ctx: ParallelCtx):
    if ctx.mesh is None:
        return registry.attend(q, k, v, causal=causal, window=window)
    tp = ctx.n_model
    nkv = k.shape[2]
    spec = P(ctx.batch_spec, None, ctx.model_axis, None)
    if nkv % tp == 0:
        return shard_map(
            lambda qb, kb, vb: registry.attend(
                qb, kb, vb, causal=causal, window=window
            ),
            mesh=ctx.mesh,
            in_specs=(spec, spec, spec),
            out_specs=spec,
            check_vma=False,
        )(q, k, v)

    # kv-head-replicated variant (tp % nkv == 0): q heads shard the model
    # axis; k/v stay replicated (qkv_proj's sharding constraint already
    # dropped the non-dividing head axis) and each rank slices out the one
    # kv head its contiguous query-head block maps to — rank r holds heads
    # [r*nh/tp, (r+1)*nh/tp), all inside GQA group r // (tp // nkv).
    def kv_rep_body(qb, kb, vb):
        r = jax.lax.axis_index(ctx.model_axis)
        i = r // (tp // nkv)
        kb = jax.lax.dynamic_slice_in_dim(kb, i, 1, axis=2)
        vb = jax.lax.dynamic_slice_in_dim(vb, i, 1, axis=2)
        return registry.attend(qb, kb, vb, causal=causal, window=window)

    kv_spec = P(ctx.batch_spec, None, None, None)
    return shard_map(
        kv_rep_body,
        mesh=ctx.mesh,
        in_specs=(spec, kv_spec, kv_spec),
        out_specs=spec,
        check_vma=False,
    )(q, k, v)


def _flash_decode_eligible(q, k_cache, ctx: ParallelCtx) -> bool:
    if not ctx.kernels_on or ctx.force_dense_attn:
        return False
    b, _, nh, hd = q.shape
    t, nkv = k_cache.shape[1], k_cache.shape[2]
    if not registry.can_flash_decode(
        t, nh, nkv, hd, registry.default_interpret()
    ):
        return False
    if ctx.mesh is None:
        return True
    if ctx.seq_parallel_kv:
        # Cache seq dim rides the model axis: decode goes through
        # ``seq_parallel_decode_attend`` (kernel partials + LSE-merge psum
        # when eligible) — see ``_seq_parallel_decode_eligible``.
        return False
    # Same eligibility shape as ``_flash_attend_eligible``: kv heads either
    # divide the model axis (head-sharded cache) or the axis divides into
    # the GQA groups (tp % nkv == 0 — kv cache replicated, each rank slices
    # its group's single kv head), so dense decode under wide TP no longer
    # requires nkv % tp == 0.
    tp = ctx.n_model
    if nh % tp or b % ctx.n_batch:
        return False
    return nkv % tp == 0 or tp % nkv == 0


def _flash_decode(q, k_cache, v_cache, valid, ctx: ParallelCtx):
    """q: (B, 1, H, hd); valid: (B, L) -> (B, 1, H, hd)."""
    q1 = q[:, 0]
    if ctx.mesh is None:
        o = registry.decode_attend(q1, k_cache, v_cache, valid)
        return o[:, None]
    bspec, ax = ctx.batch_spec, ctx.model_axis
    tp = ctx.n_model
    nkv = k_cache.shape[2]
    if nkv % tp == 0:
        o = shard_map(
            lambda qb, kb, vb, mb: registry.decode_attend(qb, kb, vb, mb),
            mesh=ctx.mesh,
            in_specs=(
                P(bspec, ax, None),
                P(bspec, None, ax, None),
                P(bspec, None, ax, None),
                P(bspec, None),
            ),
            out_specs=P(bspec, ax, None),
            check_vma=False,
        )(q1, k_cache, v_cache, valid)
        return o[:, None]

    # kv-head-replicated variant (tp % nkv == 0): mirrors ``_flash_attend``'s
    # kv-rep body — q heads shard the model axis (dim 1 of (B, H, hd)), the
    # kv cache stays replicated (``cache_specs`` already degraded the
    # non-dividing head axis to replication) and each rank slices out the
    # one kv head its contiguous query-head block attends to.
    def kv_rep_body(qb, kb, vb, mb):
        r = jax.lax.axis_index(ax)
        i = r // (tp // nkv)
        kb = jax.lax.dynamic_slice_in_dim(kb, i, 1, axis=2)
        vb = jax.lax.dynamic_slice_in_dim(vb, i, 1, axis=2)
        return registry.decode_attend(qb, kb, vb, mb)

    kv_spec = P(bspec, None, None, None)
    o = shard_map(
        kv_rep_body,
        mesh=ctx.mesh,
        in_specs=(P(bspec, ax, None), kv_spec, kv_spec, P(bspec, None)),
        out_specs=P(bspec, ax, None),
        check_vma=False,
    )(q1, k_cache, v_cache, valid)
    return o[:, None]


# ---------------------------------------------------------------------------
# train / prefill
# ---------------------------------------------------------------------------

def attention(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    ctx: ParallelCtx,
    positions: jax.Array | None = None,
    causal: bool = True,
    return_kv: bool = False,
):
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = qkv_proj(p, x, cfg, ctx)
    if cfg.rope_theta > 0:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if _flash_attend_eligible(q, k, ctx):
        o = _flash_attend(q, k, v, causal, cfg.sliding_window if causal else 0, ctx)
    elif s > CHUNKED_KV_THRESHOLD and not ctx.force_dense_attn:
        o = chunked_gqa_attend(q, k, v, causal, cfg.sliding_window)
    else:
        mask = causal_mask(s, window=cfg.sliding_window) if causal else None
        o = gqa_attend(q, k, v, mask)
    o = ctx.shard(o, ctx.batch_spec, None, ctx.model_axis, None)
    out = out_proj(p, o, ctx)
    if return_kv:
        return out, (k, v)
    return out


def cross_attention(
    p: dict,
    x: jax.Array,
    kv: tuple[jax.Array, jax.Array],
    cfg: ModelConfig,
    ctx: ParallelCtx,
) -> jax.Array:
    """Decoder cross-attention over precomputed encoder k/v (no mask)."""
    b, s, _ = x.shape
    h = cfg.head_dim_
    q = jnp.einsum("bsd,de->bse", x, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"]
    q = q.reshape(b, s, cfg.n_heads, h)
    q = ctx.shard(q, ctx.batch_spec, None, ctx.model_axis, None)
    o = gqa_attend(q, kv[0], kv[1], None)
    return out_proj(p, o, ctx)


def cross_kv(
    p: dict, memory: jax.Array, cfg: ModelConfig, ctx: ParallelCtx
) -> tuple[jax.Array, jax.Array]:
    b, t, _ = memory.shape
    h = cfg.head_dim_
    k = jnp.einsum("btd,de->bte", memory, p["wk"])
    v = jnp.einsum("btd,de->bte", memory, p["wv"])
    if cfg.qkv_bias:
        k, v = k + p["bk"], v + p["bv"]
    k = k.reshape(b, t, cfg.n_kv_heads, h)
    v = v.reshape(b, t, cfg.n_kv_heads, h)
    k = ctx.shard(k, ctx.batch_spec, None, ctx.model_axis, None)
    v = ctx.shard(v, ctx.batch_spec, None, ctx.model_axis, None)
    return k, v


# ---------------------------------------------------------------------------
# decode (single new token against a cache)
# ---------------------------------------------------------------------------

PAGE_SIZE = 128  # default logical KV page (rows per physical pool page)


def cache_len(cfg: ModelConfig, max_seq: int) -> int:
    """Logical KV slots a decode cache holds (ring length when windowed)."""
    w = cfg.sliding_window or 0
    return min(max_seq, w) if w else max_seq


def cache_init(
    cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.float32
) -> dict:
    length = cache_len(cfg, max_seq)
    shape = (batch, length, cfg.n_kv_heads, cfg.head_dim_)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def paged_layout(cfg: ModelConfig, max_seq: int, page_size: int = PAGE_SIZE):
    """``(page_size, n_blocks)`` for a paged cache of ``max_seq`` context.

    Full attention tolerates a partial tail block (prefix validity masks
    it), so any page size works. A sliding-window ring must be a whole
    number of pages — prefix validity over ``NB * bs`` logical slots *is*
    the ring's live set only when ``NB * bs == ring length`` — so the page
    shrinks to the largest divisor of the ring length ≤ ``page_size``
    (compiled-kernel eligibility may then fall back to the gather
    reference; see ``registry.can_flash_decode_paged``).
    """
    length = cache_len(cfg, max_seq)
    bs = max(min(page_size, length), 1)
    if cfg.sliding_window:
        while length % bs:
            bs -= 1
    return bs, -(-length // bs)


def paged_cache_init(
    cfg: ModelConfig,
    batch: int,
    max_seq: int,
    dtype=jnp.float32,
    page_size: int = PAGE_SIZE,
    n_pages: int | None = None,
) -> dict:
    """Paged decode cache: a shared page pool + per-request block tables.

    * ``pool_k`` / ``pool_v`` — ``(P, bs, K, hd)``: physical pages, shared
      across requests (``P`` defaults to ``batch * NB`` = fully backed);
    * ``tables`` — ``(B, NB)`` int32: logical block ``j`` of request ``b``
      lives in pool page ``tables[b, j]`` (identity layout by default; a
      serving-side allocator may remap freely);
    * ``lengths`` — ``(B,)`` int32: tokens *written* per request. The live
      context is ``min(lengths, NB * bs)`` (ring wraps in place).

    With an explicit ``n_pages`` (allocator mode, possibly oversubscribed:
    ``n_pages < batch * NB``) the pool gets **one extra write-off page** at
    index ``n_pages`` and every table entry starts there: scatters through
    unallocated entries land on the write-off page and are never read back
    (prefix validity stops before them; the dead-block clamp in the kernel
    only revisits live pages). A serving allocator (`runtime.serve.PagePool`)
    hands out pages ``0..n_pages-1`` per request and frees them on release.
    """
    bs, nb = paged_layout(cfg, max_seq, page_size)
    if n_pages is None:
        pool_pages = batch * nb
        tables = jnp.arange(pool_pages, dtype=jnp.int32).reshape(batch, nb)
    else:
        pool_pages = n_pages + 1   # + write-off page for unallocated entries
        tables = jnp.full((batch, nb), n_pages, jnp.int32)
    shape = (pool_pages, bs, cfg.n_kv_heads, cfg.head_dim_)
    return {
        "pool_k": jnp.zeros(shape, dtype),
        "pool_v": jnp.zeros(shape, dtype),
        "tables": tables,
        "lengths": jnp.zeros((batch,), jnp.int32),
    }


def is_paged(cache: dict) -> bool:
    return "pool_k" in cache


def paged_prefill_fill(
    cache: dict,
    k: jax.Array,              # (B, S, K, hd) — the prompt's keys
    v: jax.Array,
    s: int,
    lengths: jax.Array | None = None,   # (B,) true prompt lengths (<= S)
) -> dict:
    """Scatter a prefill's K/V into the page pool through the block tables.

    Token ``t`` of request ``b`` lands at logical slot ``t % cap``
    (identical to the decode write), so after writing ``L_b`` tokens, slot
    ``j`` holds position ``L_b - 1 - ((L_b - 1 - j) % cap)`` — a *per-
    request* gather, which handles ragged right-padded prompts and
    ring-wrapped prefills (``L_b > cap``) uniformly (negative positions =
    never written; they fall outside the ``min(L_b, cap)`` live prefix).
    Table entries may point at a write-off page (unallocated blocks of an
    oversubscribed pool — see ``runtime.serve.PagePool``); rows scattered
    there are never read back.
    """
    pool_k, pool_v, tables = cache["pool_k"], cache["pool_v"], cache["tables"]
    b, nb = tables.shape
    bs = pool_k.shape[1]
    cap = nb * bs
    written = (
        lengths.astype(jnp.int32)
        if lengths is not None
        else jnp.full((b,), s, jnp.int32)
    )
    j = jnp.arange(cap)[None, :]                       # (1, cap)
    last = written[:, None] - 1                        # (B, 1)
    pos = last - ((last - j) % cap)                    # (B, cap)
    idx = jnp.clip(pos, 0, s - 1)[:, :, None, None]
    kk = jnp.take_along_axis(k, idx, axis=1)           # (B, cap, K, hd)
    vv = jnp.take_along_axis(v, idx, axis=1)
    flat = tables.reshape(-1)
    page_shape = (b * nb, bs, *kk.shape[2:])
    pool_k = pool_k.at[flat].set(kk.reshape(page_shape))
    pool_v = pool_v.at[flat].set(vv.reshape(page_shape))
    return {"pool_k": pool_k, "pool_v": pool_v, "tables": tables, "lengths": written}


def cache_spec(cfg: ModelConfig, batch: int, max_seq: int, ctx: ParallelCtx):
    """PartitionSpec elements for one layer's k/v cache."""
    if ctx.seq_parallel_kv:
        return (ctx.batch_spec, ctx.model_axis, None, None)
    return (ctx.batch_spec, None, ctx.model_axis, None)


def decode_attention(
    p: dict,
    x: jax.Array,            # (B, 1, d)
    cache: dict,             # dense {"k","v"} or paged (see paged_cache_init)
    pos: jax.Array,          # scalar int32 — absolute position of new token
    cfg: ModelConfig,
    ctx: ParallelCtx,
) -> tuple[jax.Array, dict]:
    if is_paged(cache):
        return _paged_decode_attention(p, x, cache, cfg, ctx)
    b = x.shape[0]
    q, k_new, v_new = qkv_proj(p, x, cfg, ctx)
    posb = jnp.broadcast_to(pos, (b, 1))
    if cfg.rope_theta > 0:
        q = apply_rope(q, posb, cfg.rope_theta)
        k_new = apply_rope(k_new, posb, cfg.rope_theta)

    length = cache["k"].shape[1]
    w = cfg.sliding_window or 0
    if w > 0:
        slot = pos % length
    else:
        # Overflow (pos >= length): the cache is full. Freeze it — skip the
        # write (it would silently clobber the last slot's key) and clamp
        # the mask below, so slot j always holds position j. The serving
        # layer refuses such steps outright (Server.decode raises).
        slot = jnp.minimum(pos, length - 1)
        old_k = jax.lax.dynamic_slice_in_dim(cache["k"], slot, 1, axis=1)
        old_v = jax.lax.dynamic_slice_in_dim(cache["v"], slot, 1, axis=1)
        overflow = pos >= length
        k_new = jnp.where(overflow, old_k, k_new)
        v_new = jnp.where(overflow, old_v, v_new)
    k_cache = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, slot, 0, 0))

    j = jnp.arange(length)
    if w > 0:
        # ring buffer: slot j holds absolute position pos - ((pos - j) % L);
        # negative => never written yet.
        slot_pos = pos - ((pos - j) % length)
        mask = slot_pos >= 0
    else:
        mask = j <= jnp.minimum(pos, length - 1)
    if _flash_decode_eligible(q, k_cache, ctx):
        valid = jnp.broadcast_to(mask[None, :], (b, length))
        o = _flash_decode(q, k_cache, v_cache, valid, ctx)
    elif _seq_parallel_decode_eligible(q, k_cache, ctx):
        o = seq_parallel_decode_attend(q, k_cache, v_cache, mask, ctx)
    else:
        o = gqa_attend(q, k_cache, v_cache, mask[None, None, None, None, :])
    o = ctx.shard(o, ctx.batch_spec, None, ctx.model_axis, None)
    out = out_proj(p, o, ctx)
    return out, {"k": k_cache, "v": v_cache}


def chunk_prefill_attention(
    p: dict,
    x: jax.Array,            # (1, C, d) — the chunk's hidden states
    cache: dict,             # paged per-layer cache (shared pool, post-decode-write)
    table: jax.Array,        # (NB,) int32 — the prefilling request's block table
    start: jax.Array,        # scalar int32 — absolute position of chunk token 0
    length: jax.Array,       # scalar int32 — valid tokens this chunk (0 = no-op)
    cfg: ModelConfig,
    ctx: ParallelCtx,
) -> tuple[jax.Array, dict]:
    """Prefill-lane attention for one chunk of one admitting request.

    Runs *inside* the fused decode step, against the same shared page pool
    the decode lane just wrote: the chunk's K/V scatter through ``table``
    at logical slots ``start + i`` (full attention only — slot j holds
    position j, so "causal within the chunk AND against already-written
    pages" is the single mask ``kpos <= start + i``). Pad rows
    (``i >= length``) scatter to the write-off page and attend to garbage;
    their outputs are masked out of MoE routing by the caller and never
    read. ``length = 0`` is the no-op chunk: one fused program serves
    idle, decode-only and decode+chunk ticks alike.

    The chunk's pages are disjoint from every live slot's table (the
    serving allocator hands them out from the same pool), so the decode
    lane never reads a half-written chunk and the chunk never perturbs a
    live request — the isolation the splice-admission path got from a
    separate batch-1 prefill, now without stalling the batch.
    """
    pool_k, pool_v = cache["pool_k"], cache["pool_v"]
    bs = pool_k.shape[1]
    nb = table.shape[0]
    cap = nb * bs
    c = x.shape[1]

    q, k, v = qkv_proj(p, x, cfg, ctx)
    pos = start + jnp.arange(c, dtype=jnp.int32)         # (C,) absolute
    if cfg.rope_theta > 0:
        q = apply_rope(q, pos[None, :], cfg.rope_theta)
        k = apply_rope(k, pos[None, :], cfg.rope_theta)

    # Scatter this chunk's K/V through the block table. Valid rows land at
    # logical slot == absolute position; pad rows go to the write-off page.
    slot = jnp.minimum(pos, cap - 1)
    valid = jnp.arange(c) < length
    trash = pool_k.shape[0] - 1
    page = jnp.where(valid, table[slot // bs], trash)    # (C,)
    row = slot % bs
    pool_k = pool_k.at[page, row].set(k[0])
    pool_v = pool_v.at[page, row].set(v[0])

    # Attend over everything written so far: previous chunks' pages plus
    # this chunk, causally. Masked (future / never-written) slots score
    # exactly zero after softmax, so the gather over the full table is
    # bit-identical to a tight prefill over the same prefix.
    from repro.kernels.flash_decode.ref import gather_pages

    k_all = gather_pages(pool_k, table[None, :])         # (1, cap, K, hd)
    v_all = gather_pages(pool_v, table[None, :])
    mask = jnp.arange(cap)[None, :] <= pos[:, None]      # (C, cap)
    o = gqa_attend(q, k_all, v_all, mask)
    o = ctx.shard(o, ctx.batch_spec, None, ctx.model_axis, None)
    out = out_proj(p, o, ctx)
    new_cache = {
        "pool_k": pool_k, "pool_v": pool_v,
        "tables": cache["tables"], "lengths": cache["lengths"],
    }
    return out, new_cache


def _seq_parallel_decode_eligible(q, k_cache, ctx: ParallelCtx) -> bool:
    """Sequence-parallel decode: the cache's seq dim rides the model axis
    and each shard runs flash-decode partials locally, LSE-merged with a
    psum (`seq_parallel_decode_attend`). The shard_map just needs the
    sharded dims to divide their axes; whether the *kernel* or the einsum
    computes the per-shard partials is decided inside the collective."""
    if not ctx.seq_parallel_kv or ctx.mesh is None or ctx.force_dense_attn:
        return False
    b, _, _, _ = q.shape
    t = k_cache.shape[1]
    return t % ctx.n_model == 0 and b % ctx.n_batch == 0


# ---------------------------------------------------------------------------
# paged decode (block-table KV walk over a shared page pool)
# ---------------------------------------------------------------------------

def _paged_decode_eligible(q, pool_k, ctx: ParallelCtx) -> bool:
    if not ctx.kernels_on or ctx.force_dense_attn:
        return False
    b, _, nh, hd = q.shape
    bs, nkv = pool_k.shape[1], pool_k.shape[2]
    if not registry.can_flash_decode_paged(
        bs, nh, nkv, hd, registry.default_interpret()
    ):
        return False
    if ctx.mesh is None:
        return True
    # Under a mesh the pool is replicated over the batch axes (pages are
    # dynamically owned — the page dim can't shard by request) and kv-heads
    # ride the model axis.
    return nh % ctx.n_model == 0 and nkv % ctx.n_model == 0 and b % ctx.n_batch == 0


def _paged_flash_decode(q, pool_k, pool_v, tables, lengths, ctx: ParallelCtx):
    """q: (B, 1, H, hd) -> (B, 1, H, hd) via the paged kernel."""
    q1 = q[:, 0]
    if ctx.mesh is None:
        o = registry.decode_attend_paged(q1, pool_k, pool_v, tables, lengths)
        return o[:, None]
    bspec, ax = ctx.batch_spec, ctx.model_axis
    o = shard_map(
        lambda qb, kb, vb, tb, lb: registry.decode_attend_paged(
            qb, kb, vb, tb, lb
        ),
        mesh=ctx.mesh,
        in_specs=(
            P(bspec, ax, None),
            P(None, None, ax, None),
            P(None, None, ax, None),
            P(bspec, None),
            P(bspec),
        ),
        out_specs=P(bspec, ax, None),
        check_vma=False,
    )(q1, pool_k, pool_v, tables, lengths)
    return o[:, None]


def _paged_decode_attention(
    p: dict,
    x: jax.Array,            # (B, 1, d)
    cache: dict,             # paged: pool_k/pool_v/tables/lengths
    cfg: ModelConfig,
    ctx: ParallelCtx,
) -> tuple[jax.Array, dict]:
    """One decode step against a paged cache.

    Per-request ``lengths`` replace the global scalar position: each
    request RoPEs and writes at its own absolute position, so batched
    requests of different context lengths decode together. The write is a
    pool scatter (page = ``tables[b, slot // bs]``, row = ``slot % bs``);
    the ring case wraps ``slot`` over the ``NB * bs`` logical slots and
    prefix validity ``min(written, NB*bs)`` is exactly the ring's live set
    (softmax is permutation-invariant; RoPE is applied at write time).
    """
    pool_k, pool_v = cache["pool_k"], cache["pool_v"]
    tables, written = cache["tables"], cache["lengths"]
    bs = pool_k.shape[1]
    cap = tables.shape[1] * bs
    w = cfg.sliding_window or 0

    q, k_new, v_new = qkv_proj(p, x, cfg, ctx)
    posb = written[:, None]  # (B, 1) — per-request position of the new token
    if cfg.rope_theta > 0:
        q = apply_rope(q, posb, cfg.rope_theta)
        k_new = apply_rope(k_new, posb, cfg.rope_theta)

    slot = written % cap if w > 0 else jnp.minimum(written, cap - 1)
    page = jnp.take_along_axis(tables, (slot // bs)[:, None], axis=1)[:, 0]
    row = slot % bs
    if w == 0:
        # Same freeze-on-overflow contract as the dense cache: a request
        # at capacity stops writing (serving refuses the step anyway).
        overflow = (written >= cap)[:, None, None, None]
        k_new = jnp.where(overflow, pool_k[page, row][:, None], k_new)
        v_new = jnp.where(overflow, pool_v[page, row][:, None], v_new)
    pool_k = pool_k.at[page, row].set(k_new[:, 0])
    pool_v = pool_v.at[page, row].set(v_new[:, 0])
    written = written + 1
    live = jnp.minimum(written, cap)

    if _paged_decode_eligible(q, pool_k, ctx):
        o = _paged_flash_decode(q, pool_k, pool_v, tables, live, ctx)
    else:
        from repro.kernels.flash_decode.ref import gather_pages

        k_all = gather_pages(pool_k, tables)
        v_all = gather_pages(pool_v, tables)
        mask = jnp.arange(cap)[None, :] < live[:, None]
        o = gqa_attend(q, k_all, v_all, mask[:, None, None, None, :])
    o = ctx.shard(o, ctx.batch_spec, None, ctx.model_axis, None)
    out = out_proj(p, o, ctx)
    new_cache = {
        "pool_k": pool_k, "pool_v": pool_v, "tables": tables, "lengths": written,
    }
    return out, new_cache
