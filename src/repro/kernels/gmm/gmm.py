"""Grouped matmul Pallas kernels (TPU target, MXU-aligned tiling).

The MoE hot spot: after capacity dispatch, expert inputs sit in dense
buckets ``(G, C, D)`` with per-group weights ``(G, D, F)``. Two kernels:

* ``gmm``         — y[g] = x[g] @ w[g], K-accumulated in VMEM scratch.
* ``gmm_dual_act``— h[g] = silu(x[g] @ wg[g]) * (x[g] @ wu[g]) — the fused
  SwiGLU first half; saves one HBM round-trip of the (G, C, F) hidden
  tensor versus two separate gmm calls + an elementwise pass.

Tiling: grid (G, C/bm, F/bn, D/bk); block shapes default to the MXU-native
128x128 (shrunk to divisors for small inputs). The K dimension is the
innermost (sequential) grid axis; the fp32 accumulator lives in VMEM
scratch and flushes on the last K step. VMEM working set per step:
bm*bk + bk*bn (+bk*bn) inputs + bm*bn fp32 accumulator(s) — ~0.3 MB at the
defaults, far under the ~16 MB v5e VMEM budget, leaving headroom for
Pallas' input double-buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _tile(n: int, pref: int) -> int:
    t = min(pref, n)
    while n % t:
        t -= 1
    return t


def _gmm_kernel(x_ref, w_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[0],
        w_ref[0],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == nk - 1)
    def _():
        o_ref[0, ...] = acc_ref[...].astype(o_ref.dtype)


def gmm(
    x: jax.Array,
    w: jax.Array,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """y[g] = x[g] @ w[g]; x: (G, C, D), w: (G, D, F) -> (G, C, F)."""
    g, c, d = x.shape
    f = w.shape[-1]
    bm, bn, bk = _tile(c, bm), _tile(f, bn), _tile(d, bk)
    nk = d // bk
    grid = (g, c // bm, f // bn, nk)
    return pl.pallas_call(
        functools.partial(_gmm_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda gi, i, j, k: (gi, i, k)),
            pl.BlockSpec((1, bk, bn), lambda gi, i, j, k: (gi, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda gi, i, j, k: (gi, i, j)),
        out_shape=jax.ShapeDtypeStruct((g, c, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)


def _gmm_dual_kernel(x_ref, wg_ref, wu_ref, o_ref, accg_ref, accu_ref, *, nk: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _():
        accg_ref[...] = jnp.zeros_like(accg_ref)
        accu_ref[...] = jnp.zeros_like(accu_ref)

    dims = (((1,), (0,)), ((), ()))
    accg_ref[...] += jax.lax.dot_general(
        x_ref[0], wg_ref[0], dims, preferred_element_type=jnp.float32
    )
    accu_ref[...] += jax.lax.dot_general(
        x_ref[0], wu_ref[0], dims, preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _():
        h = jax.nn.silu(accg_ref[...]) * accu_ref[...]
        o_ref[0, ...] = h.astype(o_ref.dtype)


def gmm_dual_act(
    x: jax.Array,
    wg: jax.Array,
    wu: jax.Array,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """h[g] = silu(x@wg) * (x@wu); fused SwiGLU front half."""
    g, c, d = x.shape
    f = wg.shape[-1]
    bm, bn, bk = _tile(c, bm), _tile(f, bn), _tile(d, bk)
    nk = d // bk
    grid = (g, c // bm, f // bn, nk)
    return pl.pallas_call(
        functools.partial(_gmm_dual_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda gi, i, j, k: (gi, i, k)),
            pl.BlockSpec((1, bk, bn), lambda gi, i, j, k: (gi, k, j)),
            pl.BlockSpec((1, bk, bn), lambda gi, i, j, k: (gi, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda gi, i, j, k: (gi, i, j)),
        out_shape=jax.ShapeDtypeStruct((g, c, f), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, bn), jnp.float32),
        ],
        interpret=interpret,
    )(x, wg, wu)
