"""Pure-jnp oracles for the grouped-matmul / fused expert-FFN kernels."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def gmm_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (G, C, D), w: (G, D, F) -> (G, C, F)."""
    return jnp.einsum("gcd,gdf->gcf", x, w)


def expert_ffn_ref(x: jax.Array, wg: jax.Array, wu: jax.Array, wd: jax.Array):
    """Fused SwiGLU expert FFN over bucketed tokens.

    x: (G, C, D); wg/wu: (G, D, F); wd: (G, F, D) -> (G, C, D).
    """
    h = jax.nn.silu(gmm_ref(x, wg)) * gmm_ref(x, wu)
    return gmm_ref(h, wd)
