"""Pure-jnp oracles for the grouped-matmul / fused expert-FFN kernels."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def gmm_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (G, C, D), w: (G, D, F) -> (G, C, F)."""
    return jnp.einsum("gcd,gdf->gcf", x, w)


def expert_ffn_ref(x: jax.Array, wg: jax.Array, wu: jax.Array, wd: jax.Array):
    """Fused SwiGLU expert FFN over bucketed tokens.

    x: (G, C, D); wg/wu: (G, D, F); wd: (G, F, D) -> (G, C, D).
    """
    h = jax.nn.silu(gmm_ref(x, wg)) * gmm_ref(x, wu)
    return gmm_ref(h, wd)


def _row_mask(c: int, group_sizes: jax.Array) -> jax.Array:
    return (jnp.arange(c)[None, :] < group_sizes[:, None])[..., None]


def _grouped(x: jax.Array, groups_per_weight: int) -> jax.Array:
    """(G, C, D) -> (G/gpw, gpw*C, D): fold weight-sharing groups together
    so the reference einsum never materializes repeated weights."""
    g, c, d = x.shape
    return x.reshape(g // groups_per_weight, groups_per_weight * c, d)


def gmm_ragged_ref(
    x: jax.Array,
    w: jax.Array,
    group_sizes: jax.Array,
    groups_per_weight: int = 1,
) -> jax.Array:
    """Oracle for ``gmm_ragged``: matmul then zero rows >= count."""
    g, c, _ = x.shape
    y = gmm_ref(_grouped(x, groups_per_weight), w).reshape(g, c, -1)
    return y * _row_mask(c, group_sizes).astype(y.dtype)


def expert_ffn_ragged_ref(
    x: jax.Array,
    wg: jax.Array,
    wu: jax.Array,
    wd: jax.Array,
    group_sizes: jax.Array | None = None,
    groups_per_weight: int = 1,
):
    """Oracle for the count-aware expert FFN (kernel semantics: rows past a
    group's count are exactly zero). ``group_sizes=None`` -> dense ffn over
    the folded groups (the padded path)."""
    g, c, _ = x.shape
    xg = _grouped(x, groups_per_weight)
    h = jax.nn.silu(gmm_ref(xg, wg)) * gmm_ref(xg, wu)
    y = gmm_ref(h, wd).reshape(g, c, -1)
    if group_sizes is None:
        return y
    return y * _row_mask(c, group_sizes).astype(y.dtype)


def gather_buckets_ref(
    x: jax.Array,            # (R, D) flat rows, bucket-contiguous
    offsets: jax.Array,      # (G,)
    group_sizes: jax.Array,  # (G,)
    capacity: int,
) -> jax.Array:
    """Oracle for the gather prologue: materialize the (G, capacity, D)
    buckets the fused kernels never write. Differentiable in ``x``."""
    r = x.shape[0]
    idx = offsets[:, None] + jnp.arange(capacity)[None, :]        # (G, cap)
    buckets = x[jnp.clip(idx, 0, max(r - 1, 0))]
    return buckets * _row_mask(capacity, group_sizes).astype(buckets.dtype)


def expert_ffn_gather_ref(
    x: jax.Array,
    wg: jax.Array,
    wu: jax.Array,
    wd: jax.Array,
    offsets: jax.Array,
    group_sizes: jax.Array,
    capacity: int,
    groups_per_weight: int = 1,
):
    """Oracle for the fused dispatch-gather expert FFN: explicit gather
    into padded buckets, then the ragged FFN oracle."""
    buckets = gather_buckets_ref(x, offsets, group_sizes, capacity)
    return expert_ffn_ragged_ref(
        buckets, wg, wu, wd, group_sizes, groups_per_weight
    )


def scatter_rows_ref(
    y: jax.Array,            # (G, capacity, D) bucket-padded values
    offsets: jax.Array,      # (G,)
    group_sizes: jax.Array,  # (G,)
    out_rows: int,
) -> jax.Array:
    """Inverse of ``gather_buckets_ref``: compact padded buckets back into
    a flat ``(out_rows, D)`` array — bucket ``g``'s first ``count_g`` rows
    land at ``[offsets[g], offsets[g] + count_g)``. Rows no live segment
    covers are zero (the kernel leaves them unspecified; callers must not
    read them either way). Differentiable in ``y``."""
    g, cap, d = y.shape
    idx = offsets[:, None] + jnp.arange(cap)[None, :]             # (G, cap)
    mask = jnp.arange(cap)[None, :] < group_sizes[:, None]
    flat = jnp.where(mask, idx, out_rows)                         # drop row
    out = jnp.zeros((out_rows + 1, d), y.dtype)
    out = out.at[flat.reshape(-1)].set(y.reshape(g * cap, d), mode="drop")
    return out[:out_rows]


def expert_ffn_compact_ref(
    x: jax.Array,
    wg: jax.Array,
    wu: jax.Array,
    wd: jax.Array,
    offsets: jax.Array,
    group_sizes: jax.Array,
    capacity: int,
    groups_per_weight: int = 1,
):
    """Oracle for the compact-output fused expert FFN (``gmm_scatter``
    epilogue): the gather-FFN oracle scattered back to flat rows at the
    same offsets — input and output share the ``(R, D)`` layout."""
    y = expert_ffn_gather_ref(
        x, wg, wu, wd, offsets, group_sizes, capacity, groups_per_weight
    )
    return scatter_rows_ref(y, offsets, group_sizes, x.shape[0])


def expert_ffn_fused_ref(
    x: jax.Array,
    wg: jax.Array,
    wu: jax.Array,
    wd: jax.Array,
    offsets: jax.Array,
    group_sizes: jax.Array,
    capacity: int,
    groups_per_weight: int = 1,
):
    """Oracle for ``gmm_fused_ffn``. The fusion is a pure execution-strategy
    change (the hidden tensor lives in VMEM instead of HBM; the math per row
    is identical), so the oracle IS the compact-output oracle: gather into
    padded buckets, SwiGLU FFN, scatter back to flat rows at the same
    offsets. Kept as its own name so call sites and tests say which kernel
    they are checking."""
    return expert_ffn_compact_ref(
        x, wg, wu, wd, offsets, group_sizes, capacity, groups_per_weight
    )
