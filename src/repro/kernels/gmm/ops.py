"""Jit'd wrappers for the grouped-matmul kernels.

``expert_ffn`` is the drop-in replacement for the three-einsum expert
compute inside the EP/ESP MoE paths: fused SwiGLU front half + gmm down
projection. ``interpret`` defaults to True off-TPU so CPU tests execute the
kernel bodies; on TPU pass interpret=False (or rely on the default).
"""

from __future__ import annotations

import functools

import jax

from repro.kernels.gmm.gmm import gmm, gmm_dual_act
from repro.kernels.gmm.ragged import (
    gmm_dual_act_gather,
    gmm_dual_act_ragged,
    gmm_fused_ffn,
    gmm_gather,
    gmm_ragged,
    gmm_scatter,
)


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("interpret",))
def gmm_op(x, w, interpret: bool | None = None):
    interpret = _default_interpret() if interpret is None else interpret
    return gmm(x, w, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def expert_ffn(x, wg, wu, wd, interpret: bool | None = None):
    """(G,C,D) x (G,D,F) x2 x (G,F,D) -> (G,C,D): fused SwiGLU expert FFN."""
    interpret = _default_interpret() if interpret is None else interpret
    h = gmm_dual_act(x, wg, wu, interpret=interpret)
    return gmm(h, wd, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("groups_per_weight", "interpret"))
def gmm_ragged_op(
    x,
    w,
    group_sizes,
    groups_per_weight: int = 1,
    interpret: bool | None = None,
):
    interpret = _default_interpret() if interpret is None else interpret
    return gmm_ragged(
        x,
        w,
        group_sizes,
        groups_per_weight=groups_per_weight,
        interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("groups_per_weight", "interpret"))
def expert_ffn_ragged(
    x,
    wg,
    wu,
    wd,
    group_sizes,
    groups_per_weight: int = 1,
    interpret: bool | None = None,
):
    """Count-aware fused SwiGLU expert FFN: FLOPs track ``sum(group_sizes)``
    instead of ``G * capacity``; rows past each group's count come out zero."""
    interpret = _default_interpret() if interpret is None else interpret
    h = gmm_dual_act_ragged(
        x, wg, wu, group_sizes,
        groups_per_weight=groups_per_weight, interpret=interpret,
    )
    return gmm_ragged(
        h, wd, group_sizes,
        groups_per_weight=groups_per_weight, interpret=interpret,
    )


@functools.partial(
    jax.jit, static_argnames=("capacity", "groups_per_weight", "interpret")
)
def gmm_gather_op(
    x,
    w,
    offsets,
    group_sizes,
    capacity: int,
    groups_per_weight: int = 1,
    interpret: bool | None = None,
):
    interpret = _default_interpret() if interpret is None else interpret
    return gmm_gather(
        x,
        w,
        offsets,
        group_sizes,
        capacity=capacity,
        groups_per_weight=groups_per_weight,
        interpret=interpret,
    )


@functools.partial(
    jax.jit, static_argnames=("capacity", "groups_per_weight", "interpret")
)
def expert_ffn_gather(
    x,
    wg,
    wu,
    wd,
    offsets,
    group_sizes,
    capacity: int,
    groups_per_weight: int = 1,
    interpret: bool | None = None,
):
    """Fused dispatch-scatter expert FFN: the SwiGLU front half gathers
    token rows straight from the flat ``(R, D)`` activations (per-bucket
    offsets in scalar prefetch), the down projection runs ragged over the
    bucket-padded hidden tensor. The ``(G, capacity, D)`` input buffer is
    never materialized."""
    interpret = _default_interpret() if interpret is None else interpret
    h = gmm_dual_act_gather(
        x, wg, wu, offsets, group_sizes,
        capacity=capacity, groups_per_weight=groups_per_weight,
        interpret=interpret,
    )
    return gmm_ragged(
        h, wd, group_sizes,
        groups_per_weight=groups_per_weight, interpret=interpret,
    )


@functools.partial(
    jax.jit, static_argnames=("out_rows", "groups_per_weight", "interpret")
)
def gmm_scatter_op(
    x,
    w,
    offsets,
    group_sizes,
    out_rows: int,
    groups_per_weight: int = 1,
    interpret: bool | None = None,
):
    interpret = _default_interpret() if interpret is None else interpret
    return gmm_scatter(
        x,
        w,
        offsets,
        group_sizes,
        out_rows=out_rows,
        groups_per_weight=groups_per_weight,
        interpret=interpret,
    )


@functools.partial(
    jax.jit, static_argnames=("capacity", "groups_per_weight", "interpret")
)
def expert_ffn_gather_compact(
    x,
    wg,
    wu,
    wd,
    offsets,
    group_sizes,
    capacity: int,
    groups_per_weight: int = 1,
    interpret: bool | None = None,
):
    """Fully compact fused expert FFN: the gather prologue reads token rows
    from the flat ``(R, D)`` activations and the ``gmm_scatter`` epilogue
    writes the down-projection back at the same per-bucket offsets —
    neither the padded FFN *input* nor *output* buffer ever exists; only
    the bucket-padded hidden tensor remains."""
    interpret = _default_interpret() if interpret is None else interpret
    h = gmm_dual_act_gather(
        x, wg, wu, offsets, group_sizes,
        capacity=capacity, groups_per_weight=groups_per_weight,
        interpret=interpret,
    )
    return gmm_scatter(
        h, wd, offsets, group_sizes,
        out_rows=x.shape[0], groups_per_weight=groups_per_weight,
        interpret=interpret,
    )


@functools.partial(
    jax.jit, static_argnames=("capacity", "groups_per_weight", "interpret")
)
def expert_ffn_fused(
    x,
    wg,
    wu,
    wd,
    offsets,
    group_sizes,
    capacity: int,
    groups_per_weight: int = 1,
    interpret: bool | None = None,
):
    """Fully-fused single-kernel expert FFN (``gmm_fused_ffn``): gather
    prologue, VMEM-resident SwiGLU hidden tiles, down-projection, scatter
    epilogue — same flat-in/flat-out contract as ``expert_ffn_gather_compact``
    but the bucket-padded ``(G, capacity, F)`` hidden tensor between the two
    halves never round-trips HBM."""
    interpret = _default_interpret() if interpret is None else interpret
    return gmm_fused_ffn(
        x, wg, wu, wd, offsets, group_sizes,
        capacity=capacity, groups_per_weight=groups_per_weight,
        interpret=interpret,
    )
