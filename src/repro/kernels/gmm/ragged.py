"""Count-aware (ragged) grouped matmul Pallas kernels.

Megablocks-style refinement of ``gmm``/``gmm_dual_act``: the per-group token
counts (``group_sizes``, int32 ``(G,)``) ride in as a scalar-prefetch operand
(SMEM), and each row-tile checks ``mi * bm < count`` before touching the MXU.
Row-tiles entirely past a group's count skip both matmuls; partially-filled
tiles mask their tail rows to zero on the final K step. MXU FLOPs therefore
scale with ``sum(ceil(count / bm) * bm)`` ≈ tokens actually routed, not
``G * capacity`` — on the skewed routing distributions the paper targets
(fig. 6) that's the bulk of the padded EP FFN cost.

``groups_per_weight`` (gpw) lets ``gpw`` consecutive x-groups share one
weight row — the layout both MoE paths produce after flattening:

* EP after the all_to_all: ``(slots_per_device, ep, cap, d)`` flattens to
  ``G = slots_per_device * ep`` groups, weight row ``gi // ep``;
* ESP local buckets: ``(E, n_batch_groups, cap, d)`` flattens to
  ``G = E * n_groups`` groups, weight row ``gi // n_groups``.

VMEM per step matches the padded kernels (the scalar counts live in SMEM);
the grid is identical, so the only cost of raggedness is the SMEM read and
the per-tile predicate.

``gmm_gather`` / ``gmm_dual_act_gather`` go one step further and fuse the
*dispatch* into the kernel prologue: instead of consuming pre-packed
``(G, capacity, d)`` buffers, they read token rows straight out of a flat
``(R, d)`` activations array in which bucket ``g``'s rows sit contiguously
at ``[offsets[g], offsets[g] + counts[g])`` (the compacted order
``dispatch_metadata`` emits). Both ``offsets`` and ``counts`` ride as
scalar-prefetch operands; each live row-tile issues one dynamic-offset DMA
(``pltpu.make_async_copy`` from the ANY-space flat array into a VMEM
scratch tile) and feeds the MXU from the scratch. The gathers are
**double-buffered** against the MXU: two scratch tiles + two DMA
semaphores, and every grid step starts the *next* live tile's copy before
waiting on its own, so the fetch for tile ``t+1`` overlaps tile ``t``'s
matmul (``_gather_pipeline``). The padded bucket tensor is never
materialized in HBM — that's the one dispatch round-trip per MoE layer the
fused path removes. Dead tiles skip the DMA *and* the MXU, so the ragged
FLOP/byte accounting is unchanged.

``gmm_fused_ffn`` chains all three of the above into **one** kernel: the
gather prologue reads flat ``(R, d)`` token rows, the dual-activation
SwiGLU front half produces per-tile hidden activations
``silu(x @ wg) * (x @ wu)`` — shape ``(bm, F)``, computed ``(bm, bf)``
block by block and consumed *immediately* by the down-projection into a
``(bm, d)`` VMEM output accumulator — and the scatter epilogue stores the
finished row-tile back at the same per-bucket offsets. The bucket-padded
``(G, capacity, F)`` hidden tensor between the front half and the down
projection, the last padded intermediate on the expert hot path, **never
exists in HBM**: the only HBM tensors the kernel touches are the flat
input rows, the three weight stacks, and the flat compact output. The
grid is ``(G, capacity/bm, F/bf, d/bk)``; for each row-tile the ``jf``
loop walks hidden blocks (each fully reduced over ``k`` before the next
starts) and ``out_acc += h_jf @ wd[jf]`` retires each hidden block the
step it is produced, so peak VMEM holds one ``(bm, bf)`` hidden block
plus the ``(bm, d)`` accumulator — independent of ``F``. The gather DMA
double-buffering, the store serialization, and the partial-tile
spill-overwrite contract (``capacity % bm == 0`` keeps padded spans
inside their rank segment) are inherited unchanged from the pieces below.

``gmm_scatter`` is the *combine*-leg mirror of the gather prologue: a
ragged grouped matmul (the expert down-projection) whose **epilogue writes
result tiles back at the same per-bucket offsets** — a dynamic-offset
store DMA from a VMEM staging tile into a flat ``(out_rows, d)`` ANY-space
output, so the bucket-padded ``(G, capacity, d)`` FFN *output* buffer is
never written to HBM either. Live tiles mask their tail rows to zero
before storing; a partial tile's ``bm``-row store may therefore spill
zeros past its bucket's segment, which is safe because (contract) each
bucket's padded span ``[offsets[g], offsets[g] + ceil(count/bm)*bm)`` may
only overlap rows of *later-in-grid* buckets — those overwrite the spill
with their real rows (stores are issued and completed in grid order: each
store waits for the previous one before starting, so a store is in flight
across all the MXU work until the next store point). Both layouts the MoE
paths produce satisfy the contract: offsets are non-decreasing in grid
order per rank segment and ``capacity % bm == 0`` keeps padded spans
inside their segment. Rows not covered by any live ``(bucket, position)``
pair are *unwritten garbage* — the metadata-driven combine
(``collectives.combine_from_rows``) never addresses them. Dead tiles skip
the MXU and the store, so at skewed routing the combine-leg HBM bytes
track routed tokens, exactly like the dispatch leg.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.gmm.gmm import _tile


def _ragged_kernel(gs_ref, x_ref, w_ref, o_ref, acc_ref, *, nk: int, bm: int):
    gi = pl.program_id(0)
    mi = pl.program_id(1)
    k = pl.program_id(3)
    count = gs_ref[gi]
    live = mi * bm < count

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(live)
    def _():
        acc_ref[...] += jax.lax.dot_general(
            x_ref[0],
            w_ref[0],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(k == nk - 1)
    def _():
        rows = mi * bm + jax.lax.broadcasted_iota(jnp.int32, acc_ref.shape, 0)
        o_ref[0, ...] = jnp.where(rows < count, acc_ref[...], 0.0).astype(
            o_ref.dtype
        )


def gmm_ragged(
    x: jax.Array,            # (G, C, D)
    w: jax.Array,            # (G // gpw, D, F)
    group_sizes: jax.Array,  # (G,) int32 — valid leading rows per group
    *,
    groups_per_weight: int = 1,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """y[g, :count_g] = x[g, :count_g] @ w[g // gpw]; tail rows are zero."""
    g, c, d = x.shape
    f = w.shape[-1]
    gpw = groups_per_weight
    assert g == w.shape[0] * gpw, (g, w.shape, gpw)
    bm, bn, bk = _tile(c, bm), _tile(f, bn), _tile(d, bk)
    nk = d // bk
    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(g, c // bm, f // bn, nk),
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda gi, i, j, k, gs: (gi, i, k)),
            pl.BlockSpec((1, bk, bn), lambda gi, i, j, k, gs: (gi // gpw, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda gi, i, j, k, gs: (gi, i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_ragged_kernel, nk=nk, bm=bm),
        grid_spec=spec,
        out_shape=jax.ShapeDtypeStruct((g, c, f), x.dtype),
        interpret=interpret,
    )(group_sizes.astype(jnp.int32), x, w)


def _ragged_dual_kernel(
    gs_ref, x_ref, wg_ref, wu_ref, o_ref, accg_ref, accu_ref, *, nk: int, bm: int
):
    gi = pl.program_id(0)
    mi = pl.program_id(1)
    k = pl.program_id(3)
    count = gs_ref[gi]
    live = mi * bm < count

    @pl.when(k == 0)
    def _():
        accg_ref[...] = jnp.zeros_like(accg_ref)
        accu_ref[...] = jnp.zeros_like(accu_ref)

    @pl.when(live)
    def _():
        dims = (((1,), (0,)), ((), ()))
        accg_ref[...] += jax.lax.dot_general(
            x_ref[0], wg_ref[0], dims, preferred_element_type=jnp.float32
        )
        accu_ref[...] += jax.lax.dot_general(
            x_ref[0], wu_ref[0], dims, preferred_element_type=jnp.float32
        )

    @pl.when(k == nk - 1)
    def _():
        rows = mi * bm + jax.lax.broadcasted_iota(jnp.int32, accg_ref.shape, 0)
        h = jax.nn.silu(accg_ref[...]) * accu_ref[...]
        o_ref[0, ...] = jnp.where(rows < count, h, 0.0).astype(o_ref.dtype)


def gmm_dual_act_ragged(
    x: jax.Array,
    wg: jax.Array,
    wu: jax.Array,
    group_sizes: jax.Array,
    *,
    groups_per_weight: int = 1,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """h[g] = silu(x@wg) * (x@wu) on the first count_g rows; tail is zero."""
    g, c, d = x.shape
    f = wg.shape[-1]
    gpw = groups_per_weight
    assert g == wg.shape[0] * gpw, (g, wg.shape, gpw)
    bm, bn, bk = _tile(c, bm), _tile(f, bn), _tile(d, bk)
    nk = d // bk
    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(g, c // bm, f // bn, nk),
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda gi, i, j, k, gs: (gi, i, k)),
            pl.BlockSpec((1, bk, bn), lambda gi, i, j, k, gs: (gi // gpw, k, j)),
            pl.BlockSpec((1, bk, bn), lambda gi, i, j, k, gs: (gi // gpw, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda gi, i, j, k, gs: (gi, i, j)),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, bn), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_ragged_dual_kernel, nk=nk, bm=bm),
        grid_spec=spec,
        out_shape=jax.ShapeDtypeStruct((g, c, f), x.dtype),
        interpret=interpret,
    )(group_sizes.astype(jnp.int32), x, wg, wu)


# ---------------------------------------------------------------------------
# fused dispatch-gather variants (flat rows + per-bucket offsets)
# ---------------------------------------------------------------------------

def _pad_rows(x: jax.Array, bm: int) -> tuple[jax.Array, int]:
    """Append ``bm`` zero rows so a tile DMA starting anywhere inside a
    valid segment never runs off the end of the flat array (partial tiles
    over-read up to ``bm - 1`` rows; the tail is masked in the epilogue)."""
    return jnp.pad(x, ((0, bm), (0, 0))), x.shape[0] + bm


def _gather_dma(x_any, xbuf, sem, off_ref, gi, mi, k, slot, *, bm, bk, r_max):
    """Descriptor for the (bm, bk) row-tile DMA of bucket ``gi`` into
    double-buffer ``slot`` (start and wait happen at the call sites)."""
    start = jnp.minimum(off_ref[gi] + mi * bm, r_max)
    return pltpu.make_async_copy(
        x_any.at[pl.ds(start, bm), pl.ds(k * bk, bk)],
        xbuf.at[slot],
        sem.at[slot],
    )


def _gather_pipeline(gs_ref, *, g, nmi, nj, nk, bm):
    """Double-buffer bookkeeping shared by the gather kernels.

    Returns ``(live, t, nxt)``: this step's liveness, its linear step index
    (slot = ``t % 2``), and — for the *next* grid step in row-major order —
    ``(gi, mi, k, live)`` so its DMA can start before this step waits on
    its own (overlapping the copy with this step's MXU work)."""
    gi = pl.program_id(0)
    mi = pl.program_id(1)
    j = pl.program_id(2)
    k = pl.program_id(3)
    live = mi * bm < gs_ref[gi]
    t = ((gi * nmi + mi) * nj + j) * nk + k

    k1 = k + 1
    kr = (k1 == nk).astype(jnp.int32)
    k1 = k1 * (1 - kr)
    j1 = j + kr
    jr = (j1 == nj).astype(jnp.int32)
    j1 = j1 * (1 - jr)
    mi1 = mi + jr
    mr = (mi1 == nmi).astype(jnp.int32)
    mi1 = mi1 * (1 - mr)
    gi1 = gi + mr
    has_next = gi1 < g
    next_live = has_next & (mi1 * bm < gs_ref[jnp.minimum(gi1, g - 1)])
    return live, t, (gi1, mi1, k1, next_live)


def _gather_kernel(
    off_ref, gs_ref, x_any, w_ref, o_ref, acc_ref, xbuf, sem,
    *, g: int, nmi: int, nj: int, nk: int, bm: int, bk: int, r_max: int,
):
    gi = pl.program_id(0)
    mi = pl.program_id(1)
    k = pl.program_id(3)
    count = gs_ref[gi]
    live, t, (gi1, mi1, k1, next_live) = _gather_pipeline(
        gs_ref, g=g, nmi=nmi, nj=nj, nk=nk, bm=bm
    )
    dma = functools.partial(
        _gather_dma, x_any, xbuf, sem, off_ref, bm=bm, bk=bk, r_max=r_max
    )

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Warm-up: the very first grid step fetches its own tile.
    @pl.when((t == 0) & live)
    def _():
        dma(gi, mi, k, 0).start()

    # Pipeline: start the next live step's gather into the other buffer
    # before waiting on ours — the copy overlaps this step's matmul.
    @pl.when(next_live)
    def _():
        dma(gi1, mi1, k1, (t + 1) % 2).start()

    @pl.when(live)
    def _():
        dma(gi, mi, k, t % 2).wait()
        acc_ref[...] += jax.lax.dot_general(
            xbuf[t % 2],
            w_ref[0],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(k == nk - 1)
    def _():
        rows = mi * bm + jax.lax.broadcasted_iota(jnp.int32, acc_ref.shape, 0)
        o_ref[0, ...] = jnp.where(rows < count, acc_ref[...], 0.0).astype(
            o_ref.dtype
        )


def gmm_gather(
    x: jax.Array,            # (R, D) flat token rows, bucket-contiguous
    w: jax.Array,            # (G // gpw, D, F)
    offsets: jax.Array,      # (G,) int32 — bucket g's first row in x
    group_sizes: jax.Array,  # (G,) int32 — bucket g's row count
    *,
    capacity: int,
    groups_per_weight: int = 1,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """y[g, :count_g] = x[offsets[g] : offsets[g]+count_g] @ w[g // gpw].

    Output is bucket-padded ``(G, capacity, F)`` with zero tails (identical
    contract to ``gmm_ragged``), but the input is the *flat* compacted rows
    — no ``(G, capacity, D)`` buffer ever exists.
    """
    r, d = x.shape
    f = w.shape[-1]
    gpw = groups_per_weight
    g = w.shape[0] * gpw
    assert offsets.shape == (g,), (offsets.shape, g)
    bm, bn, bk = _tile(capacity, bm), _tile(f, bn), _tile(d, bk)
    x, r_pad = _pad_rows(x, bm)
    nk = d // bk
    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(g, capacity // bm, f // bn, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec((1, bk, bn), lambda gi, i, j, k, off, gs: (gi // gpw, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda gi, i, j, k, off, gs: (gi, i, j)),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((2, bm, bk), x.dtype),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    return pl.pallas_call(
        functools.partial(
            _gather_kernel,
            g=g, nmi=capacity // bm, nj=f // bn, nk=nk,
            bm=bm, bk=bk, r_max=r_pad - bm,
        ),
        grid_spec=spec,
        out_shape=jax.ShapeDtypeStruct((g, capacity, f), x.dtype),
        interpret=interpret,
    )(offsets.astype(jnp.int32), group_sizes.astype(jnp.int32), x, w)


def _gather_dual_kernel(
    off_ref, gs_ref, x_any, wg_ref, wu_ref, o_ref, accg_ref, accu_ref, xbuf, sem,
    *, g: int, nmi: int, nj: int, nk: int, bm: int, bk: int, r_max: int,
):
    gi = pl.program_id(0)
    mi = pl.program_id(1)
    k = pl.program_id(3)
    count = gs_ref[gi]
    live, t, (gi1, mi1, k1, next_live) = _gather_pipeline(
        gs_ref, g=g, nmi=nmi, nj=nj, nk=nk, bm=bm
    )
    dma = functools.partial(
        _gather_dma, x_any, xbuf, sem, off_ref, bm=bm, bk=bk, r_max=r_max
    )

    @pl.when(k == 0)
    def _():
        accg_ref[...] = jnp.zeros_like(accg_ref)
        accu_ref[...] = jnp.zeros_like(accu_ref)

    @pl.when((t == 0) & live)
    def _():
        dma(gi, mi, k, 0).start()

    @pl.when(next_live)
    def _():
        dma(gi1, mi1, k1, (t + 1) % 2).start()

    @pl.when(live)
    def _():
        dma(gi, mi, k, t % 2).wait()
        dims = (((1,), (0,)), ((), ()))
        accg_ref[...] += jax.lax.dot_general(
            xbuf[t % 2], wg_ref[0], dims, preferred_element_type=jnp.float32
        )
        accu_ref[...] += jax.lax.dot_general(
            xbuf[t % 2], wu_ref[0], dims, preferred_element_type=jnp.float32
        )

    @pl.when(k == nk - 1)
    def _():
        rows = mi * bm + jax.lax.broadcasted_iota(jnp.int32, accg_ref.shape, 0)
        h = jax.nn.silu(accg_ref[...]) * accu_ref[...]
        o_ref[0, ...] = jnp.where(rows < count, h, 0.0).astype(o_ref.dtype)


def gmm_dual_act_gather(
    x: jax.Array,            # (R, D) flat token rows, bucket-contiguous
    wg: jax.Array,           # (G // gpw, D, F)
    wu: jax.Array,           # (G // gpw, D, F)
    offsets: jax.Array,      # (G,)
    group_sizes: jax.Array,  # (G,)
    *,
    capacity: int,
    groups_per_weight: int = 1,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """h[g] = silu(rows_g @ wg) * (rows_g @ wu) with the fused gather
    prologue; rows_g are read from the flat array via per-bucket offsets."""
    r, d = x.shape
    f = wg.shape[-1]
    gpw = groups_per_weight
    g = wg.shape[0] * gpw
    assert offsets.shape == (g,), (offsets.shape, g)
    bm, bn, bk = _tile(capacity, bm), _tile(f, bn), _tile(d, bk)
    x, r_pad = _pad_rows(x, bm)
    nk = d // bk
    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(g, capacity // bm, f // bn, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec((1, bk, bn), lambda gi, i, j, k, off, gs: (gi // gpw, k, j)),
            pl.BlockSpec((1, bk, bn), lambda gi, i, j, k, off, gs: (gi // gpw, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda gi, i, j, k, off, gs: (gi, i, j)),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((2, bm, bk), x.dtype),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    return pl.pallas_call(
        functools.partial(
            _gather_dual_kernel,
            g=g, nmi=capacity // bm, nj=f // bn, nk=nk,
            bm=bm, bk=bk, r_max=r_pad - bm,
        ),
        grid_spec=spec,
        out_shape=jax.ShapeDtypeStruct((g, capacity, f), x.dtype),
        interpret=interpret,
    )(offsets.astype(jnp.int32), group_sizes.astype(jnp.int32), x, wg, wu)


# ---------------------------------------------------------------------------
# fused compact-scatter variant (flat-row output at per-bucket offsets)
# ---------------------------------------------------------------------------

def _scatter_store(o_any, obuf, sem, off_ref, gi, mi, j, *, bm, bn, r_max):
    """Descriptor for the (bm, bn) result-tile store of bucket ``gi`` row-
    tile ``mi`` / column block ``j`` into the flat output (start and wait
    happen at the call sites; the clamp only guards bogus offsets — live
    tiles of a well-formed layout never hit it)."""
    start = jnp.minimum(off_ref[gi] + mi * bm, r_max)
    return pltpu.make_async_copy(
        obuf,
        o_any.at[pl.ds(start, bm), pl.ds(j * bn, bn)],
        sem,
    )


def _scatter_kernel(
    off_ref, gs_ref, x_ref, w_ref, o_any, acc_ref, obuf, pend, sem,
    *, nsteps: int, nk: int, bm: int, bn: int, r_max: int,
):
    gi = pl.program_id(0)
    mi = pl.program_id(1)
    j = pl.program_id(2)
    k = pl.program_id(3)
    count = gs_ref[gi]
    live = mi * bm < count
    t = ((gi * pl.num_programs(1) + mi) * pl.num_programs(2) + j) * nk + k
    store = functools.partial(
        _scatter_store, o_any, obuf, sem, off_ref, bm=bm, bn=bn, r_max=r_max
    )

    @pl.when(t == 0)
    def _():
        pend[0] = 0  # no store in flight yet

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(live)
    def _():
        acc_ref[...] += jax.lax.dot_general(
            x_ref[0],
            w_ref[0],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    # Epilogue: stage the masked tile in VMEM and DMA it to the flat
    # output at the bucket's offset. Stores are serialized against each
    # other (wait the previous store before reusing the staging tile),
    # which both frees the buffer and guarantees grid-order completion —
    # the overlap-overwrite contract in the module docstring — while each
    # store still overlaps all MXU work up to the next store point.
    @pl.when((k == nk - 1) & live)
    def _():
        @pl.when(pend[0] == 1)
        def _():
            store(pend[1], pend[2], pend[3]).wait()

        rows = mi * bm + jax.lax.broadcasted_iota(jnp.int32, acc_ref.shape, 0)
        obuf[...] = jnp.where(rows < count, acc_ref[...], 0.0).astype(obuf.dtype)
        store(gi, mi, j).start()
        pend[0] = 1
        pend[1] = gi
        pend[2] = mi
        pend[3] = j

    # Drain: the final grid step waits out the last in-flight store.
    @pl.when((t == nsteps - 1) & (pend[0] == 1))
    def _():
        store(pend[1], pend[2], pend[3]).wait()
        pend[0] = 0


def gmm_scatter(
    x: jax.Array,            # (G, C, D) bucket-padded rows (ragged fill)
    w: jax.Array,            # (G // gpw, D, F)
    offsets: jax.Array,      # (G,) int32 — bucket g's first output row
    group_sizes: jax.Array,  # (G,) int32 — bucket g's live row count
    *,
    out_rows: int,
    groups_per_weight: int = 1,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """out[offsets[g] : offsets[g]+count_g] = x[g, :count_g] @ w[g // gpw].

    The compact mirror of ``gmm_gather``: same grouped matmul, but the
    epilogue scatters result tiles into a flat ``(out_rows, F)`` array at
    the scalar-prefetched per-bucket offsets instead of emitting the
    padded ``(G, capacity, F)`` tensor. Output rows outside every live
    segment are unspecified (zero where a partial tile spilled, garbage
    where never written) — callers gather exclusively through the
    dispatch metadata. See the module docstring for the non-overlap
    contract on ``offsets``.
    """
    g, c, d = x.shape
    f = w.shape[-1]
    gpw = groups_per_weight
    assert g == w.shape[0] * gpw, (g, w.shape, gpw)
    assert offsets.shape == (g,), (offsets.shape, g)
    bm, bn, bk = _tile(c, bm), _tile(f, bn), _tile(d, bk)
    nk = d // bk
    nmi, nj = c // bm, f // bn
    out_pad = out_rows + bm  # a partial tile's spill never runs off the end
    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(g, nmi, nj, nk),
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda gi, i, j, k, off, gs: (gi, i, k)),
            pl.BlockSpec((1, bk, bn), lambda gi, i, j, k, off, gs: (gi // gpw, k, j)),
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, bn), x.dtype),
            pltpu.SMEM((4,), jnp.int32),
            pltpu.SemaphoreType.DMA(()),
        ],
    )
    out = pl.pallas_call(
        functools.partial(
            _scatter_kernel,
            nsteps=g * nmi * nj * nk, nk=nk,
            bm=bm, bn=bn, r_max=out_pad - bm,
        ),
        grid_spec=spec,
        out_shape=jax.ShapeDtypeStruct((out_pad, f), x.dtype),
        interpret=interpret,
    )(offsets.astype(jnp.int32), group_sizes.astype(jnp.int32), x, w)
    return out[:out_rows]


# ---------------------------------------------------------------------------
# fully-fused SwiGLU expert FFN (gather prologue + VMEM hidden + scatter)
# ---------------------------------------------------------------------------

def _fused_ffn_kernel(
    off_ref, gs_ref, x_any, wg_ref, wu_ref, wd_ref, o_any,
    accg_ref, accu_ref, out_ref, xbuf, gsem, obuf, pend, osem,
    *, g: int, nmi: int, nj: int, nk: int, nsteps: int,
    bm: int, bk: int, dn: int, r_max_in: int, r_max_out: int,
):
    gi = pl.program_id(0)
    mi = pl.program_id(1)
    jf = pl.program_id(2)
    k = pl.program_id(3)
    count = gs_ref[gi]
    live, t, (gi1, mi1, k1, next_live) = _gather_pipeline(
        gs_ref, g=g, nmi=nmi, nj=nj, nk=nk, bm=bm
    )
    gather = functools.partial(
        _gather_dma, x_any, xbuf, gsem, off_ref, bm=bm, bk=bk, r_max=r_max_in
    )
    store = functools.partial(
        _scatter_store, o_any, obuf, osem, off_ref, bm=bm, bn=dn, r_max=r_max_out
    )

    @pl.when(t == 0)
    def _():
        pend[0] = 0  # no store in flight yet

    # A fresh row-tile: reset the (bm, dn) output accumulator. It survives
    # the whole (jf, k) loop nest — one full hidden row per token row is
    # reduced into it without ever leaving VMEM.
    @pl.when((jf == 0) & (k == 0))
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(k == 0)
    def _():
        accg_ref[...] = jnp.zeros_like(accg_ref)
        accu_ref[...] = jnp.zeros_like(accu_ref)

    # Gather prologue: identical double-buffered pipeline to the
    # stand-alone gather kernels (warm-up fetch + next-step prefetch).
    @pl.when((t == 0) & live)
    def _():
        gather(gi, mi, k, 0).start()

    @pl.when(next_live)
    def _():
        gather(gi1, mi1, k1, (t + 1) % 2).start()

    @pl.when(live)
    def _():
        gather(gi, mi, k, t % 2).wait()
        dims = (((1,), (0,)), ((), ()))
        accg_ref[...] += jax.lax.dot_general(
            xbuf[t % 2], wg_ref[0], dims, preferred_element_type=jnp.float32
        )
        accu_ref[...] += jax.lax.dot_general(
            xbuf[t % 2], wu_ref[0], dims, preferred_element_type=jnp.float32
        )

    # Hidden block jf is fully reduced: apply the dual activation and
    # retire it straight into the down-projection accumulator. The cast to
    # the I/O dtype reproduces the unfused pair bit-for-bit (there the
    # hidden tensor round-trips HBM at the I/O dtype); masked tail rows
    # stay exactly zero so the final store's spill contract holds.
    @pl.when((k == nk - 1) & live)
    def _():
        rows = mi * bm + jax.lax.broadcasted_iota(jnp.int32, accg_ref.shape, 0)
        h = jnp.where(
            rows < count, jax.nn.silu(accg_ref[...]) * accu_ref[...], 0.0
        ).astype(obuf.dtype)
        out_ref[...] += jax.lax.dot_general(
            h,
            wd_ref[0],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    # Scatter epilogue (last hidden block of a live row-tile): stage the
    # finished (bm, dn) tile and DMA it to the flat output at the bucket's
    # offset — same serialized-store bookkeeping as ``gmm_scatter`` (wait
    # the previous store before reusing the staging tile; completion order
    # == grid order, which is what makes partial-tile spills safe).
    @pl.when((jf == nj - 1) & (k == nk - 1) & live)
    def _():
        @pl.when(pend[0] == 1)
        def _():
            store(pend[1], pend[2], pend[3]).wait()

        rows = mi * bm + jax.lax.broadcasted_iota(jnp.int32, out_ref.shape, 0)
        obuf[...] = jnp.where(rows < count, out_ref[...], 0.0).astype(obuf.dtype)
        store(gi, mi, 0).start()
        pend[0] = 1
        pend[1] = gi
        pend[2] = mi
        pend[3] = 0

    # Drain: the final grid step waits out the last in-flight store.
    @pl.when((t == nsteps - 1) & (pend[0] == 1))
    def _():
        store(pend[1], pend[2], pend[3]).wait()
        pend[0] = 0


def gmm_fused_ffn(
    x: jax.Array,            # (R, D) flat token rows, bucket-contiguous
    wg: jax.Array,           # (G // gpw, D, F)
    wu: jax.Array,           # (G // gpw, D, F)
    wd: jax.Array,           # (G // gpw, F, D_out)
    offsets: jax.Array,      # (G,) int32 — bucket g's first row (in and out)
    group_sizes: jax.Array,  # (G,) int32 — bucket g's live row count
    *,
    capacity: int,
    out_rows: int | None = None,
    groups_per_weight: int = 1,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Fully-fused SwiGLU expert FFN over flat compacted rows.

    ``out[offsets[g] : offsets[g]+count_g] =
    (silu(rows_g @ wg) * (rows_g @ wu)) @ wd`` with ``rows_g =
    x[offsets[g] : offsets[g]+count_g]``. One kernel: the gather prologue
    reads each live ``(bm, bk)`` input tile by dynamic-offset DMA, the
    dual-activation front half reduces hidden blocks in VMEM, the
    down-projection retires each block into a ``(bm, D_out)`` accumulator,
    and the scatter epilogue stores the tile back at the same offsets.
    The padded ``(G, capacity, F)`` hidden tensor never touches HBM —
    hidden-leg HBM bytes are exactly zero. Output rows outside every live
    segment follow the ``gmm_scatter`` contract (zero where a partial tile
    spilled, unwritten garbage otherwise); callers combine through the
    dispatch metadata. Dead tiles skip the DMA, both MXU passes, and the
    store.
    """
    r, d = x.shape
    f = wg.shape[-1]
    dn = wd.shape[-1]
    gpw = groups_per_weight
    g = wg.shape[0] * gpw
    assert offsets.shape == (g,), (offsets.shape, g)
    assert wd.shape[-2] == f, (wd.shape, f)
    out_rows = r if out_rows is None else out_rows
    bm, bf, bk = _tile(capacity, bm), _tile(f, bn), _tile(d, bk)
    x, r_pad = _pad_rows(x, bm)
    nk = d // bk
    nmi, nj = capacity // bm, f // bf
    out_pad = out_rows + bm  # a partial tile's spill never runs off the end
    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(g, nmi, nj, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec((1, bk, bf), lambda gi, i, j, k, off, gs: (gi // gpw, k, j)),
            pl.BlockSpec((1, bk, bf), lambda gi, i, j, k, off, gs: (gi // gpw, k, j)),
            pl.BlockSpec((1, bf, dn), lambda gi, i, j, k, off, gs: (gi // gpw, j, 0)),
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
        scratch_shapes=[
            pltpu.VMEM((bm, bf), jnp.float32),   # gate accumulator
            pltpu.VMEM((bm, bf), jnp.float32),   # up accumulator
            pltpu.VMEM((bm, dn), jnp.float32),   # down-proj accumulator
            pltpu.VMEM((2, bm, bk), x.dtype),    # gather double-buffer
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.VMEM((bm, dn), x.dtype),       # store staging tile
            pltpu.SMEM((4,), jnp.int32),         # pending-store bookkeeping
            pltpu.SemaphoreType.DMA(()),
        ],
    )
    out = pl.pallas_call(
        functools.partial(
            _fused_ffn_kernel,
            g=g, nmi=nmi, nj=nj, nk=nk, nsteps=g * nmi * nj * nk,
            bm=bm, bk=bk, dn=dn,
            r_max_in=r_pad - bm, r_max_out=out_pad - bm,
        ),
        grid_spec=spec,
        out_shape=jax.ShapeDtypeStruct((out_pad, dn), x.dtype),
        interpret=interpret,
    )(offsets.astype(jnp.int32), group_sizes.astype(jnp.int32), x, wg, wu, wd)
    return out[:out_rows]
