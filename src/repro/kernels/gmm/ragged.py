"""Count-aware (ragged) grouped matmul Pallas kernels.

Megablocks-style refinement of ``gmm``/``gmm_dual_act``: the per-group token
counts (``group_sizes``, int32 ``(G,)``) ride in as a scalar-prefetch operand
(SMEM), and each row-tile checks ``mi * bm < count`` before touching the MXU.
Row-tiles entirely past a group's count skip both matmuls; partially-filled
tiles mask their tail rows to zero on the final K step. MXU FLOPs therefore
scale with ``sum(ceil(count / bm) * bm)`` ≈ tokens actually routed, not
``G * capacity`` — on the skewed routing distributions the paper targets
(fig. 6) that's the bulk of the padded EP FFN cost.

``groups_per_weight`` (gpw) lets ``gpw`` consecutive x-groups share one
weight row — the layout both MoE paths produce after flattening:

* EP after the all_to_all: ``(slots_per_device, ep, cap, d)`` flattens to
  ``G = slots_per_device * ep`` groups, weight row ``gi // ep``;
* ESP local buckets: ``(E, n_batch_groups, cap, d)`` flattens to
  ``G = E * n_groups`` groups, weight row ``gi // n_groups``.

VMEM per step matches the padded kernels (the scalar counts live in SMEM);
the grid is identical, so the only cost of raggedness is the SMEM read and
the per-tile predicate.

``gmm_gather`` / ``gmm_dual_act_gather`` go one step further and fuse the
*dispatch* into the kernel prologue: instead of consuming pre-packed
``(G, capacity, d)`` buffers, they read token rows straight out of a flat
``(R, d)`` activations array in which bucket ``g``'s rows sit contiguously
at ``[offsets[g], offsets[g] + counts[g])`` (the compacted order
``dispatch_metadata`` emits). Both ``offsets`` and ``counts`` ride as
scalar-prefetch operands; each live row-tile issues one dynamic-offset DMA
(``pltpu.make_async_copy`` from the ANY-space flat array into a VMEM
scratch tile) and feeds the MXU from the scratch. The padded bucket tensor
is never materialized in HBM — that's the one dispatch round-trip per MoE
layer the fused path removes. Dead tiles skip the DMA *and* the MXU, so
the ragged FLOP/byte accounting is unchanged.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.gmm.gmm import _tile


def _ragged_kernel(gs_ref, x_ref, w_ref, o_ref, acc_ref, *, nk: int, bm: int):
    gi = pl.program_id(0)
    mi = pl.program_id(1)
    k = pl.program_id(3)
    count = gs_ref[gi]
    live = mi * bm < count

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(live)
    def _():
        acc_ref[...] += jax.lax.dot_general(
            x_ref[0],
            w_ref[0],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(k == nk - 1)
    def _():
        rows = mi * bm + jax.lax.broadcasted_iota(jnp.int32, acc_ref.shape, 0)
        o_ref[0, ...] = jnp.where(rows < count, acc_ref[...], 0.0).astype(
            o_ref.dtype
        )


def gmm_ragged(
    x: jax.Array,            # (G, C, D)
    w: jax.Array,            # (G // gpw, D, F)
    group_sizes: jax.Array,  # (G,) int32 — valid leading rows per group
    *,
    groups_per_weight: int = 1,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """y[g, :count_g] = x[g, :count_g] @ w[g // gpw]; tail rows are zero."""
    g, c, d = x.shape
    f = w.shape[-1]
    gpw = groups_per_weight
    assert g == w.shape[0] * gpw, (g, w.shape, gpw)
    bm, bn, bk = _tile(c, bm), _tile(f, bn), _tile(d, bk)
    nk = d // bk
    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(g, c // bm, f // bn, nk),
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda gi, i, j, k, gs: (gi, i, k)),
            pl.BlockSpec((1, bk, bn), lambda gi, i, j, k, gs: (gi // gpw, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda gi, i, j, k, gs: (gi, i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_ragged_kernel, nk=nk, bm=bm),
        grid_spec=spec,
        out_shape=jax.ShapeDtypeStruct((g, c, f), x.dtype),
        interpret=interpret,
    )(group_sizes.astype(jnp.int32), x, w)


def _ragged_dual_kernel(
    gs_ref, x_ref, wg_ref, wu_ref, o_ref, accg_ref, accu_ref, *, nk: int, bm: int
):
    gi = pl.program_id(0)
    mi = pl.program_id(1)
    k = pl.program_id(3)
    count = gs_ref[gi]
    live = mi * bm < count

    @pl.when(k == 0)
    def _():
        accg_ref[...] = jnp.zeros_like(accg_ref)
        accu_ref[...] = jnp.zeros_like(accu_ref)

    @pl.when(live)
    def _():
        dims = (((1,), (0,)), ((), ()))
        accg_ref[...] += jax.lax.dot_general(
            x_ref[0], wg_ref[0], dims, preferred_element_type=jnp.float32
        )
        accu_ref[...] += jax.lax.dot_general(
            x_ref[0], wu_ref[0], dims, preferred_element_type=jnp.float32
        )

    @pl.when(k == nk - 1)
    def _():
        rows = mi * bm + jax.lax.broadcasted_iota(jnp.int32, accg_ref.shape, 0)
        h = jax.nn.silu(accg_ref[...]) * accu_ref[...]
        o_ref[0, ...] = jnp.where(rows < count, h, 0.0).astype(o_ref.dtype)


def gmm_dual_act_ragged(
    x: jax.Array,
    wg: jax.Array,
    wu: jax.Array,
    group_sizes: jax.Array,
    *,
    groups_per_weight: int = 1,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """h[g] = silu(x@wg) * (x@wu) on the first count_g rows; tail is zero."""
    g, c, d = x.shape
    f = wg.shape[-1]
    gpw = groups_per_weight
    assert g == wg.shape[0] * gpw, (g, wg.shape, gpw)
    bm, bn, bk = _tile(c, bm), _tile(f, bn), _tile(d, bk)
    nk = d // bk
    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(g, c // bm, f // bn, nk),
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda gi, i, j, k, gs: (gi, i, k)),
            pl.BlockSpec((1, bk, bn), lambda gi, i, j, k, gs: (gi // gpw, k, j)),
            pl.BlockSpec((1, bk, bn), lambda gi, i, j, k, gs: (gi // gpw, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda gi, i, j, k, gs: (gi, i, j)),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, bn), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_ragged_dual_kernel, nk=nk, bm=bm),
        grid_spec=spec,
        out_shape=jax.ShapeDtypeStruct((g, c, f), x.dtype),
        interpret=interpret,
    )(group_sizes.astype(jnp.int32), x, wg, wu)


# ---------------------------------------------------------------------------
# fused dispatch-gather variants (flat rows + per-bucket offsets)
# ---------------------------------------------------------------------------

def _pad_rows(x: jax.Array, bm: int) -> tuple[jax.Array, int]:
    """Append ``bm`` zero rows so a tile DMA starting anywhere inside a
    valid segment never runs off the end of the flat array (partial tiles
    over-read up to ``bm - 1`` rows; the tail is masked in the epilogue)."""
    return jnp.pad(x, ((0, bm), (0, 0))), x.shape[0] + bm


def _gather_tile(x_any, xbuf, sem, off_ref, gi, mi, k, *, bm, bk, r_max):
    """DMA one (bm, bk) row-tile of bucket ``gi`` from the flat array."""
    start = jnp.minimum(off_ref[gi] + mi * bm, r_max)
    cp = pltpu.make_async_copy(
        x_any.at[pl.ds(start, bm), pl.ds(k * bk, bk)], xbuf, sem
    )
    cp.start()
    cp.wait()


def _gather_kernel(
    off_ref, gs_ref, x_any, w_ref, o_ref, acc_ref, xbuf, sem,
    *, nk: int, bm: int, bk: int, r_max: int,
):
    gi = pl.program_id(0)
    mi = pl.program_id(1)
    k = pl.program_id(3)
    count = gs_ref[gi]
    live = mi * bm < count

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(live)
    def _():
        _gather_tile(x_any, xbuf, sem, off_ref, gi, mi, k, bm=bm, bk=bk, r_max=r_max)
        acc_ref[...] += jax.lax.dot_general(
            xbuf[...],
            w_ref[0],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(k == nk - 1)
    def _():
        rows = mi * bm + jax.lax.broadcasted_iota(jnp.int32, acc_ref.shape, 0)
        o_ref[0, ...] = jnp.where(rows < count, acc_ref[...], 0.0).astype(
            o_ref.dtype
        )


def gmm_gather(
    x: jax.Array,            # (R, D) flat token rows, bucket-contiguous
    w: jax.Array,            # (G // gpw, D, F)
    offsets: jax.Array,      # (G,) int32 — bucket g's first row in x
    group_sizes: jax.Array,  # (G,) int32 — bucket g's row count
    *,
    capacity: int,
    groups_per_weight: int = 1,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """y[g, :count_g] = x[offsets[g] : offsets[g]+count_g] @ w[g // gpw].

    Output is bucket-padded ``(G, capacity, F)`` with zero tails (identical
    contract to ``gmm_ragged``), but the input is the *flat* compacted rows
    — no ``(G, capacity, D)`` buffer ever exists.
    """
    r, d = x.shape
    f = w.shape[-1]
    gpw = groups_per_weight
    g = w.shape[0] * gpw
    assert offsets.shape == (g,), (offsets.shape, g)
    bm, bn, bk = _tile(capacity, bm), _tile(f, bn), _tile(d, bk)
    x, r_pad = _pad_rows(x, bm)
    nk = d // bk
    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(g, capacity // bm, f // bn, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec((1, bk, bn), lambda gi, i, j, k, off, gs: (gi // gpw, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda gi, i, j, k, off, gs: (gi, i, j)),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, bk), x.dtype),
            pltpu.SemaphoreType.DMA(()),
        ],
    )
    return pl.pallas_call(
        functools.partial(
            _gather_kernel, nk=nk, bm=bm, bk=bk, r_max=r_pad - bm
        ),
        grid_spec=spec,
        out_shape=jax.ShapeDtypeStruct((g, capacity, f), x.dtype),
        interpret=interpret,
    )(offsets.astype(jnp.int32), group_sizes.astype(jnp.int32), x, w)


def _gather_dual_kernel(
    off_ref, gs_ref, x_any, wg_ref, wu_ref, o_ref, accg_ref, accu_ref, xbuf, sem,
    *, nk: int, bm: int, bk: int, r_max: int,
):
    gi = pl.program_id(0)
    mi = pl.program_id(1)
    k = pl.program_id(3)
    count = gs_ref[gi]
    live = mi * bm < count

    @pl.when(k == 0)
    def _():
        accg_ref[...] = jnp.zeros_like(accg_ref)
        accu_ref[...] = jnp.zeros_like(accu_ref)

    @pl.when(live)
    def _():
        _gather_tile(x_any, xbuf, sem, off_ref, gi, mi, k, bm=bm, bk=bk, r_max=r_max)
        dims = (((1,), (0,)), ((), ()))
        accg_ref[...] += jax.lax.dot_general(
            xbuf[...], wg_ref[0], dims, preferred_element_type=jnp.float32
        )
        accu_ref[...] += jax.lax.dot_general(
            xbuf[...], wu_ref[0], dims, preferred_element_type=jnp.float32
        )

    @pl.when(k == nk - 1)
    def _():
        rows = mi * bm + jax.lax.broadcasted_iota(jnp.int32, accg_ref.shape, 0)
        h = jax.nn.silu(accg_ref[...]) * accu_ref[...]
        o_ref[0, ...] = jnp.where(rows < count, h, 0.0).astype(o_ref.dtype)


def gmm_dual_act_gather(
    x: jax.Array,            # (R, D) flat token rows, bucket-contiguous
    wg: jax.Array,           # (G // gpw, D, F)
    wu: jax.Array,           # (G // gpw, D, F)
    offsets: jax.Array,      # (G,)
    group_sizes: jax.Array,  # (G,)
    *,
    capacity: int,
    groups_per_weight: int = 1,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """h[g] = silu(rows_g @ wg) * (rows_g @ wu) with the fused gather
    prologue; rows_g are read from the flat array via per-bucket offsets."""
    r, d = x.shape
    f = wg.shape[-1]
    gpw = groups_per_weight
    g = wg.shape[0] * gpw
    assert offsets.shape == (g,), (offsets.shape, g)
    bm, bn, bk = _tile(capacity, bm), _tile(f, bn), _tile(d, bk)
    x, r_pad = _pad_rows(x, bm)
    nk = d // bk
    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(g, capacity // bm, f // bn, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec((1, bk, bn), lambda gi, i, j, k, off, gs: (gi // gpw, k, j)),
            pl.BlockSpec((1, bk, bn), lambda gi, i, j, k, off, gs: (gi // gpw, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda gi, i, j, k, off, gs: (gi, i, j)),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, bk), x.dtype),
            pltpu.SemaphoreType.DMA(()),
        ],
    )
    return pl.pallas_call(
        functools.partial(
            _gather_dual_kernel, nk=nk, bm=bm, bk=bk, r_max=r_pad - bm
        ),
        grid_spec=spec,
        out_shape=jax.ShapeDtypeStruct((g, capacity, f), x.dtype),
        interpret=interpret,
    )(offsets.astype(jnp.int32), group_sizes.astype(jnp.int32), x, wg, wu)
