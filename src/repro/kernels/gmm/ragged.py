"""Count-aware (ragged) grouped matmul Pallas kernels.

Megablocks-style refinement of ``gmm``/``gmm_dual_act``: the per-group token
counts (``group_sizes``, int32 ``(G,)``) ride in as a scalar-prefetch operand
(SMEM), and each row-tile checks ``mi * bm < count`` before touching the MXU.
Row-tiles entirely past a group's count skip both matmuls; partially-filled
tiles mask their tail rows to zero on the final K step. MXU FLOPs therefore
scale with ``sum(ceil(count / bm) * bm)`` ≈ tokens actually routed, not
``G * capacity`` — on the skewed routing distributions the paper targets
(fig. 6) that's the bulk of the padded EP FFN cost.

``groups_per_weight`` (gpw) lets ``gpw`` consecutive x-groups share one
weight row — the layout both MoE paths produce after flattening:

* EP after the all_to_all: ``(slots_per_device, ep, cap, d)`` flattens to
  ``G = slots_per_device * ep`` groups, weight row ``gi // ep``;
* ESP local buckets: ``(E, n_batch_groups, cap, d)`` flattens to
  ``G = E * n_groups`` groups, weight row ``gi // n_groups``.

VMEM per step matches the padded kernels (the scalar counts live in SMEM);
the grid is identical, so the only cost of raggedness is the SMEM read and
the per-tile predicate.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.gmm.gmm import _tile


def _ragged_kernel(gs_ref, x_ref, w_ref, o_ref, acc_ref, *, nk: int, bm: int):
    gi = pl.program_id(0)
    mi = pl.program_id(1)
    k = pl.program_id(3)
    count = gs_ref[gi]
    live = mi * bm < count

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(live)
    def _():
        acc_ref[...] += jax.lax.dot_general(
            x_ref[0],
            w_ref[0],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(k == nk - 1)
    def _():
        rows = mi * bm + jax.lax.broadcasted_iota(jnp.int32, acc_ref.shape, 0)
        o_ref[0, ...] = jnp.where(rows < count, acc_ref[...], 0.0).astype(
            o_ref.dtype
        )


def gmm_ragged(
    x: jax.Array,            # (G, C, D)
    w: jax.Array,            # (G // gpw, D, F)
    group_sizes: jax.Array,  # (G,) int32 — valid leading rows per group
    *,
    groups_per_weight: int = 1,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """y[g, :count_g] = x[g, :count_g] @ w[g // gpw]; tail rows are zero."""
    g, c, d = x.shape
    f = w.shape[-1]
    gpw = groups_per_weight
    assert g == w.shape[0] * gpw, (g, w.shape, gpw)
    bm, bn, bk = _tile(c, bm), _tile(f, bn), _tile(d, bk)
    nk = d // bk
    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(g, c // bm, f // bn, nk),
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda gi, i, j, k, gs: (gi, i, k)),
            pl.BlockSpec((1, bk, bn), lambda gi, i, j, k, gs: (gi // gpw, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda gi, i, j, k, gs: (gi, i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_ragged_kernel, nk=nk, bm=bm),
        grid_spec=spec,
        out_shape=jax.ShapeDtypeStruct((g, c, f), x.dtype),
        interpret=interpret,
    )(group_sizes.astype(jnp.int32), x, w)


def _ragged_dual_kernel(
    gs_ref, x_ref, wg_ref, wu_ref, o_ref, accg_ref, accu_ref, *, nk: int, bm: int
):
    gi = pl.program_id(0)
    mi = pl.program_id(1)
    k = pl.program_id(3)
    count = gs_ref[gi]
    live = mi * bm < count

    @pl.when(k == 0)
    def _():
        accg_ref[...] = jnp.zeros_like(accg_ref)
        accu_ref[...] = jnp.zeros_like(accu_ref)

    @pl.when(live)
    def _():
        dims = (((1,), (0,)), ((), ()))
        accg_ref[...] += jax.lax.dot_general(
            x_ref[0], wg_ref[0], dims, preferred_element_type=jnp.float32
        )
        accu_ref[...] += jax.lax.dot_general(
            x_ref[0], wu_ref[0], dims, preferred_element_type=jnp.float32
        )

    @pl.when(k == nk - 1)
    def _():
        rows = mi * bm + jax.lax.broadcasted_iota(jnp.int32, accg_ref.shape, 0)
        h = jax.nn.silu(accg_ref[...]) * accu_ref[...]
        o_ref[0, ...] = jnp.where(rows < count, h, 0.0).astype(o_ref.dtype)


def gmm_dual_act_ragged(
    x: jax.Array,
    wg: jax.Array,
    wu: jax.Array,
    group_sizes: jax.Array,
    *,
    groups_per_weight: int = 1,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """h[g] = silu(x@wg) * (x@wu) on the first count_g rows; tail is zero."""
    g, c, d = x.shape
    f = wg.shape[-1]
    gpw = groups_per_weight
    assert g == wg.shape[0] * gpw, (g, wg.shape, gpw)
    bm, bn, bk = _tile(c, bm), _tile(f, bn), _tile(d, bk)
    nk = d // bk
    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(g, c // bm, f // bn, nk),
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda gi, i, j, k, gs: (gi, i, k)),
            pl.BlockSpec((1, bk, bn), lambda gi, i, j, k, gs: (gi // gpw, k, j)),
            pl.BlockSpec((1, bk, bn), lambda gi, i, j, k, gs: (gi // gpw, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda gi, i, j, k, gs: (gi, i, j)),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, bn), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_ragged_dual_kernel, nk=nk, bm=bm),
        grid_spec=spec,
        out_shape=jax.ShapeDtypeStruct((g, c, f), x.dtype),
        interpret=interpret,
    )(group_sizes.astype(jnp.int32), x, wg, wu)
