"""Jit'd wrapper for flash attention (interpret on CPU, compiled on TPU)."""

from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.flash_attention import flash_attention


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "interpret")
)
def flash_attention_op(
    q, k, v, causal: bool = True, window: int = 0, interpret: bool | None = None
):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return flash_attention(
        q, k, v, causal=causal, window=window, interpret=interpret
    )
