"""Pure-jnp oracle for causal (optionally windowed) GQA flash attention."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def mha_ref(
    q: jax.Array,       # (B, S, H, hd)
    k: jax.Array,       # (B, T, K, hd)
    v: jax.Array,       # (B, T, K, hd)
    causal: bool = True,
    window: int = 0,
) -> jax.Array:
    b, s, nh, hd = q.shape
    t, nk = k.shape[1], k.shape[2]
    g = nh // nk
    qg = q.reshape(b, s, nk, g, hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(hd)
    if causal:
        qpos = jnp.arange(s)[:, None] + (t - s)
        kpos = jnp.arange(t)[None, :]
        m = kpos <= qpos
        if window:
            m &= kpos > qpos - window
        scores = jnp.where(m, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    o = jnp.einsum("bkgst,btkd->bskgd", p, v)
    return o.reshape(b, s, nh, hd)
