"""Causal GQA flash attention, Pallas/TPU.

Online-softmax tiling (Flash-2 style): grid (B, H, S/bq, T/bk) with the KV
axis innermost/sequential. Running (m, l, acc) live in VMEM scratch across
KV steps; the output block is written once on the last step. Fully-masked
(above-diagonal) KV blocks are skipped with ``pl.when`` — for causal
attention that's ~2x fewer MXU passes, the structural equivalent of
flash's "block sparsity on the diagonal".

GQA is handled in the index maps: query head h reads KV head ``h // g`` —
no materialized KV repetition in HBM or VMEM.

VMEM per step: q (bq,hd) + k,v (bk,hd) + scores (bq,bk) + acc (bq,hd) fp32
≈ 0.5 MB at bq=bk=128, hd=128 — double-buffered comfortably on v5e.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, nk: int, bq: int,
    bk: int, causal: bool, window: int, t_minus_s: int
):
    jk = pl.program_id(3)
    iq = pl.program_id(2)

    @pl.when(jk == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * bq + t_minus_s          # absolute position of first query
    k_start = jk * bk

    def compute():
        q = q_ref[0, :, 0, :]                       # (bq, hd)
        k = k_ref[0, :, 0, :]                       # (bk, hd)
        v = v_ref[0, :, 0, :]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) / (q.shape[-1] ** 0.5)                     # (bq, bk)
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            mask = kpos <= qpos
            if window:
                mask &= kpos > qpos - window
            s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[:, :1]                        # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    if causal:
        # Skip blocks entirely above the causal diagonal.
        pl.when(k_start <= q_start + bq - 1)(compute)
    else:
        compute()

    @pl.when(jk == nk - 1)
    def _():
        l = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,      # (B, S, H, hd)
    k: jax.Array,      # (B, T, K, hd)
    v: jax.Array,      # (B, T, K, hd)
    *,
    causal: bool = True,
    window: int = 0,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, s, nh, hd = q.shape
    t, nkv = k.shape[1], k.shape[2]
    g = nh // nkv

    def _fit(n, pref):
        tt = min(pref, n)
        while n % tt:
            tt -= 1
        return tt

    bq = _fit(s, bq)
    bk = _fit(t, bk)
    nk = t // bk
    grid = (b, nh, s // bq, nk)
    kernel = functools.partial(
        _flash_kernel,
        nk=nk,
        bq=bq,
        bk=bk,
        causal=causal,
        window=window,
        t_minus_s=t - s,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, hd), lambda bi, h, iq, jk: (bi, iq, h, 0)),
            pl.BlockSpec(
                (1, bk, 1, hd), lambda bi, h, iq, jk, g=g: (bi, jk, h // g, 0)
            ),
            pl.BlockSpec(
                (1, bk, 1, hd), lambda bi, h, iq, jk, g=g: (bi, jk, h // g, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, bq, 1, hd), lambda bi, h, iq, jk: (bi, iq, h, 0)
        ),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),   # running max
            pltpu.VMEM((bq, 1), jnp.float32),     # running denom
            pltpu.VMEM((bq, hd), jnp.float32),    # running numerator
        ],
        interpret=interpret,
    )(q, k, v)
