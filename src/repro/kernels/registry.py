"""Kernel dispatch layer: route hot-path math to Pallas or reference code.

One chokepoint decides, per call site, whether the Pallas kernels run and
how (compiled on TPU, interpret mode elsewhere), so model code never
hard-codes a backend:

* ``kernels_enabled(flag)``   — resolve a ``ParallelCtx.use_kernels`` value
  ("auto" -> TPU only) into a bool.
* ``default_interpret()``     — True off-TPU: kernel bodies execute via the
  Pallas interpreter so CPU tests cover the exact kernel code.
* ``expert_ffn(...)``         — count-aware grouped SwiGLU FFN. Kernel path
  = ``gmm_dual_act_ragged`` + ``gmm_ragged`` (FLOPs ~ sum(group_sizes));
  fallback = folded einsums. Differentiable: the kernel forward pairs with
  a reference-math backward via ``jax.custom_vjp``.
* ``attend(...)`` / ``can_flash_attend(...)``   — causal/bidirectional GQA
  flash attention with a chunked-reference backward.
* ``decode_attend(...)`` / ``can_flash_decode(...)`` — single-token decode
  against a (possibly partially valid) KV cache.

Fallback rules: a caller first asks the ``can_*`` predicate (shapes must
tile for the compiled path; interpret mode accepts anything), and keeps its
einsum reference for the "no" answer. Compiled-path gates are conservative
— last dims multiples of 128, row dims multiples of 8 — matching the MXU
native tiling the kernels were written for.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention.ops import flash_attention_op
from repro.kernels.flash_decode.flash_decode import merge_partials
from repro.kernels.flash_decode.ops import (
    flash_decode_op,
    flash_decode_paged_op,
    flash_decode_partials_op,
)
from repro.kernels.gmm.ops import (
    expert_ffn_fused as _expert_ffn_fused_op,
)
from repro.kernels.gmm.ops import (
    expert_ffn_gather as _expert_ffn_gather_op,
)
from repro.kernels.gmm.ops import (
    expert_ffn_gather_compact as _expert_ffn_gather_compact_op,
)
from repro.kernels.gmm.ops import (
    expert_ffn_ragged as _expert_ffn_ragged_op,
)
from repro.kernels.gmm.ref import (
    expert_ffn_compact_ref,
    expert_ffn_fused_ref,
    expert_ffn_gather_ref,
    expert_ffn_ragged_ref,
)


# ---------------------------------------------------------------------------
# flag resolution
# ---------------------------------------------------------------------------

def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def default_interpret() -> bool:
    """Off-TPU the kernels run under the Pallas interpreter."""
    return not on_tpu()


def kernels_enabled(flag: str | bool = "auto") -> bool:
    """Resolve a ``use_kernels`` setting: "auto" means TPU-only (interpret
    mode is correct everywhere but too slow to be a default on CPU)."""
    if flag == "auto":
        return on_tpu()
    return bool(flag)


def parse_use_kernels(value: str) -> str | bool:
    """CLI tri-state ("auto"|"on"|"off") -> ``ParallelCtx.use_kernels``."""
    return {"on": True, "off": False}.get(value, "auto")


def _zero_ct(a):
    """float0 cotangent for integer primal inputs (custom_vjp contract)."""
    return np.zeros(a.shape, jax.dtypes.float0)


# ---------------------------------------------------------------------------
# grouped expert FFN (ragged / count-aware)
# ---------------------------------------------------------------------------

def can_gmm(c: int, d: int, f: int, interpret: bool) -> bool:
    """Can the grouped-matmul kernels take (·, c, d) @ (·, d, f)?"""
    if interpret:
        return True
    return c % 8 == 0 and d % 128 == 0 and f % 128 == 0


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _ffn_kernel(gpw: int, interpret: bool, x, wg, wu, wd, group_sizes):
    return _expert_ffn_ragged_op(
        x, wg, wu, wd, group_sizes,
        groups_per_weight=gpw, interpret=interpret,
    )


def _ffn_fwd(gpw, interpret, x, wg, wu, wd, group_sizes):
    y = _ffn_kernel(gpw, interpret, x, wg, wu, wd, group_sizes)
    return y, (x, wg, wu, wd, group_sizes)


def _ffn_bwd(gpw, interpret, res, ct):
    # Backward through the reference math (the standard flash-style trick:
    # kernel forward, recomputed reference backward — Pallas kernels with
    # VMEM scratch have no autodiff rule).
    x, wg, wu, wd, gs = res
    _, vjp = jax.vjp(
        lambda a, b, c, d: expert_ffn_ragged_ref(a, b, c, d, gs, gpw),
        x, wg, wu, wd,
    )
    return (*vjp(ct), _zero_ct(gs))


_ffn_kernel.defvjp(_ffn_fwd, _ffn_bwd)


def expert_ffn(
    x: jax.Array,                       # (G, C, D)
    wg: jax.Array,                      # (G/gpw, D, F)
    wu: jax.Array,                      # (G/gpw, D, F)
    wd: jax.Array,                      # (G/gpw, F, D)
    group_sizes: jax.Array | None = None,   # (G,) int32 valid-row counts
    *,
    groups_per_weight: int = 1,
    enabled: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """Grouped SwiGLU expert FFN with optional raggedness.

    With ``group_sizes`` the kernel skips row-tiles past each group's count
    (and zeroes the tail), so expert FLOPs track tokens actually routed.
    ``groups_per_weight`` consecutive groups share one weight row (the
    flattened EP/ESP bucket layouts). Falls back to folded einsums when
    disabled or when shapes don't tile for the compiled kernel.
    """
    g, c, d = x.shape
    f = wg.shape[-1]
    interpret = default_interpret() if interpret is None else interpret
    if enabled and can_gmm(c, d, f, interpret) and can_gmm(c, f, d, interpret):
        gs = (
            group_sizes.astype(jnp.int32)
            if group_sizes is not None
            else jnp.full((g,), c, jnp.int32)
        )
        return _ffn_kernel(groups_per_weight, interpret, x, wg, wu, wd, gs)
    return expert_ffn_ragged_ref(
        x, wg, wu, wd, group_sizes, groups_per_weight
    )


# ---------------------------------------------------------------------------
# fused dispatch-gather expert FFN (flat rows + per-bucket offsets)
# ---------------------------------------------------------------------------

def can_gmm_gather(capacity: int, d: int, f: int, interpret: bool) -> bool:
    """Can the fused gather kernels take flat rows into (G, capacity) buckets
    with (d, f) expert dims? Same MXU-tiling gates as the ragged kernels
    (the flat array itself stays in ANY memory — no row-count constraint)."""
    return can_gmm(capacity, d, f, interpret) and can_gmm(capacity, f, d, interpret)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _ffn_gather_kernel(cap, gpw, interpret, x, wg, wu, wd, offsets, group_sizes):
    return _expert_ffn_gather_op(
        x, wg, wu, wd, offsets, group_sizes,
        capacity=cap, groups_per_weight=gpw, interpret=interpret,
    )


def _ffn_gather_fwd(cap, gpw, interpret, x, wg, wu, wd, offsets, group_sizes):
    y = _ffn_gather_kernel(cap, gpw, interpret, x, wg, wu, wd, offsets, group_sizes)
    return y, (x, wg, wu, wd, offsets, group_sizes)


def _ffn_gather_bwd(cap, gpw, interpret, res, ct):
    # Reference-math backward: the gather is a plain jnp take, so the
    # cotangent scatters back onto the flat rows for free.
    x, wg, wu, wd, offs, gs = res
    _, vjp = jax.vjp(
        lambda a, b, c, d: expert_ffn_gather_ref(a, b, c, d, offs, gs, cap, gpw),
        x, wg, wu, wd,
    )
    return (*vjp(ct), _zero_ct(offs), _zero_ct(gs))


_ffn_gather_kernel.defvjp(_ffn_gather_fwd, _ffn_gather_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _ffn_compact_kernel(cap, gpw, interpret, x, wg, wu, wd, offsets, group_sizes):
    return _expert_ffn_gather_compact_op(
        x, wg, wu, wd, offsets, group_sizes,
        capacity=cap, groups_per_weight=gpw, interpret=interpret,
    )


def _ffn_compact_fwd(cap, gpw, interpret, x, wg, wu, wd, offsets, group_sizes):
    y = _ffn_compact_kernel(cap, gpw, interpret, x, wg, wu, wd, offsets, group_sizes)
    return y, (x, wg, wu, wd, offsets, group_sizes)


def _ffn_compact_bwd(cap, gpw, interpret, res, ct):
    # Reference-math backward: gather + FFN + scatter are plain jnp ops, so
    # the cotangent flows back onto the flat rows through the same layout.
    # The kernel forward leaves rows outside live segments unspecified
    # while the reference zeroes them — consistent, because the reference
    # scatter's vjp reads the cotangent only at live (bucket, position)
    # pairs, exactly the rows downstream combines may touch.
    x, wg, wu, wd, offs, gs = res
    _, vjp = jax.vjp(
        lambda a, b, c, d: expert_ffn_compact_ref(a, b, c, d, offs, gs, cap, gpw),
        x, wg, wu, wd,
    )
    return (*vjp(ct), _zero_ct(offs), _zero_ct(gs))


_ffn_compact_kernel.defvjp(_ffn_compact_fwd, _ffn_compact_bwd)


# VMEM bound for the fully-fused kernel: it holds a (bm, d) fp32 output
# accumulator + a (bm, d) staging tile + a double-buffered (bf, d) w_down
# panel per step. At bm = bf = 128 and d = 4096 that is ~8.5 MB — near the
# ~16 MB budget — so larger model dims fall back to the two-kernel pair
# (which blocks the down-projection's output columns).
FUSED_FFN_MAX_DOWN_DIM = 4096


def can_gmm_fused(
    capacity: int, d: int, f: int, interpret: bool, d_out: int | None = None
) -> bool:
    """Can the fully-fused single-kernel FFN (``gmm_fused_ffn``) take flat
    rows with (d, f, d_out) expert dims? Same MXU-tiling gates as the
    gather/scatter pair plus the VMEM bound on the output accumulator /
    staging tile / w_down panel — all of which scale with the
    *down-projection output* dim, so the bound is on ``d_out`` (== ``d``
    for the square expert-FFN contract, the default). The bound applies in
    interpret mode too, so CPU tests exercise the same dispatch decisions
    the compiled path makes."""
    d_out = d if d_out is None else d_out
    return (
        can_gmm(capacity, d, f, interpret)
        and can_gmm(capacity, f, d_out, interpret)
        and d_out <= FUSED_FFN_MAX_DOWN_DIM
    )


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _ffn_fused_kernel(cap, gpw, interpret, x, wg, wu, wd, offsets, group_sizes):
    return _expert_ffn_fused_op(
        x, wg, wu, wd, offsets, group_sizes,
        capacity=cap, groups_per_weight=gpw, interpret=interpret,
    )


def _ffn_fused_fwd(cap, gpw, interpret, x, wg, wu, wd, offsets, group_sizes):
    y = _ffn_fused_kernel(cap, gpw, interpret, x, wg, wu, wd, offsets, group_sizes)
    return y, (x, wg, wu, wd, offsets, group_sizes)


def _ffn_fused_bwd(cap, gpw, interpret, res, ct):
    # Reference-math backward — identical to the compact pair's backward
    # (the fusion changes where the hidden tensor lives, not the math), so
    # the cotangent flows back onto the flat rows through the same
    # gather/FFN/scatter jnp composition.
    x, wg, wu, wd, offs, gs = res
    _, vjp = jax.vjp(
        lambda a, b, c, d: expert_ffn_fused_ref(a, b, c, d, offs, gs, cap, gpw),
        x, wg, wu, wd,
    )
    return (*vjp(ct), _zero_ct(offs), _zero_ct(gs))


_ffn_fused_kernel.defvjp(_ffn_fused_fwd, _ffn_fused_bwd)


def expert_ffn_from_rows(
    x: jax.Array,            # (R, D) flat token rows, bucket-contiguous
    wg: jax.Array,           # (G/gpw, D, F)
    wu: jax.Array,           # (G/gpw, D, F)
    wd: jax.Array,           # (G/gpw, F, D)
    offsets: jax.Array,      # (G,) int32 first-row index per bucket
    group_sizes: jax.Array,  # (G,) int32 rows per bucket
    *,
    capacity: int,
    groups_per_weight: int = 1,
    enabled: bool = True,
    compact_out: bool = False,
    fused: bool = False,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused dispatch-scatter grouped SwiGLU FFN.

    Bucket ``g``'s tokens are rows ``offsets[g] .. offsets[g]+count_g`` of
    the flat array; the kernel prologue gathers them tile-by-tile (dynamic-
    offset DMA), so the padded ``(G, capacity, D)`` dispatch buffer is never
    written to HBM. By default the output keeps the bucket-padded
    ``(G, capacity, D)`` contract of ``expert_ffn`` (zero tails). With
    ``compact_out=True`` the down-projection instead runs the
    ``gmm_scatter`` epilogue: result tiles are stored back at the *same*
    per-bucket offsets, emitting a flat rank-compacted ``(R, D)`` array —
    the padded FFN output buffer is never written to HBM either, and the
    caller combines through the dispatch metadata
    (``collectives.combine_from_rows``). Rows outside live segments are
    unspecified in the kernel output (zeroed by the reference path) and
    must never be read. Falls back to the reference gather + einsum math
    when disabled or when shapes don't tile.

    With ``fused=True`` (requires ``compact_out=True`` — the fusion's whole
    point is the compact layout on both sides) the three matmuls run as ONE
    kernel (``gmm_fused_ffn``): the SwiGLU hidden activations live entirely
    in VMEM accumulators, so the bucket-padded ``(G, capacity, F)`` hidden
    tensor — the last padded intermediate of the expert hot path — never
    touches HBM. Shape-gated by ``can_gmm_fused`` (the gather/scatter gates
    plus a VMEM bound on the model dim); ineligible shapes fall back to the
    two-kernel gather+scatter pair, then to the reference math.

    Per-chunk invocation (``ep_chunks > 1``): the chunked EP dispatch
    pipeline calls this once per chunk with *sliced* metadata and weights —
    the chunk's buckets' ``offsets``/``group_sizes`` rows and the matching
    weight-row slice — while ``x`` stays the full flat row array (chunk
    receive buffer on the mesh path, the whole compacted stream on the
    no-mesh path). Offsets index into ``x`` as usual; rows owned by buckets
    outside the slice are untouched/unspecified in the output and the
    caller selects each row from its owner chunk before the single final
    combine. The fallback chain above applies per chunk, so a shape that
    loses kernel eligibility after slicing degrades transparently for that
    chunk alone.
    """
    d = x.shape[-1]
    f = wg.shape[-1]
    interpret = default_interpret() if interpret is None else interpret
    if fused and not compact_out:
        raise ValueError(
            "expert_ffn_from_rows: fused=True requires compact_out=True — "
            "the single-kernel path always emits the flat compact layout"
        )
    if compact_out:
        if enabled and fused and can_gmm_fused(
            capacity, d, f, interpret, wd.shape[-1]
        ):
            return _ffn_fused_kernel(
                capacity, groups_per_weight, interpret,
                x, wg, wu, wd,
                offsets.astype(jnp.int32), group_sizes.astype(jnp.int32),
            )
        if enabled and can_gmm_gather(capacity, d, f, interpret):
            return _ffn_compact_kernel(
                capacity, groups_per_weight, interpret,
                x, wg, wu, wd,
                offsets.astype(jnp.int32), group_sizes.astype(jnp.int32),
            )
        return expert_ffn_compact_ref(
            x, wg, wu, wd, offsets, group_sizes, capacity, groups_per_weight
        )
    if enabled and can_gmm_gather(capacity, d, f, interpret):
        return _ffn_gather_kernel(
            capacity, groups_per_weight, interpret,
            x, wg, wu, wd,
            offsets.astype(jnp.int32), group_sizes.astype(jnp.int32),
        )
    return expert_ffn_gather_ref(
        x, wg, wu, wd, offsets, group_sizes, capacity, groups_per_weight
    )


# ---------------------------------------------------------------------------
# flash attention (prefill / train)
# ---------------------------------------------------------------------------

def can_flash_attend(
    s: int, t: int, nh: int, nkv: int, hd: int, interpret: bool
) -> bool:
    if nkv <= 0 or nh % nkv:
        return False
    if interpret:
        return True
    return hd % 128 == 0 and s % 8 == 0 and t % 128 == 0


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _attend_kernel(causal: bool, window: int, interpret: bool, q, k, v):
    return flash_attention_op(
        q, k, v, causal=causal, window=window, interpret=interpret
    )


def _attend_fwd(causal, window, interpret, q, k, v):
    return _attend_kernel(causal, window, interpret, q, k, v), (q, k, v)


def _attend_bwd(causal, window, interpret, res, ct):
    from repro.models.attention import chunked_gqa_attend  # import cycle

    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: chunked_gqa_attend(q_, k_, v_, causal, window),
        q, k, v,
    )
    return vjp(ct)


_attend_kernel.defvjp(_attend_fwd, _attend_bwd)


def attend(
    q: jax.Array,       # (B, S, H, hd)
    k: jax.Array,       # (B, T, K, hd)
    v: jax.Array,       # (B, T, K, hd)
    *,
    causal: bool = True,
    window: int = 0,
    interpret: bool | None = None,
) -> jax.Array:
    """Flash GQA attention (queries cover the tail of the key range). The
    caller is responsible for gating on ``can_flash_attend``."""
    interpret = default_interpret() if interpret is None else interpret
    return _attend_kernel(causal, window, interpret, q, k, v)


# ---------------------------------------------------------------------------
# flash decode (one token vs the KV cache)
# ---------------------------------------------------------------------------

def can_flash_decode(
    t: int, nh: int, nkv: int, hd: int, interpret: bool
) -> bool:
    if nkv <= 0 or nh % nkv:
        return False
    if interpret:
        return True
    return hd % 128 == 0 and t % 128 == 0


def decode_attend(
    q: jax.Array,        # (B, H, hd) — the single new token's queries
    k: jax.Array,        # (B, T, K, hd)
    v: jax.Array,        # (B, T, K, hd)
    valid: jax.Array,    # (B, T) int32/bool cache-slot validity
    *,
    interpret: bool | None = None,
) -> jax.Array:
    interpret = default_interpret() if interpret is None else interpret
    return flash_decode_op(q, k, v, valid.astype(jnp.int32), interpret=interpret)


def decode_attend_partials(
    q: jax.Array,        # (B, H, hd)
    k: jax.Array,        # (B, T, K, hd) — one shard's KV slice
    v: jax.Array,
    valid: jax.Array,    # (B, T)
    *,
    interpret: bool | None = None,
):
    """Unnormalized fp32 ``(acc, m, l)`` over this KV slice. Partials over
    disjoint slices LSE-merge exactly — ``merge_decode_partials`` does it
    across a named mesh axis (the sequence-parallel decode path)."""
    interpret = default_interpret() if interpret is None else interpret
    return flash_decode_partials_op(
        q, k, v, valid.astype(jnp.int32), interpret=interpret
    )


# the cross-shard LSE merge (psum/pmax over a named axis) — kernel partials
# ride the collective as-is, no per-shard normalization round-trip.
merge_decode_partials = merge_partials


# ---------------------------------------------------------------------------
# paged flash decode (block-table KV walk over a shared page pool)
# ---------------------------------------------------------------------------

def can_flash_decode_paged(
    page_size: int, nh: int, nkv: int, hd: int, interpret: bool
) -> bool:
    """Compiled paged decode streams (page_size, hd) k/v panels: last dims
    must hit the MXU/VPU native tiles. Interpret mode takes anything."""
    if nkv <= 0 or nh % nkv:
        return False
    if interpret:
        return True
    return hd % 128 == 0 and page_size % 128 == 0


def decode_attend_paged(
    q: jax.Array,             # (B, H, hd)
    pool_k: jax.Array,        # (P, page_size, K, hd) shared page pool
    pool_v: jax.Array,
    block_tables: jax.Array,  # (B, NB) int32 logical block -> physical page
    lengths: jax.Array,       # (B,) int32 live context per request
    *,
    interpret: bool | None = None,
) -> jax.Array:
    """Paged decode: walks only ``ceil(lengths / page_size)`` live pages per
    request (dead blocks clamp to the last live page and skip the MXU), so
    decode HBM traffic tracks actual context, not the pool/max_seq size."""
    interpret = default_interpret() if interpret is None else interpret
    return flash_decode_paged_op(
        q, pool_k, pool_v,
        block_tables.astype(jnp.int32), lengths.astype(jnp.int32),
        interpret=interpret,
    )
