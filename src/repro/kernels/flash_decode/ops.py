"""Jit'd wrapper for flash decode."""

from __future__ import annotations

import functools

import jax

from repro.kernels.flash_decode.flash_decode import flash_decode


@functools.partial(jax.jit, static_argnames=("interpret",))
def flash_decode_op(q, k, v, valid, interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return flash_decode(q, k, v, valid, interpret=interpret)
