"""Jit'd wrappers for flash decode (dense, partials, paged)."""

from __future__ import annotations

import functools

import jax

from repro.kernels.flash_decode.flash_decode import flash_decode
from repro.kernels.flash_decode.paged import flash_decode_paged


@functools.partial(jax.jit, static_argnames=("interpret",))
def flash_decode_op(q, k, v, valid, interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return flash_decode(q, k, v, valid, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def flash_decode_partials_op(q, k, v, valid, interpret: bool | None = None):
    """fp32 ``(acc, m, l)`` online-softmax state over the (masked) cache —
    the cross-shard LSE-merge operand (see ``merge_partials``)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return flash_decode(q, k, v, valid, return_partials=True, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def flash_decode_paged_op(
    q, pool_k, pool_v, block_tables, lengths, interpret: bool | None = None
):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return flash_decode_paged(
        q, pool_k, pool_v, block_tables, lengths, interpret=interpret
    )
