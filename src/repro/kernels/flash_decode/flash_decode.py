"""Flash-decode Pallas kernel: one query token vs a long KV cache.

Decode is HBM-bound (the whole KV cache streams through once), so the
kernel's job is to keep that stream dense: grid (B, K, T/bt) with the KV
axis sequential, online softmax in VMEM scratch, and — the GQA trick — all
``G = H/K`` query heads of a KV group processed *together* as a (G, hd)
panel, turning the per-block score computation into an MXU (G x hd) @
(hd x bt) matmul instead of G vector passes. Cache-slot validity arrives
as an int32 mask (ring buffers / partially filled caches).

``return_partials=True`` skips the local normalization and emits the raw
online-softmax state ``(acc, m, l)`` instead — ``acc`` is the
*unnormalized* weighted value sum in fp32, ``m`` the running row max and
``l`` the running exp-sum. Two partials over disjoint key sets merge
exactly (the standard LSE merge)::

    m* = max(m1, m2);  l* = l1*e^(m1-m*) + l2*e^(m2-m*)
    acc* = acc1*e^(m1-m*) + acc2*e^(m2-m*);   out = acc* / l*

which is what the sequence-parallel decode path psums across shards
(`repro.parallel.collectives.seq_parallel_decode_attend`).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def write_outputs(partials: bool, out_refs, m_ref, l_ref, acc_ref):
    """Final-block epilogue shared by the dense and paged decode kernels:
    either locally normalize, or emit the raw ``(acc, m, l)`` state (``l``
    broadcast across the 128-lane tile; column 0 is the value)."""
    if partials:
        o_ref, mo_ref, lo_ref = out_refs
        o_ref[0, 0] = acc_ref[...].astype(o_ref.dtype)
        mo_ref[0, 0] = m_ref[...].astype(mo_ref.dtype)
        lo_ref[0, 0] = jnp.broadcast_to(
            l_ref[:, :1], lo_ref.shape[2:]
        ).astype(lo_ref.dtype)
    else:
        (o_ref,) = out_refs
        l = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def output_layout(partials: bool, b, nkv, g, hd, dtype, index_map):
    """(out_shape, out_specs) shared by the decode wrappers. Partials ride
    out as fp32 ``acc (…, g, hd)`` plus ``m``/``l`` through ``(…, g, 128)``
    lanes (min lane tile; the broadcast is free in VMEM)."""
    o_spec = pl.BlockSpec((1, 1, g, hd), index_map)
    if not partials:
        return jax.ShapeDtypeStruct((b, nkv, g, hd), dtype), o_spec
    ml_shape = jax.ShapeDtypeStruct((b, nkv, g, 128), jnp.float32)
    ml_spec = pl.BlockSpec((1, 1, g, 128), index_map)
    return (
        (jax.ShapeDtypeStruct((b, nkv, g, hd), jnp.float32), ml_shape, ml_shape),
        (o_spec, ml_spec, ml_spec),
    )


def unpack_outputs(partials: bool, out, b, nh, hd):
    """Reshape kernel outputs to the public ``(B, H, …)`` contract."""
    if not partials:
        return out.reshape(b, nh, hd)
    acc, m, l = out
    return (
        acc.reshape(b, nh, hd),
        m[..., 0].reshape(b, nh),
        l[..., 0].reshape(b, nh),
    )


def _decode_kernel(q_ref, k_ref, v_ref, m_in_ref, *refs, nt: int, partials: bool):
    if partials:
        o_ref, mo_ref, lo_ref, m_ref, l_ref, acc_ref = refs
        out_refs = (o_ref, mo_ref, lo_ref)
    else:
        o_ref, m_ref, l_ref, acc_ref = refs
        out_refs = (o_ref,)
    jt = pl.program_id(2)

    @pl.when(jt == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                             # (G, hd)
    k = k_ref[0, :, 0, :]                       # (bt, hd)
    v = v_ref[0, :, 0, :]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) / (q.shape[-1] ** 0.5)                     # (G, bt)
    valid = m_in_ref[0, :]                       # (bt,)
    s = jnp.where(valid[None, :] > 0, s, NEG_INF)

    m_prev = m_ref[:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    @pl.when(jt == nt - 1)
    def _():
        write_outputs(partials, out_refs, m_ref, l_ref, acc_ref)


def flash_decode(
    q: jax.Array,       # (B, H, hd)
    k: jax.Array,       # (B, T, K, hd)
    v: jax.Array,       # (B, T, K, hd)
    valid: jax.Array,   # (B, T) int32
    *,
    bt: int = 512,
    return_partials: bool = False,
    interpret: bool = False,
):
    """Locally-normalized output ``(B, H, hd)``, or — with
    ``return_partials`` — the fp32 triple ``(acc, m, l)`` of shapes
    ``(B, H, hd)``, ``(B, H)``, ``(B, H)`` for a cross-shard LSE merge."""
    b, nh, hd = q.shape
    t, nkv = k.shape[1], k.shape[2]
    g = nh // nkv

    def _fit(n, pref):
        tt = min(pref, n)
        while n % tt:
            tt -= 1
        return tt

    bt = _fit(t, bt)
    nt = t // bt
    qg = q.reshape(b, nkv, g, hd)
    grid = (b, nkv, nt)
    out_shape, out_specs = output_layout(
        return_partials, b, nkv, g, hd, q.dtype,
        lambda bi, kh, jt: (bi, kh, 0, 0),
    )
    out = pl.pallas_call(
        functools.partial(_decode_kernel, nt=nt, partials=return_partials),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda bi, kh, jt: (bi, kh, 0, 0)),
            pl.BlockSpec((1, bt, 1, hd), lambda bi, kh, jt: (bi, jt, kh, 0)),
            pl.BlockSpec((1, bt, 1, hd), lambda bi, kh, jt: (bi, jt, kh, 0)),
            pl.BlockSpec((1, bt), lambda bi, kh, jt: (bi, jt)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k, v, valid.astype(jnp.int32))
    return unpack_outputs(return_partials, out, b, nh, hd)


def merge_partials(acc, m, l, axis_name: str):
    """LSE-merge flash-decode partials across a named mesh axis.

    ``acc (B, H, hd)``, ``m (B, H)``, ``l (B, H)`` — each shard's state over
    its disjoint KV slice. A fully-masked shard carries ``m = NEG_INF`` but
    *non-zero* ``l``/``acc`` (the online softmax computes ``exp(s - m)`` with
    both at ``NEG_INF``, so masked rows contribute ``exp(0) = 1`` until a
    live key raises ``m``); it still contributes nothing here because its
    weight ``exp(m - m_max)`` underflows to exactly 0 whenever *any* shard
    saw a live key. If every shard is fully masked the merge degenerates to
    the same uniform average over cache rows the dense masked softmax
    produces — callers must not treat ``l`` as a liveness signal.
    """
    m_max = jax.lax.pmax(m, axis_name)
    scale = jnp.exp(m - m_max)
    num = jax.lax.psum(acc * scale[..., None], axis_name)
    den = jax.lax.psum(l * scale, axis_name)
    return num / jnp.maximum(den, 1e-30)[..., None]
