"""Flash-decode Pallas kernel: one query token vs a long KV cache.

Decode is HBM-bound (the whole KV cache streams through once), so the
kernel's job is to keep that stream dense: grid (B, K, T/bt) with the KV
axis sequential, online softmax in VMEM scratch, and — the GQA trick — all
``G = H/K`` query heads of a KV group processed *together* as a (G, hd)
panel, turning the per-block score computation into an MXU (G x hd) @
(hd x bt) matmul instead of G vector passes. Cache-slot validity arrives
as an int32 mask (ring buffers / partially filled caches).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, m_in_ref, o_ref, m_ref, l_ref, acc_ref, *, nt: int):
    jt = pl.program_id(2)

    @pl.when(jt == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                             # (G, hd)
    k = k_ref[0, :, 0, :]                       # (bt, hd)
    v = v_ref[0, :, 0, :]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) / (q.shape[-1] ** 0.5)                     # (G, bt)
    valid = m_in_ref[0, :]                       # (bt,)
    s = jnp.where(valid[None, :] > 0, s, NEG_INF)

    m_prev = m_ref[:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    @pl.when(jt == nt - 1)
    def _():
        l = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_decode(
    q: jax.Array,       # (B, H, hd)
    k: jax.Array,       # (B, T, K, hd)
    v: jax.Array,       # (B, T, K, hd)
    valid: jax.Array,   # (B, T) int32
    *,
    bt: int = 512,
    interpret: bool = False,
) -> jax.Array:
    b, nh, hd = q.shape
    t, nkv = k.shape[1], k.shape[2]
    g = nh // nkv

    def _fit(n, pref):
        tt = min(pref, n)
        while n % tt:
            tt -= 1
        return tt

    bt = _fit(t, bt)
    nt = t // bt
    qg = q.reshape(b, nkv, g, hd)
    grid = (b, nkv, nt)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, nt=nt),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda bi, kh, jt: (bi, kh, 0, 0)),
            pl.BlockSpec((1, bt, 1, hd), lambda bi, kh, jt: (bi, jt, kh, 0)),
            pl.BlockSpec((1, bt, 1, hd), lambda bi, kh, jt: (bi, jt, kh, 0)),
            pl.BlockSpec((1, bt), lambda bi, kh, jt: (bi, jt)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda bi, kh, jt: (bi, kh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, nkv, g, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k, v, valid.astype(jnp.int32))
    return out.reshape(b, nh, hd)
