"""Paged flash-decode: block-table KV walk with scalar-prefetched pages.

The dense ``flash_decode`` streams the whole ``(B, max_seq, K, hd)`` cache
per step and masks invalid slots, so a short sequence in a long-``max_seq``
batch still pays full-cache HBM bandwidth. Here the cache is a shared
*page pool* ``(P, page_size, K, hd)`` plus per-request int32 metadata:

* ``block_tables (B, NB)`` — logical KV block ``j`` of request ``b`` lives
  in physical page ``block_tables[b, j]``;
* ``lengths (B,)`` — live context per request (no dense validity mask).

Both ride as **scalar-prefetch operands** (same mechanism as the ragged
GMM's per-bucket offsets), so the k/v *BlockSpec index maps* can read them:
grid step ``(b, kh, jb)`` fetches page ``block_tables[b, jb]`` straight
from the pool — the Pallas pipeline double-buffers those fetches like any
other block. Blocks past ``lengths[b]`` are clamped to the request's last
live page: consecutive grid steps with an identical block index elide the
copy, so HBM traffic tracks ``ceil(length / page_size)`` live pages, not
``max_seq``. The kernel body skips the MXU for dead blocks and masks the
final partial page with ``position < length``.

A ring-buffer sliding-window cache is the same kernel with a small block
table (``ceil(W / page_size)`` entries): ring validity is always a prefix
``min(pos + 1, W)`` of the logical slot space, which is exactly the
``lengths`` contract (softmax is permutation-invariant over the key set
and RoPE is applied at write time, so slot order never matters).

``return_partials`` matches ``flash_decode``: fp32 ``(acc, m, l)`` for the
cross-shard LSE merge instead of locally-normalized output.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.flash_decode.flash_decode import (
    output_layout,
    unpack_outputs,
    write_outputs,
)

NEG_INF = -1e30


def _paged_kernel(
    bt_ref, ln_ref, q_ref, k_ref, v_ref, *refs,
    bs: int, nb: int, partials: bool,
):
    if partials:
        o_ref, mo_ref, lo_ref, m_ref, l_ref, acc_ref = refs
        out_refs = (o_ref, mo_ref, lo_ref)
    else:
        o_ref, m_ref, l_ref, acc_ref = refs
        out_refs = (o_ref,)
    bi = pl.program_id(0)
    jb = pl.program_id(2)
    length = ln_ref[bi]
    live = jb * bs < length

    @pl.when(jb == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(live)
    def _():
        q = q_ref[0, 0]                         # (G, hd)
        k = k_ref[0, :, 0, :]                   # (bs, hd) — one pool page
        v = v_ref[0, :, 0, :]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) / (q.shape[-1] ** 0.5)                 # (G, bs)
        kpos = jb * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < length, s, NEG_INF)

        m_prev = m_ref[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    @pl.when(jb == nb - 1)
    def _():
        write_outputs(partials, out_refs, m_ref, l_ref, acc_ref)


def flash_decode_paged(
    q: jax.Array,             # (B, H, hd)
    pool_k: jax.Array,        # (P, bs, K, hd) shared page pool
    pool_v: jax.Array,        # (P, bs, K, hd)
    block_tables: jax.Array,  # (B, NB) int32 logical block -> physical page
    lengths: jax.Array,       # (B,) int32 live context per request
    *,
    return_partials: bool = False,
    interpret: bool = False,
):
    b, nh, hd = q.shape
    bs, nkv = pool_k.shape[1], pool_k.shape[2]
    nb = block_tables.shape[1]
    g = nh // nkv
    qg = q.reshape(b, nkv, g, hd)
    grid = (b, nkv, nb)

    def kv_map(bi, kh, jb, bt, ln):
        # Dead blocks clamp to the request's last live block: repeated
        # identical indices make the pipeline skip the page fetch.
        last = jnp.maximum(ln[bi] - 1, 0) // bs
        return (bt[bi, jnp.minimum(jb, last)], 0, kh, 0)

    out_shape, out_specs = output_layout(
        return_partials, b, nkv, g, hd, q.dtype,
        lambda bi, kh, jb, bt, ln: (bi, kh, 0, 0),
    )
    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda bi, kh, jb, bt, ln: (bi, kh, 0, 0)),
            pl.BlockSpec((1, bs, 1, hd), kv_map),
            pl.BlockSpec((1, bs, 1, hd), kv_map),
        ],
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_kernel, bs=bs, nb=nb, partials=return_partials),
        grid_spec=spec,
        out_shape=out_shape,
        interpret=interpret,
    )(
        block_tables.astype(jnp.int32),
        lengths.astype(jnp.int32),
        qg,
        pool_k,
        pool_v,
    )
    return unpack_outputs(return_partials, out, b, nh, hd)
