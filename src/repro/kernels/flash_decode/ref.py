"""Pure-jnp oracle for single-token GQA decode attention with a mask."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_ref(
    q: jax.Array,        # (B, H, hd)
    k: jax.Array,        # (B, T, K, hd)
    v: jax.Array,        # (B, T, K, hd)
    valid: jax.Array,    # (B, T) bool/int — which cache slots participate
) -> jax.Array:
    b, nh, hd = q.shape
    nk = k.shape[2]
    g = nh // nk
    qg = q.reshape(b, nk, g, hd)
    s = jnp.einsum("bkgd,btkd->bkgt", qg, k).astype(jnp.float32)
    s = s / jnp.sqrt(hd)
    s = jnp.where(valid[:, None, None, :].astype(bool), s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bkgt,btkd->bkgd", p, v)
    return o.reshape(b, nh, hd)


def gather_pages(
    pool: jax.Array,          # (P, bs, K, hd) shared page pool
    block_tables: jax.Array,  # (B, NB) int32
) -> jax.Array:
    """Materialize each request's logical KV view from the pool: (B, NB*bs,
    K, hd). Reference-path helper (the kernel never builds this)."""
    b, nb = block_tables.shape
    _, bs, nkv, hd = pool.shape
    return jnp.take(pool, block_tables.reshape(-1), axis=0).reshape(
        b, nb * bs, nkv, hd
    )


def paged_decode_ref(
    q: jax.Array,             # (B, H, hd)
    pool_k: jax.Array,        # (P, bs, K, hd)
    pool_v: jax.Array,        # (P, bs, K, hd)
    block_tables: jax.Array,  # (B, NB) int32
    lengths: jax.Array,       # (B,) int32 — live context per request
) -> jax.Array:
    """Oracle for ``flash_decode_paged``: gather pages densely, mask the
    prefix ``lengths``, run the dense decode reference."""
    k = gather_pages(pool_k, block_tables)
    v = gather_pages(pool_v, block_tables)
    t = k.shape[1]
    valid = (jnp.arange(t)[None, :] < lengths[:, None]).astype(jnp.int32)
    return decode_ref(q, k, v, valid)
