"""Pure-jnp oracle for single-token GQA decode attention with a mask."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_ref(
    q: jax.Array,        # (B, H, hd)
    k: jax.Array,        # (B, T, K, hd)
    v: jax.Array,        # (B, T, K, hd)
    valid: jax.Array,    # (B, T) bool/int — which cache slots participate
) -> jax.Array:
    b, nh, hd = q.shape
    nk = k.shape[2]
    g = nh // nk
    qg = q.reshape(b, nk, g, hd)
    s = jnp.einsum("bkgd,btkd->bkgt", qg, k).astype(jnp.float32)
    s = s / jnp.sqrt(hd)
    s = jnp.where(valid[:, None, None, :].astype(bool), s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bkgt,btkd->bkgd", p, v)
    return o.reshape(b, nh, hd)
