# Pallas kernels for the paper's compute hot spots (grouped expert FFN,
# flash attention, flash decode) + the dispatch layer in ``registry.py``.
# Model code routes through ``repro.kernels.registry``; see README.md for
# flags, fallback rules and VMEM budgets.
