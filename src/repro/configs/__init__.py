"""Architecture registry: ``get_config(arch_id)`` for every ``--arch``."""

from __future__ import annotations

import importlib

from repro.configs.base import (
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    SHAPES,
    TRAIN_4K,
    ModelConfig,
    ShapeConfig,
    shapes_for,
    smoke,
)

_MODULES = {
    "llama3.2-1b": "llama3_2_1b",
    "qwen2-72b": "qwen2_72b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "deepseek-7b": "deepseek_7b",
    "zamba2-1.2b": "zamba2_1_2b",
    "dbrx-132b": "dbrx_132b",
    "mixtral-8x22b": "mixtral_8x22b",
    "xlstm-350m": "xlstm_350m",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "internvl2-76b": "internvl2_76b",
}

ARCHS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCHS}


__all__ = [
    "ARCHS",
    "ModelConfig",
    "ShapeConfig",
    "SHAPES",
    "TRAIN_4K",
    "PREFILL_32K",
    "DECODE_32K",
    "LONG_500K",
    "get_config",
    "all_configs",
    "shapes_for",
    "smoke",
]
