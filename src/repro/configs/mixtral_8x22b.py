"""mixtral-8x22b — 8 experts top-2, sliding-window attention [arXiv:2401.04088].

SWA (window 4096) makes decode KV window-bounded => sub-quadratic, so the
long_500k cell runs for this arch.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    n_experts=8,
    experts_per_token=2,
    moe_d_ff=16384,
    sliding_window=4096,
)
