"""internvl2-76b — InternViT + LLM backbone [arXiv:2404.16821].

Backbone only (llama3-70b-class decoder); the vision frontend is a STUB —
precomputed patch embeddings are prepended to the token stream.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    rope_theta=500000.0,
    frontend_stub=True,
    frontend_tokens=256,         # precomputed image patch embeddings
)
