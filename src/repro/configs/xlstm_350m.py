"""xlstm-350m — sLSTM + mLSTM blocks [arXiv:2405.04517].

Attention-free recurrent blocks; decode carries an O(1) state per layer, so
the long_500k cell runs. ``d_ff=0`` per the assignment: xLSTM blocks carry
their own internal up/down projections instead of a separate FFN.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    head_dim=256,
    d_ff=0,
    vocab_size=50304,
    block_pattern="xlstm",
    ssm_state=256,
)
