"""Model/config system.

``ModelConfig`` fully describes an architecture; ``ShapeConfig`` describes
one (seq_len, global_batch, step-kind) workload cell. The registry in
``repro.configs`` maps ``--arch`` ids to builders.

Every assigned architecture also ships a ``smoke()`` reduction: same block
pattern and family, tiny dims, runnable on one CPU device in tests.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0                # per-expert hidden (0 -> d_ff)
    # --- attention flavour ---------------------------------------------------
    sliding_window: int = 0          # 0 -> full attention
    qkv_bias: bool = False
    # --- SSM / hybrid ---------------------------------------------------------
    ssm_state: int = 0
    block_pattern: str = "attn"      # attn | mamba | zamba | xlstm | encdec
    attn_every: int = 0              # hybrid: attention block every k layers
    # --- enc-dec / multimodal -------------------------------------------------
    n_encoder_layers: int = 0
    frontend_stub: bool = False      # inputs are precomputed embeddings
    frontend_tokens: int = 0         # prepended stub embedding count
    # --- misc -------------------------------------------------------------
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def moe_d_ff_(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def subquadratic(self) -> bool:
        """Eligible for the long_500k cell (sub-quadratic decode)."""
        return self.block_pattern in ("mamba", "zamba", "xlstm") or (
            self.sliding_window > 0
        )

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, h = self.d_model, self.head_dim_
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        attn = d * self.n_heads * h + 2 * d * self.n_kv_heads * h + self.n_heads * h * d
        dense_mlp = 3 * d * self.d_ff if self.d_ff else 0
        if self.is_moe:
            mlp = self.n_experts * 3 * d * self.moe_d_ff_
        else:
            mlp = dense_mlp
        if self.block_pattern == "mamba" or self.block_pattern == "zamba":
            # Mamba2 block: in_proj (2*d_inner + heads...), rough 6*d^2.
            mamba = 6 * d * d
        else:
            mamba = 0
        per_layer = {
            "attn": attn + mlp,
            "encdec": attn + mlp,
            "mamba": mamba,
            "zamba": mamba,          # shared attn counted once below
            "xlstm": 5 * d * d,
        }[self.block_pattern]
        total = emb + self.n_layers * per_layer
        if self.block_pattern == "zamba":
            total += attn + dense_mlp        # one shared attention block
        if self.n_encoder_layers:
            total += self.n_encoder_layers * (attn + dense_mlp)
        return int(total)

    def active_param_count(self) -> int:
        """Activated params per token (MoE: only top-k experts)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        all_experts = self.n_layers * self.n_experts * 3 * d * self.moe_d_ff_
        active = self.n_layers * self.experts_per_token * 3 * d * self.moe_d_ff_
        return int(full - all_experts + active)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def shapes_for(cfg: ModelConfig) -> list[ShapeConfig]:
    """The shape cells applicable to an architecture. ``long_500k`` needs
    sub-quadratic attention (an O(L^2) full-attention pass cannot fit the
    524k context)."""
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.subquadratic:
        out.append(LONG_500K)
    return out


def smoke(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family reduction for CPU smoke tests."""
    deep = cfg.block_pattern in ("zamba", "xlstm")  # need a full block unit
    return dataclasses.replace(
        cfg,
        n_layers=min(cfg.n_layers, 4 if deep else 2),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        n_experts=min(cfg.n_experts, 4),
        experts_per_token=min(cfg.experts_per_token, 2),
        moe_d_ff=96 if cfg.is_moe else 0,
        sliding_window=32 if cfg.sliding_window else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        attn_every=2 if cfg.attn_every else 0,
        n_encoder_layers=2 if cfg.n_encoder_layers else 0,
        frontend_tokens=8 if cfg.frontend_stub else 0,
    )
