"""zamba2-1.2b — Mamba2 backbone + shared attention blocks [arXiv:2411.15242].

Hybrid: most layers are Mamba2 blocks; a single *shared* attention+MLP block
is invoked every ``attn_every`` layers (the Zamba signature). Sub-quadratic
decode (SSM state), so the long_500k cell runs.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    block_pattern="zamba",
    attn_every=6,
)
