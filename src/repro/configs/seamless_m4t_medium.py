"""seamless-m4t-medium — enc-dec multimodal backbone [arXiv:2308.11596].

The transformer backbone only: the audio frontend is a STUB — inputs arrive
as precomputed frame embeddings (``frontend_stub``), per the assignment.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,                 # decoder layers
    n_encoder_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    block_pattern="encdec",
    frontend_stub=True,
    frontend_tokens=1024,        # precomputed audio frame embeddings
)
