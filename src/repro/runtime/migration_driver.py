"""Live stepped expert migration, driven through the decode loop.

The analytical :class:`~repro.core.migration.MigrationEngine` showed that a
migration decomposed into Local/Global hops can ride the cold links the
attention/MoE collectives leave idle. This module is the *executable*
counterpart: it moves real expert weight rows, one slice per decode tick,
and swaps the routing table atomically only when the last slice has landed.

Lifecycle of one migration ``(expert, src_device, dst_device)``:

1. **submit** — reserve a destination slot in the shared
   :class:`~repro.parallel.placement.PlacementTable` (pending: visible to
   the balancer's planning view, invisible to routing) and decompose the
   move via :func:`repro.core.migration.decompose` into its Local/Global
   hop schedule. The hop count floors the slice count: a 3-hop migration
   never lands in fewer than 3 ticks.
2. **tick** (one per decode step) — issue one weight-row slice per tensor:
   a donated jit'd ``dynamic_slice``/``dynamic_update_slice`` pair copies
   rows ``[lo, lo+chunk)`` of the source slot into the reserved slot,
   in-place in the live parameter buffers. The copy is dispatched before
   the decode step and the arrays only meet again at the *next* step, so
   the transfer overlaps the step's compute — there is no whole-expert
   copy on the hot path. Tokens cannot observe the half-copied slot: it
   is not in the committed table.
3. **commit** — at the first tick boundary after the final slice was
   issued (i.e. after the XLA data dependency guarantees it landed before
   anything that consumes the new buffers), the table commit publishes the
   replica to the routing view. That single host-side table swap is the
   atomic commit point.

Device death mid-migration (``Server.mark_dead``) must never publish a
torn replica: in-flight migrations *to* the dead device are aborted (the
reservation is released) and requeued toward a live destination from slice
zero; migrations *from* the dead device are fast-forwarded — the remaining
slices are issued immediately and committed, which is safe under the
repo's logical death model (the scheduler stops routing to the device but
its memory stays addressable; see ``Server.mark_dead``).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.er_mapping import Mapping, baseline_mapping
from repro.core.migration import MigStep, decompose
from repro.core.ni_balancer import Migration
from repro.core.topology import MeshTopology
from repro.parallel.placement import PlacementTable

MOE_WEIGHTS = ("w_gate", "w_up", "w_down")


@functools.partial(jax.jit, static_argnames=("rows",), donate_argnums=(0,))
def _copy_row_slice(w, src_slot, dst_slot, lo, *, rows: int):
    """Copy rows ``[lo, lo+rows)`` of slot ``src_slot`` onto ``dst_slot``.

    ``w`` is ``(L, n_slots, rows_total, cols)`` and is donated: the copy
    updates the live buffer instead of round-tripping every expert weight
    (the old full-tensor ``.at[:, slot].set(...)`` functional update).
    Slot ids and ``lo`` are traced scalars, so every slice of every
    migration reuses one compiled program per (shape, chunk)."""
    blk = jax.lax.dynamic_slice(
        w, (0, src_slot, lo, 0), (w.shape[0], 1, rows, w.shape[3])
    )
    return jax.lax.dynamic_update_slice(w, blk, (0, dst_slot, lo, 0))


def _i32(x: int):
    return jnp.asarray(x, jnp.int32)


@dataclasses.dataclass
class InFlightMigration:
    mig: Migration
    src_slot: int
    dst_slot: int
    n_slices: int
    hops: list[MigStep]
    submitted: int                 # server tick at submission
    next_slice: int = 0
    issue_ticks: list[int] = dataclasses.field(default_factory=list)

    @property
    def expert(self) -> int:
        return self.mig[0]

    @property
    def copied(self) -> bool:
        return self.next_slice >= self.n_slices

    def record(self, committed: int | None) -> dict:
        return {
            "mig": tuple(self.mig),
            "expert": self.expert,
            "src_slot": self.src_slot,
            "dst_slot": self.dst_slot,
            "n_slices": self.n_slices,
            "hops": [(h.kind, h.src, h.dst) for h in self.hops],
            "submitted": self.submitted,
            "issue_ticks": list(self.issue_ticks),
            "committed": committed,
        }


class MigrationDriver:
    """Owns the in-flight migrations; the Server ticks it once per decode
    step (and the scheduler on idle ticks, via ``drain_migrations``)."""

    def __init__(
        self,
        table: PlacementTable,
        min_slices: int = 4,
        mapping: Mapping | None = None,
        expert_bytes: float | None = None,
    ):
        self.table = table
        self.min_slices = max(1, int(min_slices))
        # Hop decomposition needs a topology; virtual EP has no physical
        # mesh, so default to a 1-D mesh where every device shares one FTD
        # (decompose then yields the single-Local-hop schedule).
        self.mapping = mapping or baseline_mapping(
            MeshTopology(1, table.n_devices), table.n_devices, 1
        )
        self.expert_bytes = expert_bytes
        self.in_flight: list[InFlightMigration] = []
        self.history: list[dict] = []
        self.aborted: list[dict] = []

    # -- submission ----------------------------------------------------------

    def _slot_bytes(self, moe: dict) -> float:
        if self.expert_bytes is None:
            self.expert_bytes = float(
                sum(
                    moe[w].dtype.itemsize * moe[w].size / moe[w].shape[1]
                    for w in MOE_WEIGHTS
                )
            )
        return self.expert_bytes

    def submit(
        self, plan: list[Migration], moe: dict, t: int
    ) -> list[Migration]:
        """Reserve destination slots for a balancer plan and build each
        migration's slice schedule. Unplaceable entries (no free slot /
        replica cap / already hosted or in flight) are skipped, mirroring
        the instantaneous path's no-op contract. Returns the accepted
        migrations."""
        accepted: list[Migration] = []
        nbytes = self._slot_bytes(moe)
        for mig in plan:
            e, src, dst = mig
            src_slot = self.table.slot_on_device(e, src)
            if src_slot is None:
                continue
            dst_slot = self.table.try_reserve(e, dst)
            if dst_slot is None:
                continue
            hops = decompose(mig, self.mapping, nbytes)
            self.in_flight.append(
                InFlightMigration(
                    mig=mig,
                    src_slot=src_slot,
                    dst_slot=dst_slot,
                    n_slices=max(self.min_slices, len(hops)),
                    hops=hops,
                    submitted=t,
                )
            )
            accepted.append(mig)
        return accepted

    # -- per-tick drive ------------------------------------------------------

    def _issue_slice(self, moe: dict, fl: InFlightMigration, t: int) -> None:
        i = fl.next_slice
        for name in MOE_WEIGHTS:
            w = moe[name]
            total = w.shape[2]
            chunk = min(total, -(-total // fl.n_slices))
            lo = max(0, min(i * chunk, total - chunk))
            moe[name] = _copy_row_slice(
                w, _i32(fl.src_slot), _i32(fl.dst_slot), _i32(lo), rows=chunk
            )
        fl.next_slice += 1
        fl.issue_ticks.append(t)

    def tick(self, moe: dict, t: int) -> list[dict]:
        """One decode-tick worth of progress: first commit migrations whose
        last slice was issued on a *previous* tick (the atomic table swap,
        at the step boundary), then issue this tick's slice for the rest.
        Returns the committed records."""
        committed: list[dict] = []
        remaining: list[InFlightMigration] = []
        for fl in self.in_flight:
            if fl.copied:
                self.table.commit(fl.expert, fl.dst_slot)
                rec = fl.record(committed=t)
                self.history.append(rec)
                committed.append(rec)
            else:
                self._issue_slice(moe, fl, t)
                remaining.append(fl)
        self.in_flight = remaining
        return committed

    # -- device death --------------------------------------------------------

    def handle_device_death(
        self,
        device: int,
        moe: dict,
        t: int,
        retarget: Callable[[Migration], Migration | None] | None = None,
    ) -> dict:
        """Resolve in-flight migrations touching a dead device *before*
        evacuation plans against the table. Migrations **to** the device
        abort (reservation released — the routing view never saw the slot)
        and requeue as ``retarget(mig)`` — a replacement migration with a
        live source and destination — from slice zero; migrations **from**
        it fast-forward (remaining slices issued now, then committed) so
        the expert keeps a fully-copied live replica."""
        survivors: list[InFlightMigration] = []
        out = {"aborted": [], "requeued": [], "fast_forwarded": []}
        requeue: list[Migration] = []
        for fl in self.in_flight:
            e, src, dst = fl.mig
            if self.table.device_of(fl.dst_slot) == device:
                self.table.release_pending(e, fl.dst_slot)
                rec = fl.record(committed=None)
                self.aborted.append(rec)
                out["aborted"].append(rec)
                new_mig = retarget(fl.mig) if retarget else None
                if new_mig is not None:
                    requeue.append(new_mig)
            elif self.table.device_of(fl.src_slot) == device:
                while not fl.copied:
                    self._issue_slice(moe, fl, t)
                self.table.commit(e, fl.dst_slot)
                rec = fl.record(committed=t)
                self.history.append(rec)
                out["fast_forwarded"].append(rec)
            else:
                survivors.append(fl)
        self.in_flight = survivors
        if requeue:
            out["requeued"] = self.submit(requeue, moe, t)
        return out

    @property
    def pending(self) -> int:
        return len(self.in_flight)

    def export_in_flight(self) -> list[dict]:
        """JSON-able ledger of in-flight migrations, for crash snapshots.
        Only the plan entry and its progress are exported: a restore
        re-submits from slice zero (partially copied slices died with the
        crashed process's HBM), so slot ids and hop schedules are
        recomputed against the restored table."""
        return [
            {
                "mig": list(fl.mig),
                "next_slice": fl.next_slice,
                "n_slices": fl.n_slices,
                "submitted": fl.submitted,
            }
            for fl in self.in_flight
        ]
