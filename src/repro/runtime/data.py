"""Synthetic data pipeline: deterministic, host-sharded, resumable.

Real deployments stream tokenized shards; offline we generate a synthetic
corpus with *learnable structure* (an order-1 Markov chain over the vocab
with a few hundred high-probability transitions) so example trainings show
real loss curves, not noise-floor flatlines.

Determinism contract: ``batch_at(step)`` is a pure function of
``(seed, step, host_id)`` — restart/elastic-resume replays the exact
stream; the checkpoint stores only the step cursor.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    global_batch: int
    seq_len: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0


class SyntheticLM:
    """Order-1 Markov stream with a skewed transition structure."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        if cfg.global_batch % cfg.n_hosts:
            raise ValueError("global_batch must divide across hosts")
        self.local_batch = cfg.global_batch // cfg.n_hosts
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # Each token has 4 likely successors (p=0.2 each) + uniform tail.
        self._succ = rng.integers(0, v, size=(v, 4)).astype(np.int64)

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 64 + cfg.host_id
        )
        b, s, v = self.local_batch, cfg.seq_len, cfg.vocab_size
        toks = np.empty((b, s + 1), dtype=np.int64)
        toks[:, 0] = rng.integers(0, v, size=b)
        follow = rng.random((b, s)) < 0.8
        choice = rng.integers(0, 4, size=(b, s))
        rand_tok = rng.integers(0, v, size=(b, s))
        for t in range(s):
            nxt = self._succ[toks[:, t], choice[:, t]]
            toks[:, t + 1] = np.where(follow[:, t], nxt, rand_tok[:, t])
        return {
            "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "labels": jnp.asarray(toks[:, 1:], jnp.int32),
        }

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def request_stream(
    vocab_size: int, batch: int, prompt_len: int, seed: int = 0
):
    """Serving-side synthetic request batches (prompts of equal length)."""
    step = 0
    while True:
        rng = np.random.default_rng(seed + step)
        yield jnp.asarray(
            rng.integers(0, vocab_size, size=(batch, prompt_len)), jnp.int32
        )
        step += 1
