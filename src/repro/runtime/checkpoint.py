"""Fault-tolerant checkpointing (pure numpy, atomic, elastic restore).

* Pytrees flatten to path-keyed numpy arrays inside a single ``.npz``;
  writes go to a temp file + ``os.replace`` (atomic on POSIX), so a crash
  mid-save never corrupts the latest checkpoint. A checkpoint counts as
  *complete* only once its ``.meta`` sidecar landed too: ``steps()``
  skips meta-less torn writes, so ``restore()`` falls back to the newest
  complete step after a crash in the npz→meta window.
* ``CheckpointManager`` keeps the newest ``keep`` steps and can resume the
  data-pipeline cursor.
* **Elastic restore**: arrays come back as host numpy and are re-placed
  with whatever shardings the *new* mesh prescribes — restoring onto a
  different device count / mesh shape (node failure, pool resize) is the
  same code path as same-shape restore.
* ``async_save`` runs serialization off the training thread (device->host
  copy happens eagerly; file IO and retention GC overlap the next step;
  a failed background write re-raises from the next ``wait()``).

Used on both sides of the repo: the training loop checkpoints params +
optimizer + data cursor (``runtime/elastic.py``), and the serving tier
persists crash snapshots of its host-side scheduler/placement truth
through the same atomic writer (``runtime/snapshot.py``).
"""

from __future__ import annotations

import json
import os
import re
import threading

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def save(path: str, tree, step: int | None = None, extra: dict | None = None):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    tmp = path + ".tmp"
    np.savez(tmp, **flat)
    os.replace(tmp + ".npz" if not tmp.endswith(".npz") else tmp, path)
    meta = {"step": step, **(extra or {})}
    mtmp = path + ".meta.tmp"
    with open(mtmp, "w") as f:
        json.dump(meta, f)
    os.replace(mtmp, path + ".meta")


def restore(path: str, template, shardings=None):
    """Rebuild ``template``'s pytree from ``path``.

    ``shardings``: optional matching pytree of ``jax.sharding.Sharding`` —
    arrays are placed there (elastic restore onto any mesh)."""
    data = np.load(path)
    flat = dict(data)

    keys = []
    for p, _ in jax.tree_util.tree_flatten_with_path(template)[0]:
        keys.append("/".join(str(getattr(q, "key", getattr(q, "idx", q))) for q in p))
    leaves = [flat[k] for k in keys]
    tdef = jax.tree_util.tree_structure(template)
    tree = jax.tree_util.tree_unflatten(tdef, leaves)
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s), tree, shardings
        )
    return tree


def load_meta(path: str) -> dict:
    with open(path + ".meta") as f:
        return json.load(f)


class CheckpointManager:
    """Step-stamped checkpoints in a directory, newest-``keep`` retention."""

    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._exc: BaseException | None = None

    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"ckpt_{step:08d}.npz")

    def steps(self, complete_only: bool = True) -> list[int]:
        out = []
        for f in os.listdir(self.dir):
            m = re.fullmatch(r"ckpt_(\d+)\.npz", f)
            if not m:
                continue
            s = int(m.group(1))
            # A crash between the npz replace and the meta replace leaves a
            # torn checkpoint that load_meta would explode on; a complete
            # checkpoint has both halves. restore()'s latest() fallback
            # therefore lands on the newest *complete* step.
            if complete_only and not os.path.exists(self._path(s) + ".meta"):
                continue
            out.append(s)
        return sorted(out)

    def latest(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def save(self, step: int, tree, extra: dict | None = None):
        save(self._path(step), tree, step, extra)
        self._gc()

    def async_save(self, step: int, tree, extra: dict | None = None):
        """Snapshot to host now; write *and garbage-collect* in the
        background (the old thread target was bare ``save``, so ``keep``
        was never enforced for async-only users). A failed background
        write is re-raised from the next ``wait()`` / ``async_save()``
        instead of dying silently on the worker thread."""
        host = jax.tree.map(np.asarray, tree)  # device->host before returning
        self.wait()

        def _job():
            try:
                save(self._path(step), host, step, extra)
                self._gc()
            except BaseException as e:  # re-raised from wait()
                self._exc = e

        self._thread = threading.Thread(target=_job)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._exc is not None:
            exc, self._exc = self._exc, None
            raise exc

    def restore(self, template, step: int | None = None, shardings=None):
        step = step if step is not None else self.latest()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        tree = restore(self._path(step), template, shardings)
        return tree, load_meta(self._path(step))

    def _gc(self):
        complete = self.steps()
        for s in complete[: -self.keep]:
            for suffix in ("", ".meta"):
                try:
                    os.remove(self._path(s) + suffix)
                except FileNotFoundError:
                    pass
        if not complete:
            return
        # Torn writes (npz without meta) strictly older than the newest
        # complete step are crash debris — reclaim them. A *newer* meta-less
        # npz is spared: it may be an in-progress write whose meta is about
        # to land.
        for s in self.steps(complete_only=False):
            if s < complete[-1] and not os.path.exists(self._path(s) + ".meta"):
                try:
                    os.remove(self._path(s))
                except FileNotFoundError:
                    pass
