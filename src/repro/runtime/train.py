"""Training step: loss, gradients, microbatch accumulation, remat.

``make_train_step`` builds a jit-able ``(state, batch) -> (state, metrics)``
closure with:

* causal cross-entropy + MoE aux-loss,
* optional gradient accumulation over leading microbatches (lax.scan),
* remat over layers via ``ctx.remat`` (checkpointed scan bodies),
* optional cross-pod int8 gradient compression (see
  ``repro.parallel.grad_compress``) for the slow DCI axis.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.parallel.ctx import ParallelCtx
from repro.runtime.optimizer import AdamWConfig, adamw_init, adamw_update

AUX_WEIGHT = 0.01


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def loss_fn(params, batch, cfg: ModelConfig, ctx: ParallelCtx):
    logits, aux = T.forward(
        params, batch["tokens"], cfg, ctx, embeds=batch.get("embeds")
    )
    ce = cross_entropy(logits, batch["labels"])
    return ce + AUX_WEIGHT * aux["loss"], {"ce": ce, "aux": aux["loss"]}


def make_train_step(
    cfg: ModelConfig,
    ctx: ParallelCtx,
    opt: AdamWConfig,
    microbatches: int = 1,
    grad_compress: bool = False,
):
    """Build the train step. ``batch["tokens"]``: (microbatches?, B, S)."""

    def grads_of(params, batch):
        (loss, met), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch, cfg, ctx
        )
        met["loss"] = loss
        return grads, met

    def step(state, batch):
        params, opt_state = state["params"], state["opt"]
        if microbatches > 1:
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def scan_body(g_acc, mb):
                g, met = grads_of(params, mb)
                return jax.tree.map(jnp.add, g_acc, g), met

            grads, mets = jax.lax.scan(scan_body, zero, batch)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            met = jax.tree.map(jnp.mean, mets)
        else:
            grads, met = grads_of(params, batch)

        # Cross-pod traffic strategy: per-step grads reduce over the batch
        # axes via GSPMD; with grad_compress the caller instead keeps the
        # pod axis OUT of the batch spec and reconciles pods periodically
        # through repro.parallel.grad_compress.compressed_pod_mean (DiLoCo-
        # style), which is applied by the training loop, not per step.
        new_params, new_opt, om = adamw_update(grads, opt_state, params, opt)
        met.update(om)
        return {"params": new_params, "opt": new_opt}, met

    return step


def init_state(rng, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    params = T.init_params(rng, cfg, dtype)
    return {"params": params, "opt": adamw_init(params)}
