"""Continuous-batching request scheduler over the paged ``Server``.

The ``RequestScheduler`` owns the request lifecycle

    QUEUED -> PREFILLING -> DECODING -> FINISHED
                   ^            |
                   '-- PREEMPTED (requeued, recomputed on re-admission)
                            |
                         FAILED (capacity / retry exhaustion)

over the existing jit-stable step function: the batch shape never changes —
empty slots are masked inert (write-off pages, length pinned to 0, excluded
from MoE routing via ``slot_mask``) — so admission, retirement and
preemption are pure host-side bookkeeping between steps, with zero
recompilation.

Per tick (``step()``):

1. **faults** — drain the :class:`repro.runtime.faults.FaultPlan` for this
   step (device death, stragglers, pool pressure, NaN logits);
2. **admission** — FIFO over arrived requests, watermark-gated against
   ``PagePool`` occupancy (strict FIFO among arrived requests: the head
   blocks, so admission is starvation-free). With
   ``ServeConfig(prefill_chunk=C)`` admission only stakes out a slot and
   pre-allocates pages; the request then rides the decode step's prefill
   lane, one C-token chunk per tick, until the final chunk's logits emit
   its first token and the slot flips to DECODING — live decode slots
   never stall more than the one fused step they already share. Without
   ``prefill_chunk``, admission is a batch-1 prefill spliced into one
   empty slot (``Server.prefill_into_slot``), which stalls the batch for
   the full prompt length;
3. **headroom** — if the live requests' next writes need more fresh pages
   than the pool holds, preempt (victim: fewest decoded tokens, youngest
   first) until the step cannot exhaust the pool — instead of the
   ``RuntimeError`` mid-``decode`` that a pool miss used to raise;
4. **decode** — one jitted step over the whole batch; per-slot argmax,
   EOS / max-token retirement recycling pages and slots mid-flight.

Determinism contract (the chaos parity test): per-request outputs are a
pure function of (params, prompt, max_new_tokens, eos) — independent of
batch composition, arrival order, placement changes and preemptions —
because every per-token computation is row-independent, expert replicas
are exact weight copies, and preempted work is recomputed from the full
prompt + already-emitted tokens. The one caveat is capacity drops: keep
``ParallelCtx.capacity_factor`` high enough that no routed copy is ever
dropped, or whole-batch routing pressure leaks between requests.

See docs/serving.md for the full state machine and design notes.
"""

from __future__ import annotations

import collections
import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.runtime import faults as F

QUEUED = "QUEUED"
PREFILLING = "PREFILLING"
DECODING = "DECODING"
FINISHED = "FINISHED"
PREEMPTED = "PREEMPTED"
FAILED = "FAILED"


@dataclasses.dataclass
class Request:
    """One generation request and its lifecycle state."""

    rid: int
    prompt: np.ndarray               # (P,) int32 prompt tokens
    max_new_tokens: int
    eos_id: int | None = None
    arrival: int = 0                 # earliest scheduler step for admission
    state: str = QUEUED
    slot: int | None = None          # batch row while PREFILLING/DECODING
    tokens_out: list = dataclasses.field(default_factory=list)
    preemptions: int = 0             # pool evictions + fault requeues
    error: str | None = None
    # Chunked-admission progress: context tokens already prefilled (the
    # chunk lane has written their KV). Meaningful only while PREFILLING;
    # reset to 0 on preemption/crash (the KV dies with the slot/process
    # and the standard recompute re-prefills from chunk zero).
    prefill_pos: int = 0
    # Serving stats (ticks are scheduler steps, not wall time).
    admitted_step: int | None = None     # first PREFILLING/DECODING tick
    first_token_step: int | None = None  # tick the first token was emitted
    last_token_step: int | None = None   # tick of the most recent token
    max_stall: int = 0                   # widest gap between tokens, -1 tick

    @property
    def ttft_ticks(self) -> int | None:
        """Ticks from arrival until the first token existed (1 = the very
        first eligible tick emitted it). None until it has."""
        if self.first_token_step is None:
            return None
        return self.first_token_step - self.arrival + 1

    @property
    def n_decoded(self) -> int:
        return len(self.tokens_out)

    @property
    def context_len(self) -> int:
        """Tokens a (re)admission prefill must write: the prompt plus every
        token already emitted (recompute-on-preemption semantics)."""
        return len(self.prompt) + len(self.tokens_out)

    @property
    def done(self) -> bool:
        return self.state in (FINISHED, FAILED)


@dataclasses.dataclass
class SchedulerConfig:
    # Admit only while (occupied + needed) / pool <= watermark — headroom
    # for lazy decode-time page growth. A request that can't pass the
    # watermark with the system otherwise empty is admitted anyway
    # (progress guarantee for pools smaller than the watermark slack).
    admit_watermark: float = 0.85
    # A request evicted (pool pressure or fault requeue) more than this
    # many times FAILs instead of looping forever.
    max_preemptions: int = 8
    # Prompts are right-padded to power-of-two buckets (>= this floor) so
    # admission prefills hit a bounded set of jit traces.
    prompt_bucket_floor: int = 8
    # run() safety valve.
    max_steps: int = 10_000
    # Crash safety: every `snapshot_every` ticks, snapshot the scheduler's
    # end-of-previous-tick state (kept on `last_snapshot`; also written
    # atomically to `snapshot_path` when set). 0 disables the cadence —
    # `crash_restart` faults still snapshot at the crash tick.
    snapshot_every: int = 0
    snapshot_path: str = ""


class RequestScheduler:
    """Host-side continuous-batching loop over a paged ``Server``."""

    def __init__(self, server, cfg: SchedulerConfig | None = None, faults=None):
        if not server.scfg.paged:
            raise ValueError(
                "RequestScheduler needs ServeConfig(paged=True): slot-level "
                "admission and retirement are page-table operations"
            )
        self.server = server
        self.cfg = cfg or SchedulerConfig()
        self.faults = faults or F.FaultPlan()
        self.batch = server.scfg.batch
        self.cap_tokens = server.n_blocks * server.page_size
        self.queue: collections.deque[Request] = collections.deque()
        self.slots: list[Request | None] = [None] * self.batch
        self.cache = server.empty_cache()
        self.next_tok = np.zeros((self.batch, 1), np.int32)
        self.step_no = 0
        self.requests: list[Request] = []
        self.events: list[tuple] = []        # (step, kind, detail)
        self.n_preempted = 0
        self._rid = 0
        self._hostage: list[int] = []        # pages stolen by pool_pressure
        self._poison: set[int] | None = None  # nan_logits slots this tick
        self.last_snapshot = None            # most recent ServerSnapshot
        # Chunked admission (ServeConfig.prefill_chunk): at most one request
        # is mid-prefill at a time — the head of admission, one chunk per
        # tick through the decode step's prefill lane.
        self.chunk: int | None = server.scfg.prefill_chunk
        self._prefilling: Request | None = None

    # -- submission ----------------------------------------------------------

    def submit(
        self,
        prompt,
        max_new_tokens: int,
        eos_id: int | None = None,
        arrival: int = 0,
    ) -> Request:
        """Enqueue a request. Requests whose full context can never fit the
        per-request KV capacity FAIL immediately (named, not a decode-time
        RuntimeError half way through)."""
        req = Request(
            rid=self._rid,
            prompt=np.asarray(prompt, np.int32).reshape(-1),
            max_new_tokens=int(max_new_tokens),
            eos_id=eos_id,
            arrival=int(arrival),
        )
        self._rid += 1
        self.requests.append(req)
        if req.max_new_tokens < 1 or len(req.prompt) < 1:
            req.state = FAILED
            req.error = "empty prompt or non-positive max_new_tokens"
            return req
        if len(req.prompt) + req.max_new_tokens - 1 > self.cap_tokens:
            req.state = FAILED
            req.error = (
                f"request needs {len(req.prompt) + req.max_new_tokens - 1} KV "
                f"rows > per-request capacity {self.cap_tokens}; raise "
                f"max_seq or trim the request"
            )
            return req
        self.queue.append(req)
        return req

    # -- pool accounting -----------------------------------------------------

    def _pages_for(self, n_tokens: int) -> int:
        ps = self.server.page_size
        nb = self.server.n_blocks
        return min(-(-min(n_tokens, self.cap_tokens) // ps), nb)

    def _live(self) -> list[Request]:
        return [r for r in self.slots if r is not None]

    def _free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slots) if r is None]

    def _admissible(self, req: Request) -> bool:
        pool = self.server.page_pool
        need = self._pages_for(req.context_len)
        if need > pool.n_free:
            return False
        if not self._live():
            return True   # empty system: progress beats the watermark
        occupied = pool.n_pages - pool.n_free
        return occupied + need <= self.cfg.admit_watermark * pool.n_pages

    # -- lifecycle transitions ----------------------------------------------

    def _bucket(self, n: int) -> int:
        m = self.cfg.prompt_bucket_floor
        while m < n:
            m *= 2
        return min(m, self.cap_tokens)

    def _admit(self, req: Request, slot: int) -> None:
        req.state = PREFILLING
        req.admitted_step = self.step_no
        if self.chunk:
            # Chunked admission: no device work here — just stake out the
            # slot and pre-allocate the pages. The decode step's prefill
            # lane writes one chunk per tick (step() drives it) until the
            # final chunk's logits emit the first token and the slot flips
            # to DECODING.
            req.slot = slot
            req.prefill_pos = 0
            self.slots[slot] = req
            self._prefilling = req
            self.server.begin_chunk_prefill(slot, req.context_len)
            self.events.append((self.step_no, "admit", req.rid))
            return
        ctx_tokens = np.concatenate(
            [req.prompt, np.asarray(req.tokens_out, np.int32)]
        )
        true_len = len(ctx_tokens)
        padded = np.zeros(self._bucket(true_len), np.int32)
        padded[:true_len] = ctx_tokens
        logits, self.cache = self.server.prefill_into_slot(
            slot, padded[None, :], self.cache, length=true_len
        )
        req.slot = slot
        self.slots[slot] = req
        req.state = DECODING
        self.events.append((self.step_no, "admit", req.rid))
        # The prefill's last-position logits emit this request's next token
        # — for a recompute, bit-for-bit the token the preempted decode
        # would have produced next.
        self._push_token(req, int(np.argmax(np.asarray(logits[0, -1]))))

    def _push_token(self, req: Request, tok: int) -> bool:
        """Append an emitted token; retire on EOS / max-token. Returns
        whether the request finished."""
        req.tokens_out.append(tok)
        if req.first_token_step is None:
            req.first_token_step = self.step_no
        elif req.last_token_step is not None:
            req.max_stall = max(
                req.max_stall, self.step_no - req.last_token_step - 1
            )
        req.last_token_step = self.step_no
        hit_eos = req.eos_id is not None and tok == req.eos_id
        if hit_eos or len(req.tokens_out) >= req.max_new_tokens:
            self._retire(req, FINISHED)
            return True
        self.next_tok[req.slot, 0] = tok
        return False

    def _retire(self, req: Request, state: str) -> None:
        """Free the request's slot and pages (they are reusable by the very
        next admission, mid-flight)."""
        self.cache = self.server.release(req.slot, self.cache)
        self.slots[req.slot] = None
        req.slot = None
        req.state = state
        self.events.append((self.step_no, "retire", req.rid))

    def _preempt(self, req: Request, reason: str) -> None:
        """Evict a running request; requeue it at the front for recompute,
        or FAIL it past the retry budget. Only this request is affected —
        the step loop and its batchmates keep going. A request preempted
        mid-prefill never emitted a token, so nothing is ever un-counted:
        its chunk pages go back to the pool and re-admission restarts the
        chunk state machine from position 0."""
        if req.state == PREFILLING and self.chunk:
            self.server.abort_chunk_prefill(req.slot)
            if self._prefilling is req:
                self._prefilling = None
            req.prefill_pos = 0
        else:
            self.cache = self.server.release(req.slot, self.cache)
        self.slots[req.slot] = None
        req.slot = None
        req.preemptions += 1
        self.n_preempted += 1
        self.events.append((self.step_no, "preempt", (req.rid, reason)))
        if req.preemptions > self.cfg.max_preemptions:
            req.state = FAILED
            req.error = f"evicted {req.preemptions} times (last: {reason})"
        else:
            req.state = PREEMPTED
            self.queue.appendleft(req)

    # -- per-tick phases -----------------------------------------------------

    def _apply_faults(self) -> None:
        pool = self.server.page_pool
        for f in self.faults.at(self.step_no):
            self.events.append((self.step_no, "fault", (f.kind, f)))
            if f.kind == F.CRASH_RESTART:
                continue   # handled at the top of step(), pre-snapshot
            if f.kind == F.DEVICE_DEATH:
                plan = self.server.mark_dead(f.device)
                self.events.append(
                    (self.step_no, "evacuated", (f.device, len(plan)))
                )
            elif f.kind == F.DEVICE_REVIVAL:
                plan = self.server.revive(f.device)
                self.events.append(
                    (self.step_no, "revived", (f.device, len(plan)))
                )
            elif f.kind == F.STRAGGLER:
                self.server.report_step_time(f.device, f.ratio)
            elif f.kind == F.POOL_PRESSURE:
                stolen = pool.alloc(min(f.pages, pool.n_free))
                self._hostage.extend(stolen)
            elif f.kind == F.POOL_RELEASE:
                n = min(f.pages or len(self._hostage), len(self._hostage))
                back, self._hostage = self._hostage[:n], self._hostage[n:]
                pool.free(back)
            elif f.kind == F.NAN_LOGITS:
                self._poison = set(f.slots) if f.slots else None
                if self._poison is None:
                    self._poison = {i for i, r in enumerate(self.slots) if r}

    def _admit_ready(self) -> None:
        while self.queue:
            if self.chunk and self._prefilling is not None:
                # One admission in flight at a time: the prefill lane is a
                # single chunk per tick, and strict FIFO means nobody may
                # overtake the head mid-prefill anyway.
                return
            free = self._free_slots()
            if not free:
                return
            # Strict FIFO among arrived requests: the earliest-queued
            # arrived request either admits or blocks admission this tick.
            head = next(
                (r for r in self.queue if r.arrival <= self.step_no), None
            )
            if head is None or not self._admissible(head):
                return
            self.queue.remove(head)
            self._admit(head, free[0])

    def _ensure_headroom(self) -> None:
        """Preempt until this step's lazy page growth cannot exhaust the
        pool (victim: fewest decoded tokens; ties broken youngest-first)."""
        srv = self.server
        while True:
            live = self._live()
            deficit = (
                sum(
                    srv.next_write_unbacked(r.slot)
                    for r in live
                    if r.state == DECODING
                )
                - srv.page_pool.n_free
            )
            if deficit <= 0 or not live:
                return
            # A mid-prefill request holds every page it will ever need (no
            # lazy growth), so it contributes nothing to the deficit — but
            # it is the cheapest victim (zero decoded tokens) and evicting
            # it returns the most pages at once.
            victim = min(live, key=lambda r: (r.n_decoded, -r.rid))
            self._preempt(victim, "pool-exhausted")

    def _drain_migrations(self) -> None:
        """Keep in-flight stepped expert migrations landing on idle ticks.
        When requests are live the decode step itself drives the
        MigrationDriver (one slice per decode tick, overlapped with the
        step's compute); on an idle tick there is no decode to ride, so
        the scheduler advances the slices here — a dead batch must not
        freeze a half-copied replica in limbo."""
        if not self._live():
            self.server.drain_migrations()

    # -- the tick ------------------------------------------------------------

    def save_snapshot(self, path: str | None = None):
        """Capture end-of-previous-tick state as a ServerSnapshot (kept on
        ``last_snapshot``); with ``path``, also persist it via the atomic
        checkpoint writer. Lazy import: snapshot.py layers on top of the
        scheduler, not under it."""
        from repro.runtime import snapshot as S

        snap = S.snapshot_scheduler(self)
        if path:
            S.save_snapshot(path, snap)
        self.last_snapshot = snap
        return snap

    def step(self) -> list[Request]:
        """One scheduler tick. Returns the requests that finished.

        Snapshot/crash handling comes first — before faults, admission or
        decode — so a snapshot always captures a clean tick boundary (the
        end of the previous tick) and the faults of the crash tick re-fire
        exactly once after a restore."""
        if (
            self.cfg.snapshot_every
            and self.step_no
            and self.step_no % self.cfg.snapshot_every == 0
        ):
            self.save_snapshot(self.cfg.snapshot_path or None)
        crash = next(
            (
                f
                for f in self.faults.at(self.step_no)
                if f.kind == F.CRASH_RESTART
            ),
            None,
        )
        if crash is not None:
            snap = self.save_snapshot(crash.path or None)
            raise F.SimulatedCrash(self.step_no, snap, crash.path)
        self._apply_faults()
        self._admit_ready()
        self._ensure_headroom()
        self._drain_migrations()
        finished: list[Request] = []
        if self._live():
            chunk = None
            chunk_n = 0
            pf = self._prefilling
            if pf is not None:
                # One fixed-size chunk of the head-of-admission request's
                # context rides this tick's step (right-padded — the shape
                # is jit-stable; `length` marks the valid rows).
                ctx_tokens = np.concatenate(
                    [pf.prompt, np.asarray(pf.tokens_out, np.int32)]
                )
                chunk_n = min(self.chunk, len(ctx_tokens) - pf.prefill_pos)
                buf = np.zeros(self.chunk, np.int32)
                buf[:chunk_n] = ctx_tokens[
                    pf.prefill_pos : pf.prefill_pos + chunk_n
                ]
                chunk = self.server.chunk_operand(
                    pf.slot, buf, pf.prefill_pos, chunk_n
                )
            logits, self.cache = self.server.decode(
                jnp.asarray(self.next_tok), self.cache, chunk=chunk
            )
            rows = np.asarray(logits[:, -1])                 # (B, V)
            poison = self._poison or set()
            if poison:
                rows = rows.copy()
                rows[sorted(poison)] = np.nan
            for slot, req in enumerate(self.slots):
                if req is None or req.state != DECODING:
                    continue    # PREFILLING rows are masked garbage
                row = rows[slot]
                if not np.isfinite(row).all():
                    # Numerics blew up for this row only: requeue it for a
                    # clean recompute instead of emitting garbage; the
                    # step loop and the other requests never notice.
                    self._preempt(req, "non-finite-logits")
                    continue
                if self._push_token(req, int(np.argmax(row))):
                    finished.append(req)
            if pf is not None:
                pf.prefill_pos += chunk_n
                if pf.prefill_pos >= pf.context_len:
                    # Final chunk: its last-position logits emit the first
                    # token — for a recompute, bit-for-bit the token the
                    # preempted decode would have produced next — and the
                    # slot flips live atomically (table + length splice).
                    crow = np.asarray(self.server.last_chunk_logits[0, -1])
                    if pf.slot in poison or not np.isfinite(crow).all():
                        self._preempt(pf, "non-finite-logits")
                    else:
                        self.cache = self.server.finish_chunk_prefill(
                            pf.slot, self.cache, pf.context_len
                        )
                        pf.state = DECODING
                        self._prefilling = None
                        if self._push_token(pf, int(np.argmax(crow))):
                            finished.append(pf)
        self._poison = None
        self.step_no += 1
        return finished

    def run(self, max_steps: int | None = None) -> dict[int, np.ndarray]:
        """Drive ``step()`` until every submitted request is FINISHED or
        FAILED (idle ticks advance time toward future arrivals / faults).
        Returns ``results()``."""
        limit = max_steps or self.cfg.max_steps
        last_fault = max((f.step for f in self.faults), default=-1)
        for _ in range(limit):
            if all(r.done for r in self.requests) and not self.queue:
                return self.results()
            self.step()
            if (
                not self._live()
                and self.queue
                and self.step_no > last_fault
                and all(r.arrival <= self.step_no for r in self.queue)
            ):
                head = next(
                    (r for r in self.queue if self._admissible(r)), None
                )
                if head is None and not self._free_slots():
                    continue  # unreachable: no live => slots all free
                if head is None:
                    # Nothing live, nothing can ever admit (pool starved for
                    # good): fail the head instead of spinning forever.
                    stuck = self.queue.popleft()
                    stuck.state = FAILED
                    stuck.error = (
                        f"needs {self._pages_for(stuck.context_len)} pages; "
                        f"pool has {self.server.page_pool.n_free} free for "
                        f"good — undersized pool or leaked pressure"
                    )
                    self.events.append((self.step_no, "admit-failed", stuck.rid))
        if not all(r.done for r in self.requests):
            raise RuntimeError(
                f"scheduler made no full progress in {limit} steps: "
                f"{[r.state for r in self.requests]}"
            )
        return self.results()

    def results(self) -> dict[int, np.ndarray]:
        """rid -> emitted tokens (present for every submitted request;
        FAILED requests report what they produced before failing)."""
        return {
            r.rid: np.asarray(r.tokens_out, np.int32) for r in self.requests
        }

    def stats(self) -> dict:
        """Serving statistics in scheduler ticks (not wall time).

        ``prefill_backlog`` counts context tokens still to prefill: the
        in-flight request's remaining chunks plus the full context of every
        queued request. ``ttft_ticks`` is arrival-to-first-token (1 = the
        first eligible tick emitted it; ``ceil(len/chunk)`` for uncontended
        chunked admission); ``max_stall_ticks`` is the widest gap between a
        request's consecutive tokens minus one — 0 means every tick after
        the first token emitted one, i.e. O(1) inter-token latency even
        while long prompts were being admitted."""
        backlog = sum(r.context_len for r in self.queue)
        if self._prefilling is not None:
            pf = self._prefilling
            backlog += pf.context_len - pf.prefill_pos
        ttfts = [
            r.ttft_ticks for r in self.requests if r.ttft_ticks is not None
        ]
        return {
            "step": self.step_no,
            "queue_depth": len(self.queue),
            "prefill_backlog": backlog,
            "n_preempted": self.n_preempted,
            # Static dispatch-pipeline depth of the step program (ops
            # visibility: 1 = single-shot EP dispatch, K = chunked overlap).
            "ep_chunks": self.server.scfg.ep_chunks,
            "max_ttft_ticks": max(ttfts, default=None),
            "max_stall_ticks": max(
                (r.max_stall for r in self.requests), default=0
            ),
            "per_request": {
                r.rid: {
                    "state": r.state,
                    "ttft_ticks": r.ttft_ticks,
                    "max_stall_ticks": r.max_stall,
                    "n_tokens": r.n_decoded,
                    "preemptions": r.preemptions,
                }
                for r in self.requests
            },
        }
