"""Elastic scaling & failure handling glue.

Training-side story (the 1000+ node contract):

1. every N steps the loop calls ``CheckpointManager.async_save`` (params +
   optimizer + data cursor);
2. on node failure the job restarts on the surviving pool — ``make_mesh``
   with the new device count, ``restore_elastic`` re-places the same host
   arrays under the new shardings, the data pipeline resumes from the
   stored step (deterministic ``batch_at``);
3. a changed ``data``-axis size only changes *throughput*; per-step
   semantics stay identical because the global batch is respecified, not
   resharded from device state.

Serving-side: ``Server.mark_dead`` + Algorithm 1 evacuate experts; decode
batches re-route around the dead device (heat = inf).

Straggler mitigation: ``StepTimer`` tracks per-step wall times and flags
outliers (>1.5x median EMA) so the caller can feed ``report_step_time``.
"""

from __future__ import annotations

import time

import numpy as np

from repro.runtime.checkpoint import CheckpointManager


def restore_elastic(mgr: CheckpointManager, template, mesh, sharding_fn):
    """Restore the latest checkpoint onto an arbitrary mesh.

    ``sharding_fn(mesh, template) -> pytree of NamedSharding`` encodes the
    layout policy; arrays come back host-side and are placed fresh, so the
    previous run's device count is irrelevant.
    """
    shardings = sharding_fn(mesh, template) if mesh is not None else None
    return mgr.restore(template, shardings=shardings)


class StepTimer:
    """EMA step timer with straggler detection."""

    def __init__(self, alpha: float = 0.9, threshold: float = 1.5):
        self.alpha = alpha
        self.threshold = threshold
        self.ema: float | None = None
        self._t0: float | None = None

    def __enter__(self):
        self._t0 = time.monotonic()
        return self

    def __exit__(self, *exc):
        dt = time.monotonic() - self._t0
        self.last = dt
        self.ema = dt if self.ema is None else self.alpha * self.ema + (1 - self.alpha) * dt

    @property
    def is_straggling(self) -> bool:
        return self.ema is not None and self.last > self.threshold * self.ema

    @property
    def ratio(self) -> float:
        if self.ema is None or self.ema == 0:
            return 1.0
        return float(self.last / self.ema)


def _drain_all(server, limit: int = 256) -> int:
    """Tick the stepped migration driver on idle time until nothing is in
    flight — a drill has no decode loop for the slices to ride, so this
    plays the scheduler's idle-tick role. Advances ``server.t`` (commits
    need a tick boundary after the last slice). Returns ticks consumed."""
    if server.driver is None:
        return 0
    ticks = 0
    while server.driver.pending and ticks < limit:
        server.drain_migrations()
        server.t += 1
        ticks += 1
    # one final boundary: commit anything whose last slice just issued
    server.drain_migrations()
    return ticks


def drill_failure(server, device: int, revive: bool = False) -> dict:
    """Fault-injection drill: kill a device, rebalance, optionally revive
    it — through the *public* serving path (``Server.mark_dead`` /
    ``apply_plan`` / ``revive``), so the drill exercises exactly the
    stepped-migration machinery production uses (the old version reached
    into the private instantaneous ``_apply_migration``). Reports peak-heat
    recovery and, with ``revive=True``, revival recovery time in ticks.
    Used by tests and the ops runbook."""
    state = server.state
    if state is None:
        return {"supported": False}
    before = float(np.max(state.heats()[np.isfinite(state.heats())]))
    from repro.core.ni_balancer import topology_aware_balance

    # Availability first: Server.mark_dead runs the whole evacuation path
    # (state + physical weight rows + routing-table drop). Then rebalance
    # the surviving devices for load, driving the plan through the same
    # migration path (stepped driver or instantaneous) serving uses.
    plan = server.mark_dead(device)
    migs = topology_aware_balance(state, server.distance)
    applied = server.apply_plan(migs)
    _drain_all(server)
    heats = state.heats()
    after = float(np.max(heats[np.isfinite(heats)]))
    # The availability invariant: every expert keeps at least one replica
    # on a live device (only an out-of-slots evacuation can violate it).
    evacuated = all(
        any(d not in state.dead for d in state.replicas[e])
        for e in range(state.n_experts)
    )
    out = {
        "supported": True,
        "migrations": len(plan) + applied,
        "peak_before": before,
        "peak_after": after,
        "evacuated": evacuated,
    }
    if revive:
        rplan = server.revive(device)
        ticks = _drain_all(server)
        heats = state.heats()
        out["revival_migrations"] = len(rplan)
        # Ticks from revival until every seeded replica committed — the
        # window in which the device is back up but carries no traffic.
        out["revival_recovery_ticks"] = ticks
        out["revival_replicas"] = sum(
            device in devs for devs in state.replicas
        )
        out["peak_after_revival"] = float(
            np.max(heats[np.isfinite(heats)])
        )
    return out
