"""Fault-injection harness for the serving loop.

A :class:`FaultPlan` is a deterministic schedule of :class:`Fault` events,
keyed by scheduler step. The :class:`repro.runtime.scheduler.RequestScheduler`
drains the plan at the start of each tick and degrades gracefully: a fault
fails or requeues only the requests it touches — the jitted step loop never
crashes, and (because preempted work is recomputed from the prompt) the
surviving requests' outputs stay bit-identical to a fault-free run.

Fault kinds
-----------

* ``device_death``  — ``Server.mark_dead(device)``: evacuate orphaned
  experts (state + physical weight rows), drop the device from routing.
* ``straggler``     — ``Server.report_step_time(device, ratio)``: folds a
  measured slowdown into the balancer heats, draining load away.
* ``pool_pressure`` — steals ``pages`` pages from the ``PagePool`` (an
  external tenant / fragmentation stand-in), forcing admission backpressure
  and preemption.
* ``pool_release``  — returns ``pages`` stolen pages (all, if fewer held).
* ``nan_logits``    — poisons the chosen batch ``slots``' logits with NaN
  for one step (a numerics-blowup stand-in); the scheduler detects the
  non-finite row and requeues the request for recompute instead of
  emitting garbage tokens.
* ``device_revival`` — ``Server.revive(device)``: re-admits a repaired
  device with blank HBM; replica copies stream back through the stepped
  migration driver and routing only references the device once they
  commit.
* ``crash_restart`` — simulated host crash: the scheduler snapshots its
  state (end of the previous tick) and raises :class:`SimulatedCrash`
  before doing any work this tick; the harness rebuilds a fresh
  scheduler from the snapshot and the run resumes bit-identically.

``FaultPlan.chaos`` builds a seeded random plan with the shape the chaos
parity test (and the CI smoke) uses: one device death, a straggler report,
a pool-pressure window, and a NaN step — plus, with ``revive=True``, a
revival of the killed device a few steps after its death.
"""

from __future__ import annotations

import dataclasses

import numpy as np

DEVICE_DEATH = "device_death"
STRAGGLER = "straggler"
POOL_PRESSURE = "pool_pressure"
POOL_RELEASE = "pool_release"
NAN_LOGITS = "nan_logits"
DEVICE_REVIVAL = "device_revival"
CRASH_RESTART = "crash_restart"

KINDS = (
    DEVICE_DEATH,
    STRAGGLER,
    POOL_PRESSURE,
    POOL_RELEASE,
    NAN_LOGITS,
    DEVICE_REVIVAL,
    CRASH_RESTART,
)


class SimulatedCrash(Exception):
    """Raised by the scheduler when a ``crash_restart`` fault fires.

    Carries everything the harness needs to play the crash for real:
    the snapshot of end-of-previous-tick state (also written to ``path``
    when one was given), from which a fresh process rebuilds the server
    and scheduler and resumes."""

    def __init__(self, step: int, snapshot, path: str = ""):
        super().__init__(f"simulated crash at scheduler step {step}")
        self.step = step
        self.snapshot = snapshot
        self.path = path


@dataclasses.dataclass(frozen=True)
class Fault:
    """One injected event at scheduler step ``step``."""

    step: int
    kind: str
    device: int = 0          # device_death / straggler / device_revival
    ratio: float = 1.0       # straggler step-time ratio
    pages: int = 0           # pool_pressure / pool_release page count
    slots: tuple[int, ...] = ()  # nan_logits targets; () = every live slot
    path: str = ""           # crash_restart snapshot destination ("" = memory)

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} (one of {KINDS})")


class FaultPlan:
    """An immutable, step-indexed schedule of faults."""

    def __init__(self, faults: tuple | list = ()):
        self.faults = tuple(sorted(faults, key=lambda f: (f.step, f.kind)))
        self._by_step: dict[int, list[Fault]] = {}
        for f in self.faults:
            self._by_step.setdefault(f.step, []).append(f)

    def at(self, step: int) -> tuple:
        """Faults firing at ``step`` (deterministic order)."""
        return tuple(self._by_step.get(step, ()))

    def __len__(self) -> int:
        return len(self.faults)

    def __iter__(self):
        return iter(self.faults)

    def __repr__(self) -> str:
        return f"FaultPlan({list(self.faults)!r})"

    @classmethod
    def chaos(
        cls,
        seed: int,
        n_steps: int,
        n_devices: int = 0,
        pressure_pages: int = 0,
        nan_slots: tuple[int, ...] = (),
        straggler_ratio: float = 3.0,
        revive: bool = False,
    ) -> "FaultPlan":
        """Seeded random chaos: one device death (when ``n_devices`` > 1 —
        device 0 is spared so native experts keep a live anchor in tiny
        topologies), one straggler report, one pool-pressure window of
        ``pressure_pages`` pages, and one NaN-logits step on ``nan_slots``.
        With ``revive=True``, the killed device comes back (blank HBM) a
        few steps after its death. Deterministic in ``seed``; the revival
        draw happens after all others, so ``revive=False`` plans are
        byte-identical to pre-revival versions of this helper."""
        rng = np.random.default_rng(seed)
        span = max(n_steps, 8)
        faults = []
        death = None
        if n_devices > 1:
            death = Fault(
                step=int(rng.integers(1, span)),
                kind=DEVICE_DEATH,
                device=int(rng.integers(1, n_devices)),
            )
            faults.append(death)
            faults.append(
                Fault(
                    step=int(rng.integers(1, span)),
                    kind=STRAGGLER,
                    device=int(rng.integers(0, n_devices)),
                    ratio=straggler_ratio,
                )
            )
        if pressure_pages > 0:
            start = int(rng.integers(1, span))
            stop = int(rng.integers(start + 1, start + span))
            faults.append(
                Fault(step=start, kind=POOL_PRESSURE, pages=pressure_pages)
            )
            faults.append(
                Fault(step=stop, kind=POOL_RELEASE, pages=pressure_pages)
            )
        if nan_slots:
            faults.append(
                Fault(
                    step=int(rng.integers(1, span)),
                    kind=NAN_LOGITS,
                    slots=tuple(nan_slots),
                )
            )
        if revive and death is not None:
            faults.append(
                Fault(
                    step=death.step + int(rng.integers(2, max(3, span // 2))),
                    kind=DEVICE_REVIVAL,
                    device=death.device,
                )
            )
        return cls(faults)
