"""Serving runtime: batched decode with the NI-Balancer in the loop.

The ``Server`` owns

* jitted prefill/decode closures (cache donated, placement traced),
* physical expert *slot* weights — ``(L, n_slots, d, f)`` rows, i.e. native
  experts + shadow-slot replicas, slot dim sharded over the model axis,
* the shared :class:`repro.parallel.placement.PlacementTable` — the single
  placement substrate read by the balancer (planning view) and the jitted
  decode step (committed routing view),
* a :class:`repro.core.ni_balancer.BalancerState` fed by the per-step
  expert counts the model emits,
* a :class:`repro.runtime.migration_driver.MigrationDriver` executing
  balancer plans as live stepped migrations,
* the ER-Mapping-derived hop distance used by Algorithm 1.

Every decode step: drain migrations (commit fully-copied replicas at the
step boundary — the atomic routing-table swap — then issue this tick's
weight-row slice copies, overlapped with the step's compute) -> route ->
dispatch -> observe counts -> (Eq. 2 trigger) -> plan with Algorithm 1 ->
submit the plan to the driver. ``ServeConfig(migration_slices=0)`` keeps
the old instantaneous path (synchronous whole-expert copy) as the parity
baseline.

Device failures: ``mark_dead`` aborts/fast-forwards in-flight migration
slices, evacuates orphaned experts (placement table *and* physical weight
rows) and drops the dead device's replicas from the routing table.
Stragglers: per-device step-time EMAs scale heats, draining load away.

Request-level serving (admission, preemption, retirement) lives one layer
up in :mod:`repro.runtime.scheduler`; this module provides the slot-level
substrate it drives (``empty_cache`` / ``prefill_into_slot`` / ``release``
/ ``next_write_unbacked`` / ``drain_migrations``). The full lifecycle is
documented in docs/serving.md.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.ni_balancer import (
    BalancerState,
    evacuate,
    revival_plan,
    should_trigger,
    topology_aware_balance,
)
from repro.models import attention as A
from repro.models import transformer as T
from repro.parallel.collectives import validate_ep_chunks
from repro.parallel.ctx import ParallelCtx
from repro.parallel.placement import PlacementTable
from repro.runtime.migration_driver import MOE_WEIGHTS, MigrationDriver


@dataclasses.dataclass
class ServeConfig:
    max_seq: int = 1024
    batch: int = 8
    slots_per_device: int = 2      # native + shadow capacity per device
    alpha: float = 0.5             # Eq. 2 imbalance threshold
    beta: float = 0.0              # Eq. 2 refractory (0 = non-invasive)
    ema: float = 0.8
    # Live stepped migration: each balancer-planned migration copies its
    # expert's weight rows over this many decode ticks (one slice per tick,
    # floored by the migration's Local/Global hop count), and the routing
    # table swaps atomically only after the last slice lands. 0 = the
    # instantaneous baseline: synchronous whole-expert copy on the decode
    # path (the paper's invasive strawman; kept for parity testing).
    migration_slices: int = 4
    # Paged KV cache: requests share a physical page pool through per-
    # request block tables (attention.paged_cache_init); `pool_pages`
    # oversubscribes the pool vs the dense `batch * ceil(max_seq / page)`
    # worst case — ragged batches then fit where dense caches wouldn't.
    paged: bool = False
    page_size: int = A.PAGE_SIZE
    pool_pages: int | None = None  # None = fully backed (batch * NB)
    # Virtual EP (single process, no mesh): treat the expert slots as if
    # they were spread over this many logical devices, so the NI-Balancer —
    # replica routing, migration, evacuation, straggler draining — runs for
    # real (weight rows move between slot rows, routing tables update);
    # only the inter-device hop is notional (collectives.ep_moe_local).
    # Ignored under a real multi-device mesh (the model axis wins).
    virtual_ep: int | None = None
    # Chunked prefill: admission prefills run as a *lane inside the decode
    # step* — `prefill_chunk` context tokens per tick alongside the live
    # decode batch, so a long prompt never stalls running requests and
    # queued TTFT is bounded by ceil(len / prefill_chunk) ticks. None =
    # the splice-admission path (whole-prompt batch-1 prefill spliced into
    # the cache). Requires paged=True and full (non-windowed) attention;
    # must be a positive multiple of page_size no larger than max_seq.
    prefill_chunk: int | None = None
    # Chunked EP dispatch: split each device's expert groups into this many
    # chunks and pipeline the dispatch/combine all_to_all legs against the
    # fused expert FFN (collectives.ep_moe_shardmap; the virtual-EP local
    # path chunks the grouped FFN the same way). 1 = single-shot dispatch.
    # Must divide the expert-group count: slots_per_device on a mesh,
    # slots_per_device * virtual_ep on the single-process path. Static —
    # baked into the one compiled step program, never a traced switch.
    ep_chunks: int = 1

    def __post_init__(self):
        validate_prefill_chunk(
            self.prefill_chunk, self.page_size, self.max_seq, self.paged
        )
        validate_ep_chunks(self.ep_chunks, where="ServeConfig")
        if self.ep_chunks > 1:
            groups = self.slots_per_device * (self.virtual_ep or 1)
            validate_ep_chunks(
                self.ep_chunks,
                groups,
                where="ServeConfig slots_per_device"
                + (" * virtual_ep" if self.virtual_ep else ""),
            )


def validate_prefill_chunk(
    chunk: int | None, page_size: int, max_seq: int, paged: bool
) -> None:
    """Up-front validation for ``ServeConfig(prefill_chunk=...)``.

    Same convention as ``validate_ep_token_split``: a bad chunk size would
    otherwise surface as an opaque scatter/spec error deep inside the jitted
    step (or silently mis-page the chunk's KV). Fail at construction,
    naming the offending numbers."""
    if chunk is None:
        return
    chunk = int(chunk)
    if chunk <= 0:
        raise ValueError(
            f"ServeConfig: prefill_chunk={chunk} must be a positive number "
            f"of tokens (use prefill_chunk=None for splice admission)"
        )
    if chunk % page_size:
        raise ValueError(
            f"ServeConfig: prefill_chunk={chunk} is not page-size-aligned "
            f"(page_size={page_size}) — each chunk must fill whole KV "
            f"pages so the chunk scatter never straddles an unallocated "
            f"block"
        )
    if chunk > max_seq:
        raise ValueError(
            f"ServeConfig: prefill_chunk={chunk} exceeds max_seq={max_seq} "
            f"— a chunk can never hold more context than one request's KV "
            f"capacity"
        )
    if not paged:
        raise ValueError(
            "ServeConfig: prefill_chunk requires paged=True — the chunk "
            "lane writes KV through a page table (dense caches have no "
            "per-request block mapping to write through)"
        )


# A revived device's HBM is blank (no on-wafer disk); its free slot rows are
# scrubbed with this loud finite sentinel until migration slices overwrite
# them. Finite so inert paths stay exactly zero (an empty expert bucket
# computes FFN(0 @ W) = 0 regardless of W), loud so any routing leak to an
# uncommitted replica explodes the logits instead of silently decoding.
BLANK_WEIGHT = 1e30


class SlotReleaseError(RuntimeError):
    """``Server.release`` of a slot that holds no pages — a double release,
    or a slot that was never admitted. Silently no-opping here (the old
    behaviour) let lifecycle bugs surface much later as stale-table
    corruption; failing at the call site names the culprit."""


class PagePool:
    """Host-side physical-page allocator for the paged KV cache.

    Pages are plain int ids into the pool's leading dim; ``alloc``/``free``
    are O(1) list ops off the jit path (the device-side scatter/gather goes
    through the block *tables*, which reference these ids). Exhaustion
    raises — admission control belongs to the caller.
    """

    def __init__(self, n_pages: int):
        self.n_pages = n_pages
        self._free = list(range(n_pages - 1, -1, -1))
        self._live: set[int] = set()

    @property
    def n_free(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> list[int]:
        if n > len(self._free):
            raise RuntimeError(
                f"page pool exhausted: want {n}, have {len(self._free)} "
                f"of {self.n_pages}"
            )
        pages = [self._free.pop() for _ in range(n)]
        self._live.update(pages)
        return pages

    def free(self, pages) -> None:
        for p in pages:
            if p not in self._live:
                raise ValueError(f"double free of page {p}")
            self._live.discard(p)
            self._free.append(p)


class Server:
    def __init__(
        self,
        cfg: ModelConfig,
        ctx: ParallelCtx,
        params,
        serve_cfg: ServeConfig = ServeConfig(),
        distance=None,
        table: PlacementTable | None = None,
    ):
        self.cfg = cfg
        if serve_cfg.ep_chunks != getattr(ctx, "ep_chunks", 1):
            # Static pipeline depth: the chunk count is baked into the
            # jitted step closures built below (one compiled program, no
            # traced switch), so it must land on the ctx first.
            ctx = dataclasses.replace(ctx, ep_chunks=serve_cfg.ep_chunks)
        self.ctx = ctx
        self.scfg = serve_cfg
        self.params = params
        self.ep = ctx.n_model
        if self.ep == 1 and serve_cfg.virtual_ep:
            self.ep = serve_cfg.virtual_ep
        self.use_balancer = cfg.is_moe and self.ep > 1
        self.distance = distance or (lambda a, b: abs(a - b))
        self.t = 0
        self.last_mig = -(10**9)
        self.migrations = 0

        if self.use_balancer:
            # Slot-expanded weights require EP dispatch everywhere; the
            # "auto" impl would pick ESP when n_experts % ep != 0, and ESP
            # indexes weights by logical expert, not physical slot.
            if ctx.moe_impl == "auto":
                self.ctx = ctx = dataclasses.replace(ctx, moe_impl="ep")
            spd = serve_cfg.slots_per_device
            n_slots = self.ep * spd
            if n_slots < cfg.n_experts:
                raise ValueError("not enough slots for native experts")
            # Expand per-layer expert rows to physical slots. Fresh start:
            # slot s holds expert s % E. Snapshot restore: a saved table
            # dictates the owner of every committed slot, so the restored
            # weights land exactly where the crashed process routed them;
            # free slots fall back to s % E (never routed to).
            if table is not None:
                if (
                    table.n_experts != cfg.n_experts
                    or table.n_slots != n_slots
                    or table.slots_per_device != spd
                ):
                    raise ValueError(
                        f"restored table shape ({table.n_experts} experts, "
                        f"{table.n_slots} slots, {table.slots_per_device} "
                        f"per device) does not match serve config "
                        f"({cfg.n_experts}, {n_slots}, {spd})"
                    )
                owner = table.owner_of_slots()
                rows = np.where(
                    owner >= 0, owner, np.arange(n_slots) % cfg.n_experts
                )
            else:
                rows = np.arange(n_slots) % cfg.n_experts
            for w in MOE_WEIGHTS:
                arr = self.params["layers"]["moe"][w]
                self.params["layers"]["moe"][w] = jnp.take(arr, rows, axis=1)
            # The one placement substrate: expert e natively lives in slot
            # e, i.e. on device e // spd. The balancer plans against it
            # (committed + in-flight view) and the jitted decode routes by
            # its committed device_view — no mirrored tables to diverge.
            self.table = table or PlacementTable.uniform(
                cfg.n_experts, n_slots, spd
            )
            self.state = BalancerState(
                n_experts=cfg.n_experts,
                n_devices=self.ep,
                slots_per_device=spd,
                table=self.table,
                load_ema=np.ones(cfg.n_experts) / cfg.n_experts,
                ema_decay=serve_cfg.ema,
            )
            self.driver = (
                MigrationDriver(
                    self.table, min_slices=serve_cfg.migration_slices
                )
                if serve_cfg.migration_slices > 0
                else None
            )
        else:
            self.table = None
            self.state = None
            self.driver = None

        prefill_kw: dict = {}
        if serve_cfg.paged:
            self.page_size, self.n_blocks = A.paged_layout(
                cfg, serve_cfg.max_seq, serve_cfg.page_size
            )
            backed = serve_cfg.batch * self.n_blocks
            self.n_pool_pages = serve_cfg.pool_pages or backed
            self.page_pool = PagePool(self.n_pool_pages)
            self.trash_page = self.n_pool_pages  # write-off page index
            self._tables = np.full(
                (serve_cfg.batch, self.n_blocks), self.trash_page, np.int32
            )
            self._pages: dict[int, list[int]] = {}
            self._released: set[int] = set()
            self._tables_dirty = False
            # host-side mirror of per-request written counts (lengths): the
            # block-boundary check must not force a device sync per token.
            self._written: np.ndarray | None = None
            # Chunked-prefill ledger: pages/table-row of the (at most one)
            # request mid-prefill, kept OUT of `_pages`/`_tables` until the
            # final chunk lands — `_ensure_pages` and the decode lane must
            # treat the slot as empty (trash table, length 0) while the
            # chunk lane writes its KV through the side row.
            self._prefill_pages: dict[int, list[int]] = {}
            self._prefill_row: dict[int, np.ndarray] = {}
            self.last_chunk_logits = None
            if serve_cfg.prefill_chunk:
                if cfg.sliding_window:
                    raise ValueError(
                        f"prefill_chunk requires full attention: sliding_"
                        f"window={cfg.sliding_window} breaks the chunk "
                        f"lane's slot-j-holds-position-j invariant (the "
                        f"ring remaps logical slots as context wraps)"
                    )
                if serve_cfg.prefill_chunk % self.page_size:
                    raise ValueError(
                        f"prefill_chunk={serve_cfg.prefill_chunk} is not a "
                        f"multiple of the effective page size "
                        f"{self.page_size} (paged_layout shrank it from "
                        f"{serve_cfg.page_size})"
                    )
            prefill_kw = dict(
                paged=True,
                page_size=serve_cfg.page_size,
                n_pages=self.n_pool_pages,
            )
        # host-side mirror of cache["pos"] — the overflow guard must not
        # block on the previous step's device computation every token.
        self._pos: int | None = None
        # donate the *cache* (argnum 2: params, token, cache). Donating the
        # token (the old argnums=(1,)) was an off-by-one: harmless off-mesh
        # (XLA refused it — the recurring "donated buffers were not usable"
        # warning), but under a mesh the donation can be accepted and
        # generate() then concatenates a deleted token array.
        self._decode = jax.jit(
            functools.partial(T.decode_step, cfg=cfg, ctx=ctx),
            donate_argnums=(2,),
        )
        # Chunk operands are tiny host-built metadata; under a mesh they
        # are placed explicitly (replicated — see sharding.chunk_specs) so
        # the fused step never re-triggers layout inference per tick.
        self._chunk_shardings = None
        if serve_cfg.paged and serve_cfg.prefill_chunk and ctx.mesh is not None:
            from repro.parallel.sharding import chunk_specs, to_shardings

            self._chunk_shardings = to_shardings(ctx.mesh, chunk_specs())
        self._prefill = jax.jit(
            functools.partial(
                T.prefill, cfg=cfg, ctx=ctx, max_seq=serve_cfg.max_seq,
                **prefill_kw,
            ),
            static_argnames=(),
        )
        # Slot admission: splice one request's prefilled pool pages into the
        # live batch cache (donates the big pool — no second copy resident).
        self._splice_pages = jax.jit(
            lambda bk, bv, sk, sv, idx: (
                bk.at[:, idx].set(sk[:, idx]),
                bv.at[:, idx].set(sv[:, idx]),
            ),
            donate_argnums=(0, 1),
        )

    # -- placement views -----------------------------------------------------

    @property
    def slot_of(self):
        """Committed routing table (device mirror) — reads through the
        shared PlacementTable; kept as a property for callers that predate
        the unification."""
        return None if self.table is None else self.table.device_view()[0]

    @property
    def n_replicas(self):
        return None if self.table is None else self.table.device_view()[1]

    def _moe(self) -> dict:
        return self.params["layers"]["moe"]

    # -- request lifecycle ---------------------------------------------------

    def _prompt_rows(self, tokens, embeds) -> int:
        """KV rows a prefill writes per request: prompt tokens plus any
        prepended frontend-stub embeddings (see T.prefill)."""
        s = tokens.shape[1]
        if (
            embeds is not None
            and self.cfg.frontend_stub
            and self.cfg.block_pattern != "encdec"
        ):
            s += embeds.shape[1]
        return s

    def prefill(self, tokens, embeds=None, lengths=None):
        """Prime a cache for a batch of prompts.

        Paged mode: allocates each request's blocks from the shared pool
        (``lengths`` marks true per-request prompt lengths for right-padded
        ragged batches — shorter requests hold fewer pages; prepended
        frontend embeds count toward every request). Pages of a previously
        prefilled batch are auto-released."""
        s = self._prompt_rows(tokens, embeds)
        if not self.scfg.paged:
            logits, cache = self._prefill(self.params, tokens, embeds=embeds)
            self._pos = s
            return logits, cache
        b = tokens.shape[0]
        n_embed = s - tokens.shape[1]
        lens = (
            np.full(b, s, np.int32)
            if lengths is None
            else np.asarray(lengths, np.int32) + n_embed
        )
        for slot in list(self._pages):
            self.release(slot)
        for slot in list(self._prefill_pages):
            self.abort_chunk_prefill(slot)
        self._released = set()
        self._tables = np.full((b, self.n_blocks), self.trash_page, np.int32)
        self._tables_dirty = False
        cap = self.n_blocks * self.page_size
        for slot in range(b):
            need = min(-(-int(min(lens[slot], cap)) // self.page_size), self.n_blocks)
            pages = self.page_pool.alloc(need)
            self._pages[slot] = pages
            self._tables[slot, :need] = pages
        logits, cache = self._prefill(
            self.params,
            tokens,
            embeds=embeds,
            tables=jnp.asarray(self._tables),
            lengths=jnp.asarray(lens),
        )
        self._written = lens.copy()
        self._pos = s
        return logits, cache

    def release(self, slot: int, cache: dict | None = None):
        """Free request ``slot``'s pages back to the pool. With ``cache``,
        also clears its table row and length immediately; without it, the
        device tables are refreshed on the next ``decode`` (before any
        write), so the freed pages are never scattered into once they're
        re-allocated. The batch row keeps stepping (its writes land on the
        write-off page and its output is meaningless until re-admitted) —
        ``decode`` pins its length back to 0 each step so it never grows a
        live prefix or new pages.

        Raises :class:`SlotReleaseError` if the slot holds no pages
        (double release / never admitted)."""
        if slot not in self._pages:
            raise SlotReleaseError(
                f"release of slot {slot}, which holds no pages (already "
                f"released, or never admitted)"
            )
        self.page_pool.free(self._pages.pop(slot))
        self._released.add(slot)
        self._tables[slot, :] = self.trash_page
        if self._written is not None:
            self._written[slot] = 0
        if cache is None:
            self._tables_dirty = True
            return None
        layers = dict(cache["layers"])
        layers["tables"] = self._stacked_tables(layers["tables"].shape[0])
        layers["lengths"] = layers["lengths"].at[:, slot].set(0)
        return {**cache, "layers": layers}

    def _stacked_tables(self, n_layers: int):
        return jnp.broadcast_to(
            jnp.asarray(self._tables), (n_layers, *self._tables.shape)
        ).copy()

    # -- slot-level admission (continuous batching substrate) ----------------

    def empty_cache(self) -> dict:
        """A paged cache with every batch slot empty — the starting state
        for slot-level admission (``prefill_into_slot``). All table rows
        point at the write-off page, all lengths are 0, and any previously
        admitted requests' pages go back to the pool."""
        if not self.scfg.paged:
            raise ValueError("empty_cache requires ServeConfig(paged=True)")
        b = self.scfg.batch
        for slot in list(self._pages):
            self.release(slot)
        for slot in list(self._prefill_pages):
            self.abort_chunk_prefill(slot)
        self._released = set(range(b))
        self._tables = np.full((b, self.n_blocks), self.trash_page, np.int32)
        self._tables_dirty = False
        self._written = np.zeros(b, np.int32)
        self._pos = 0
        return T.init_cache(
            self.cfg,
            b,
            self.scfg.max_seq,
            paged=True,
            page_size=self.scfg.page_size,
            n_pages=self.n_pool_pages,
        )

    def prefill_into_slot(self, slot: int, tokens, cache: dict, length=None):
        """Admit one request into batch row ``slot`` of a *live* cache.

        Runs the jitted prefill at batch 1 over this request alone (its
        block table indexes the same shared pool id space), then splices
        the request's pool pages, table row and length into ``cache`` —
        the other batch rows are untouched, so admission happens mid-flight
        without pausing or recomputing live requests.

        ``length`` marks the true prompt length when ``tokens`` is
        right-padded (jit-stable prompt buckets). Returns ``(logits,
        cache)`` with logits at the request's true last prompt position
        (``(1, 1, vocab)``).
        """
        if not self.scfg.paged:
            raise ValueError("prefill_into_slot requires ServeConfig(paged=True)")
        if slot in self._pages:
            raise RuntimeError(
                f"slot {slot} is still admitted; release it before reuse"
            )
        if self._written is None:
            self._written = np.zeros(self.scfg.batch, np.int32)
        tokens = jnp.asarray(tokens, jnp.int32).reshape(1, -1)
        true_len = int(length if length is not None else tokens.shape[1])
        cap = self.n_blocks * self.page_size
        need = min(-(-min(true_len, cap) // self.page_size), self.n_blocks)
        pages = self.page_pool.alloc(need)
        row = np.full((1, self.n_blocks), self.trash_page, np.int32)
        row[0, :need] = pages
        logits, small = self._prefill(
            self.params,
            tokens,
            embeds=None,
            tables=jnp.asarray(row),
            lengths=jnp.asarray([true_len], np.int32),
        )
        self._pages[slot] = pages
        self._tables[slot] = row[0]
        self._released.discard(slot)
        self._written[slot] = true_len
        self._tables_dirty = False
        layers = dict(cache["layers"])
        if need:
            idx = jnp.asarray(pages)
            layers["pool_k"], layers["pool_v"] = self._splice_pages(
                layers["pool_k"],
                layers["pool_v"],
                small["layers"]["pool_k"],
                small["layers"]["pool_v"],
                idx,
            )
        layers["tables"] = self._stacked_tables(layers["tables"].shape[0])
        layers["lengths"] = layers["lengths"].at[:, slot].set(true_len)
        return logits, {**cache, "layers": layers}

    # -- chunked prefill (the admission lane inside the decode step) ---------

    def begin_chunk_prefill(self, slot: int, length: int) -> None:
        """Start a chunked admission into batch row ``slot``: allocate every
        page ``length`` context rows will need, into a *side* ledger. The
        live cache is untouched — the slot's device table row stays at the
        write-off page and its length stays 0 for the whole prefill, so the
        decode lane's masked write for this row keeps landing on the trash
        page instead of corrupting the chunk's real position-0 KV. The
        chunk lane writes through the side row (``chunk_operand``);
        ``finish_chunk_prefill`` splices the mapping in atomically when the
        last chunk lands."""
        if not self.scfg.prefill_chunk:
            raise ValueError(
                "begin_chunk_prefill requires ServeConfig(prefill_chunk=N)"
            )
        if slot in self._pages or slot in self._prefill_pages:
            raise RuntimeError(
                f"slot {slot} is still admitted or mid-prefill; release or "
                f"abort it before reuse"
            )
        cap = self.n_blocks * self.page_size
        need = min(-(-min(int(length), cap) // self.page_size), self.n_blocks)
        pages = self.page_pool.alloc(need)
        row = np.full(self.n_blocks, self.trash_page, np.int32)
        row[:need] = pages
        self._prefill_pages[slot] = pages
        self._prefill_row[slot] = row

    def chunk_operand(self, slot: int, tokens, start: int, length: int) -> dict:
        """Build the decode step's prefill-lane operand for one chunk of the
        request mid-prefill in ``slot``. ``tokens`` is the fixed-size
        ``(prefill_chunk,)`` buffer (right-padded past ``length``);
        ``start`` is the request's prefill progress (absolute position of
        ``tokens[0]``)."""
        if slot not in self._prefill_row:
            raise RuntimeError(
                f"slot {slot} has no chunked prefill in flight "
                f"(begin_chunk_prefill first)"
            )
        tokens = np.asarray(tokens, np.int32).reshape(1, -1)
        if tokens.shape[1] != self.scfg.prefill_chunk:
            raise ValueError(
                f"chunk_operand: got {tokens.shape[1]} tokens, want exactly "
                f"prefill_chunk={self.scfg.prefill_chunk} (right-pad past "
                f"`length` — the shape is jit-stable)"
            )
        return {
            "tokens": jnp.asarray(tokens),
            "table": jnp.asarray(self._prefill_row[slot]),
            "start": jnp.asarray(int(start), jnp.int32),
            "length": jnp.asarray(int(length), jnp.int32),
        }

    def noop_chunk(self) -> dict:
        """The idle prefill-lane operand (length 0, all-trash table): padded
        rows write to the write-off page and route nowhere, so ticks with no
        admission in flight reuse the exact same compiled program."""
        return {
            "tokens": jnp.zeros((1, self.scfg.prefill_chunk), jnp.int32),
            "table": jnp.full((self.n_blocks,), self.trash_page, jnp.int32),
            "start": jnp.zeros((), jnp.int32),
            "length": jnp.zeros((), jnp.int32),
        }

    def finish_chunk_prefill(self, slot: int, cache: dict, length: int) -> dict:
        """The final chunk landed: atomically flip ``slot`` live. The
        chunk lane already wrote every KV row into the pool through the
        side table, so this is pure mapping surgery — move the pages into
        the live ledger and splice the table row + true length into the
        device cache (the exact splice ``prefill_into_slot`` does, minus
        the pool copy it needed for its separate batch-1 cache)."""
        if slot not in self._prefill_pages:
            raise RuntimeError(
                f"slot {slot} has no chunked prefill in flight"
            )
        if self._written is None:
            self._written = np.zeros(self.scfg.batch, np.int32)
        self._pages[slot] = self._prefill_pages.pop(slot)
        self._tables[slot] = self._prefill_row.pop(slot)
        self._released.discard(slot)
        self._written[slot] = int(length)
        self._tables_dirty = False
        layers = dict(cache["layers"])
        layers["tables"] = self._stacked_tables(layers["tables"].shape[0])
        layers["lengths"] = layers["lengths"].at[:, slot].set(int(length))
        return {**cache, "layers": layers}

    def abort_chunk_prefill(self, slot: int) -> None:
        """Tear down a mid-prefill admission (preemption, device pressure,
        crash recovery): free the side pages back to the pool. Nothing was
        ever spliced into the live cache, so there is no device state to
        undo — the half-written pool pages are unreachable once freed and
        get overwritten by their next owner."""
        if slot not in self._prefill_pages:
            raise SlotReleaseError(
                f"abort_chunk_prefill of slot {slot}, which has no chunked "
                f"prefill in flight"
            )
        self.page_pool.free(self._prefill_pages.pop(slot))
        del self._prefill_row[slot]

    def next_write_unbacked(self, slot: int) -> bool:
        """Would this request's next decode write need a fresh pool page
        (its block table doesn't back the target block yet)? The scheduler
        sums this over live slots to preempt *before* ``_ensure_pages``
        would hit pool exhaustion mid-step."""
        cap = self.n_blocks * self.page_size
        written = int(self._written[slot])
        if self.cfg.sliding_window:
            nxt = written % cap
        else:
            nxt = min(written, cap - 1)
        return bool(self._tables[slot, nxt // self.page_size] == self.trash_page)

    def _ensure_pages(self, cache: dict) -> dict:
        """Allocate the page a request's next write lands on, if its block
        table doesn't back it yet (lazy per-request growth at block
        boundaries). Both the boundary check (host mirror ``_written``) and
        the alloc are host-side — no per-token device sync on the hot path."""
        layers = cache["layers"]
        if self._written is None:
            # cache primed outside this Server (e.g. T.prefill directly):
            # sync the mirror once, then track host-side. No pages to grow
            # (this Server's allocator doesn't own that cache's mapping).
            self._written = np.asarray(layers["lengths"][0]).copy()
        cap = self.n_blocks * self.page_size
        w = self.cfg.sliding_window or 0
        changed = self._tables_dirty   # release(slot) without a cache handle
        self._tables_dirty = False
        for slot in self._pages:
            if self.next_write_unbacked(slot):
                written = int(self._written[slot])
                nxt = written % cap if w else min(written, cap - 1)
                (page,) = self.page_pool.alloc(1)
                self._pages[slot].append(page)
                self._tables[slot, nxt // self.page_size] = page
                changed = True
        if not changed:
            return cache
        layers = dict(layers)
        layers["tables"] = self._stacked_tables(layers["tables"].shape[0])
        return {**cache, "layers": layers}

    def decode(self, token, cache, chunk: dict | None = None):
        """One fused step. With ``ServeConfig(prefill_chunk=N)`` a chunk
        operand is ALWAYS passed to the jitted step — ``chunk=None`` here
        substitutes the no-op chunk — so idle, decode-only and decode+chunk
        ticks compile to one program per shape."""
        if self.scfg.prefill_chunk:
            if chunk is None:
                chunk = self.noop_chunk()
            if self._chunk_shardings is not None:
                chunk = jax.device_put(chunk, self._chunk_shardings)
        elif chunk is not None:
            raise ValueError(
                "decode(chunk=...) requires ServeConfig(prefill_chunk=N)"
            )
        if self._pos is None:   # cache primed outside this Server
            self._pos = int(cache["pos"])
        pos = self._pos
        windowed = bool(self.cfg.sliding_window or 0)
        if self.scfg.paged:
            cache = self._ensure_pages(cache)   # also syncs _written
            if not windowed:
                # Per-request occupancy: a ragged batch keeps serving as
                # long as every *live* request has headroom (releasing a
                # finished request really does restore capacity).
                cap = self.n_blocks * self.page_size
                live = self._pages or range(len(self._written))
                full = [s for s in live if self._written[s] >= cap]
                if full:
                    raise RuntimeError(
                        f"decode past capacity={cap} for request(s) {full} "
                        f"(cache full): release them or raise max_seq"
                    )
        elif not windowed and pos >= self.scfg.max_seq:
            # Dense caches used to clobber the last slot silently here;
            # both layouts now freeze at capacity and serving refuses.
            raise RuntimeError(
                f"decode past max_seq={self.scfg.max_seq} (cache full, "
                f"pos={pos}): release the request or raise max_seq"
            )
        if self.use_balancer:
            # Step boundary: commit migrations whose last slice landed (the
            # atomic routing-table swap), then issue this tick's weight
            # slices — dispatched before the step so the copy overlaps the
            # decode compute below.
            self.drain_migrations()
        placement = self.table.device_view() if self.use_balancer else None
        slot_mask = None
        if self.scfg.paged:
            # Continuous batching: released/empty rows still step (fixed
            # shapes) but are masked out of MoE routing so they never spend
            # expert bucket capacity or skew the balancer's counts. Always
            # an array (all-live when nothing is released): were it None on
            # full batches, the mask's appearance after the first retire
            # would change the step's pytree structure and force a second
            # compile — one program must serve idle, decode-only and
            # decode+chunk ticks alike.
            live = np.ones(token.shape[0], bool)
            live[sorted(self._released)] = False
            slot_mask = jnp.asarray(live)
        logits, cache, stats = self._decode(
            self.params, token, cache, placement=placement,
            slot_mask=slot_mask, chunk=chunk,
        )
        # Chunk-lane logits (last valid chunk position): on the final chunk
        # of an admission these emit the request's first token. Host mirror
        # — the scheduler reads it right after the step it drove.
        self.last_chunk_logits = stats.get("chunk_logits")
        if self.scfg.paged and self._written is not None:
            for slot in range(len(self._written)):
                if slot not in self._released:
                    self._written[slot] += 1
            if self._released:
                # keep released rows inert: the model step incremented their
                # length past 0, which would grow a live prefix over the
                # write-off page — pin it back down.
                lengths = cache["layers"]["lengths"]
                idx = jnp.asarray(sorted(self._released))
                cache = {
                    **cache,
                    "layers": {
                        **cache["layers"],
                        "lengths": lengths.at[:, idx].set(0),
                    },
                }
        self._pos = pos + 1
        self.t += 1
        if self.use_balancer:
            counts = np.asarray(stats["expert_counts"])
            self.state.observe(counts)
            self._maybe_balance(counts)
        return logits, cache

    def generate(self, prompt, n_tokens: int, embeds=None):
        logits, cache = self.prefill(prompt, embeds=embeds)
        out = []
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        for _ in range(n_tokens):
            out.append(tok)
            logits, cache = self.decode(tok, cache)
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return jnp.concatenate(out, axis=1)

    # -- balancing -----------------------------------------------------------

    def _maybe_balance(self, counts):
        if not should_trigger(
            [counts], self.scfg.alpha, self.t - self.last_mig, self.scfg.beta
        ):
            return
        plan = topology_aware_balance(self.state, self.distance)
        if not plan:
            return
        self.last_mig = self.t
        self.apply_plan(plan)

    def apply_plan(self, plan) -> int:
        """Execute a balancer plan ``[(expert, src, dst), ...]`` through
        the configured migration path — the one public entry point for
        placement changes (balancing, ops drills, revival seeding).

        With a driver (``migration_slices > 0``): reserve destination
        slots now; slices are issued one per decode tick by
        ``drain_migrations`` and ``self.migrations`` counts commits (the
        atomic table swaps). Returns the number of migrations accepted.
        Without a driver: synchronous whole-expert copies, applied (and
        counted) immediately."""
        if not plan:
            return 0
        if self.driver is None:
            applied = sum(self._apply_migration(mig) for mig in plan)
            self.migrations += applied
            return applied
        return len(self.driver.submit(plan, self._moe(), self.t))

    def drain_migrations(self) -> int:
        """Advance in-flight stepped migrations by one tick: commit the
        fully-copied ones (routing-table swap at a step boundary), then
        issue one weight-row slice for the rest. ``decode`` calls this at
        the top of every step; the scheduler calls it on idle ticks so
        migrations keep landing while no request is decodable. Returns the
        number of migrations committed this tick."""
        if self.driver is None:
            return 0
        committed = self.driver.tick(self._moe(), self.t)
        self.migrations += len(committed)
        return len(committed)

    def _copy_expert_rows(self, src_slot: int, dst_slot: int) -> None:
        """Whole-expert row copy (the instantaneous/fast-forward path; the
        stepped hot path copies per-tick slices in the driver instead)."""
        moe = self._moe()
        for w in MOE_WEIGHTS:
            moe[w] = moe[w].at[:, dst_slot].set(moe[w][:, src_slot])

    def _apply_migration(self, mig) -> bool:
        """Replicate expert ``e`` onto a free slot of device ``dst``,
        instantaneously. Returns True iff the migration was physically
        applied; a no-op (no free slot, or the expert is at its replica
        cap) leaves the table untouched — the reserve/commit pair cannot
        apply the routing half without the data movement or vice versa
        (the old split-table behaviour at the cap overwrote
        ``slot_of[e, -1]`` and leaked the previous replica's slot from the
        free-slot accounting forever)."""
        e, _src, dst = mig
        slot = self.table.try_reserve(e, dst)
        if slot is None:
            return False
        # Data movement: copy the expert's weight rows into the shadow slot
        # (a device-to-device transfer under the slot sharding).
        self._copy_expert_rows(int(self.table.slot_of[e, 0]), slot)
        self.table.commit(e, slot)
        return True

    # -- fault tolerance ------------------------------------------------------

    def _retarget(self, dead: int, mig):
        """Replacement for a migration aborted by ``dead``'s death: same
        expert, re-sourced from a live committed replica, aimed at the
        nearest live device with a free slot that doesn't already host (or
        expect) the expert. None when no such device exists."""
        e, _src, _dst = mig
        src = next(
            (
                d
                for d in self.table.replica_devices(e, include_pending=False)
                if d != dead and d not in self.state.dead
            ),
            None,
        )
        if src is None:
            return None            # evacuation will recreate the expert
        cand = [
            d
            for d in range(self.ep)
            if d != dead
            and d not in self.state.dead
            and d not in self.table.replica_devices(e)
            and self.table.free_slot(d) is not None
        ]
        if not cand:
            return None
        return (e, src, min(cand, key=lambda d: self.distance(src, d)))

    def mark_dead(self, device: int) -> list:
        """Node failure — the full evacuation path:

        1. in-flight stepped migrations touching the device are resolved
           first: slices headed *to* it abort (reservation released, then
           requeued toward a live destination from slice zero), slices
           sourced *from* it fast-forward to completion — either way no
           torn replica is ever committed;
        2. ``evacuate`` pins the device's heat to infinity and commits
           (table-side) a replica for every expert whose only live copy
           sat on the dead device;
        3. each evacuation entry's weight rows are copied whole
           (fast-forward — availability beats overlap here). The rows are
           read from the dead device's slot — valid in this logical
           simulation, where "death" means the scheduler stops routing to
           the device but its HBM is still addressable; a real wafer die
           failure would restore the rows from checkpoint shards instead;
        4. the dead device's replicas drop out of the shared table's
           routing view, so no token copy is dispatched to it again.

        Returns the evacuation plan (list of ``(expert, src, dst)``).
        """
        if self.state is None:
            return []
        if self.driver is not None:
            self.driver.handle_device_death(
                device,
                self._moe(),
                self.t,
                retarget=functools.partial(self._retarget, device),
            )
        plan = evacuate(self.state, device, self.distance)
        for e, _src, dst in plan:
            # Orphan source: usually the dying device's slot; under repeated
            # failures the sole copy may sit on an earlier-dead device, so
            # fall back to the native column (0 — commit appends after it).
            src_slot = self.table.slot_on_device(e, device)
            if src_slot is None:
                src_slot = int(self.table.slot_of[e, 0])
            dst_slot = self.table.slot_on_device(e, dst)
            self._copy_expert_rows(src_slot, dst_slot)
        self.table.drop_device(device)
        return plan

    def revive(self, device: int) -> list:
        """Device revival — re-admit a repaired device with *blank* HBM
        (wafer-scale chips have no on-wafer disk; everything it held died
        with it):

        1. the balancer forgets the death (finite heat, straggler penalty
           reset) so planning may target the device again;
        2. the device's free slot rows are scrubbed with ``BLANK_WEIGHT``
           — any premature route to an uncommitted replica now explodes
           instead of silently decoding stale weights. Slots still
           committed there (sole-copy orphans left by a failed evacuation)
           are spared: they are all the routing view has for that expert;
        3. :func:`~repro.core.ni_balancer.revival_plan` seeds the blank
           slots with the hottest per-replica experts from their nearest
           live hosts, and the plan goes through ``apply_plan`` — i.e. the
           stepped MigrationDriver when configured, so copies overlap
           decode ticks and routing only references the device once each
           replica's last slice commits. A second death mid-revival rides
           the driver's existing abort/fast-forward handling.

        After the seeded replicas commit, ``_maybe_balance`` sees the
        device's (low) heat and rebalances onto it naturally. Returns the
        revival plan."""
        if self.state is None:
            raise ValueError("revive requires the balancer serving path")
        device = int(device)
        if not 0 <= device < self.ep:
            raise ValueError(
                f"revive: device {device} is outside the EP axis "
                f"(want 0 <= device < {self.ep})"
            )
        if device not in self.state.dead:
            raise ValueError(f"revive: device {device} is not dead")
        self.state.revive(device)
        spd = self.table.slots_per_device
        used = self.table.used_slots()
        blank = [
            s
            for s in range(device * spd, (device + 1) * spd)
            if not used[s]
        ]
        if blank:
            moe = self._moe()
            idx = jnp.asarray(blank)
            for w in MOE_WEIGHTS:
                moe[w] = moe[w].at[:, idx].set(BLANK_WEIGHT)
        plan = revival_plan(self.state, device, self.distance)
        self.apply_plan(plan)
        return plan

    # -- crash-safe snapshot/restore ------------------------------------------

    @classmethod
    def restore_snapshot(
        cls, snap, cfg: ModelConfig, ctx: ParallelCtx, params, distance=None
    ):
        """Rebuild a live ``Server`` on a fresh process from a
        :class:`~repro.runtime.snapshot.ServerSnapshot` plus the params
        checkpoint (``params`` holds *logical* expert rows, exactly as a
        fresh ``__init__`` expects — the snapshot deliberately excludes
        weights). Expert rows are re-placed per the saved committed table,
        balancer truth (load EMA, dead set, slowdowns) is restored, and the
        pending-migration ledger is re-submitted from slice zero — partial
        slices died with the old process's HBM, and re-copying is
        idempotent because nothing routes to a reservation until commit."""
        scfg = ServeConfig(**snap.serve_cfg)
        table = None
        if snap.table is not None:
            table = PlacementTable(
                n_experts=cfg.n_experts,
                n_slots=int(snap.table["n_slots"]),
                slots_per_device=int(snap.table["slots_per_device"]),
                slot_of=snap.table["slot_of"],
                n_replicas=snap.table["n_replicas"],
            )
        srv = cls(cfg, ctx, params, scfg, distance=distance, table=table)
        srv.t = int(snap.t)
        srv.last_mig = int(snap.last_mig)
        srv.migrations = int(snap.migrations)
        if srv.state is not None:
            srv.state.load_ema = np.asarray(snap.load_ema, float).copy()
            srv.state.dead = set(int(d) for d in snap.dead)
            srv.state.slowdown = (
                None
                if snap.slowdown is None
                else np.asarray(snap.slowdown, float).copy()
            )
            if srv.driver is not None and snap.pending_migrations:
                srv.driver.submit(
                    [tuple(m["mig"]) for m in snap.pending_migrations],
                    srv._moe(),
                    srv.t,
                )
        return srv

    def report_step_time(self, device: int, ratio: float):
        """Straggler mitigation: fold measured step-time ratio into heats.

        Validates its inputs the way ``validate_ep_token_split`` does —
        the old silent acceptance let an out-of-range device id grow the
        slowdown array past the EP axis and a negative ratio drive a
        device's heat below zero, both corrupting Algorithm 1's ordering
        long after the bad report."""
        if self.state is None:
            return
        device = int(device)
        if not 0 <= device < self.ep:
            raise ValueError(
                f"report_step_time: device {device} is outside the EP axis "
                f"(want 0 <= device < {self.ep})"
            )
        ratio = float(ratio)
        if not np.isfinite(ratio) or ratio <= 0:
            raise ValueError(
                f"report_step_time: ratio {ratio} must be a finite positive "
                f"step-time ratio (measured / median); a non-positive EMA "
                f"would corrupt the balancer's heat ordering"
            )
        if self.state.slowdown is None:
            self.state.slowdown = np.ones(self.ep)
        self.state.slowdown[device] = (
            0.8 * self.state.slowdown[device] + 0.2 * ratio
        )
