"""Serving runtime: batched decode with the NI-Balancer in the loop.

The ``Server`` owns

* jitted prefill/decode closures (cache donated, placement traced),
* physical expert *slot* weights — ``(L, n_slots, d, f)`` rows, i.e. native
  experts + shadow-slot replicas, slot dim sharded over the model axis,
* a :class:`repro.core.ni_balancer.BalancerState` fed by the per-step
  expert counts the model emits,
* the ER-Mapping-derived hop distance used by Algorithm 1.

Every decode step: route -> dispatch -> observe counts -> (Eq. 2 trigger)
-> plan with Algorithm 1 -> apply placement (slot table update + expert
weight row copy = the migration's data movement; its *schedule* across cold
links is validated in the analytical evaluator — see DESIGN.md §3).

Device failures: ``mark_dead`` pins the device's heat to infinity, so the
next balancing pass evacuates its experts to shadow slots elsewhere.
Stragglers: per-device step-time EMAs scale heats, draining load away.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.ni_balancer import (
    BalancerState,
    should_trigger,
    topology_aware_balance,
)
from repro.models import transformer as T
from repro.parallel.collectives import uniform_placement
from repro.parallel.ctx import ParallelCtx


@dataclasses.dataclass
class ServeConfig:
    max_seq: int = 1024
    batch: int = 8
    slots_per_device: int = 2      # native + shadow capacity per device
    alpha: float = 0.5             # Eq. 2 imbalance threshold
    beta: float = 0.0              # Eq. 2 refractory (0 = non-invasive)
    ema: float = 0.8


class Server:
    def __init__(
        self,
        cfg: ModelConfig,
        ctx: ParallelCtx,
        params,
        serve_cfg: ServeConfig = ServeConfig(),
        distance=None,
    ):
        self.cfg = cfg
        self.ctx = ctx
        self.scfg = serve_cfg
        self.params = params
        self.ep = ctx.n_model
        self.use_balancer = cfg.is_moe and self.ep > 1
        self.distance = distance or (lambda a, b: abs(a - b))
        self.t = 0
        self.last_mig = -(10**9)
        self.migrations = 0

        if self.use_balancer:
            # Slot-expanded weights require EP dispatch everywhere; the
            # "auto" impl would pick ESP when n_experts % ep != 0, and ESP
            # indexes weights by logical expert, not physical slot.
            if ctx.moe_impl == "auto":
                self.ctx = ctx = dataclasses.replace(ctx, moe_impl="ep")
            spd = serve_cfg.slots_per_device
            n_slots = self.ep * spd
            if n_slots < cfg.n_experts:
                raise ValueError("not enough slots for native experts")
            # Expand per-layer expert rows to physical slots (slot s holds
            # expert s % E initially).
            rows = np.arange(n_slots) % cfg.n_experts
            for w in ("w_gate", "w_up", "w_down"):
                arr = self.params["layers"]["moe"][w]
                self.params["layers"]["moe"][w] = jnp.take(arr, rows, axis=1)
            self.slot_of, self.n_replicas = uniform_placement(
                cfg.n_experts, n_slots
            )
            # Expert e natively lives in slot e, i.e. on device e // spd —
            # the balancer state must mirror the physical slot layout.
            self.state = BalancerState(
                n_experts=cfg.n_experts,
                n_devices=self.ep,
                slots_per_device=spd,
                replicas=[[e // spd] for e in range(cfg.n_experts)],
                load_ema=np.ones(cfg.n_experts) / cfg.n_experts,
                ema_decay=serve_cfg.ema,
            )
        else:
            self.slot_of = self.n_replicas = None
            self.state = None

        self._decode = jax.jit(
            functools.partial(T.decode_step, cfg=cfg, ctx=ctx),
            donate_argnums=(1,),
        )
        self._prefill = jax.jit(
            functools.partial(
                T.prefill, cfg=cfg, ctx=ctx, max_seq=serve_cfg.max_seq
            ),
            static_argnames=(),
        )

    # -- request lifecycle ---------------------------------------------------

    def prefill(self, tokens, embeds=None):
        logits, cache = self._prefill(self.params, tokens, embeds=embeds)
        return logits, cache

    def decode(self, token, cache):
        placement = (
            (self.slot_of, self.n_replicas) if self.use_balancer else None
        )
        logits, cache, stats = self._decode(
            self.params, token, cache, placement=placement
        )
        self.t += 1
        if self.use_balancer:
            counts = np.asarray(stats["expert_counts"])
            self.state.observe(counts)
            self._maybe_balance(counts)
        return logits, cache

    def generate(self, prompt, n_tokens: int, embeds=None):
        logits, cache = self.prefill(prompt, embeds=embeds)
        out = []
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        for _ in range(n_tokens):
            out.append(tok)
            logits, cache = self.decode(tok, cache)
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return jnp.concatenate(out, axis=1)

    # -- balancing -----------------------------------------------------------

    def _maybe_balance(self, counts):
        if not should_trigger(
            [counts], self.scfg.alpha, self.t - self.last_mig, self.scfg.beta
        ):
            return
        plan = topology_aware_balance(self.state, self.distance)
        if not plan:
            return
        self.last_mig = self.t
        for mig in plan:
            self._apply_migration(mig)
        self.migrations += len(plan)

    def _free_slot(self, device: int) -> int | None:
        spd = self.scfg.slots_per_device
        used = set()
        slot_of = np.asarray(self.slot_of)
        n_rep = np.asarray(self.n_replicas)
        for e in range(self.cfg.n_experts):
            for r in range(n_rep[e]):
                used.add(int(slot_of[e, r]))
        for s in range(device * spd, (device + 1) * spd):
            if s not in used:
                return s
        return None

    def _apply_migration(self, mig, update_state: bool = True):
        e, _src, dst = mig
        slot = self._free_slot(dst)
        if slot is None:
            return
        # Data movement: copy the expert's weight rows into the shadow slot
        # (a device-to-device transfer under the slot sharding).
        src_slot = int(np.asarray(self.slot_of)[e, 0])
        moe = self.params["layers"]["moe"]
        for w in ("w_gate", "w_up", "w_down"):
            moe[w] = moe[w].at[:, slot].set(moe[w][:, src_slot])
        r = int(np.asarray(self.n_replicas)[e])
        self.slot_of = self.slot_of.at[e, min(r, self.slot_of.shape[1] - 1)].set(slot)
        self.n_replicas = self.n_replicas.at[e].set(
            min(r + 1, self.slot_of.shape[1])
        )
        if update_state:
            self.state.apply(mig)

    def _mirror_migration(self, mig):
        """Physical half only — for plans already applied to the balancer
        state (e.g. evacuation)."""
        self._apply_migration(mig, update_state=False)

    # -- fault tolerance ------------------------------------------------------

    def mark_dead(self, device: int):
        """Node failure: evacuate by rebalancing away from the dead device."""
        if self.state is not None:
            self.state.mark_dead(device)

    def report_step_time(self, device: int, ratio: float):
        """Straggler mitigation: fold measured step-time ratio into heats."""
        if self.state is None:
            return
        if self.state.slowdown is None:
            self.state.slowdown = np.ones(self.ep)
        self.state.slowdown[device] = (
            0.8 * self.state.slowdown[device] + 0.2 * ratio
        )
