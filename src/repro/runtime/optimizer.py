"""AdamW + cosine schedule + global-norm clipping, in pure JAX.

(optax is not available in this environment; this is the standard
implementation, pytree-generic, with bf16-safe fp32 moments.)
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def cosine_lr(cfg: AdamWConfig) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = cfg.lr * jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
        t = jnp.clip(
            (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
            0.0,
            1.0,
        )
        cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)

    return lr


def adamw_init(params) -> dict:
    zeros = lambda p: jax.tree.map(lambda x: jnp.zeros_like(x, dtype=jnp.float32), p)
    return {"step": jnp.zeros((), jnp.int32), "mu": zeros(params), "nu": zeros(params)}


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def adamw_update(grads, opt_state, params, cfg: AdamWConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = cosine_lr(cfg)(step)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_g, tdef = jax.tree.flatten(grads)
    flat_m = tdef.flatten_up_to(opt_state["mu"])
    flat_v = tdef.flatten_up_to(opt_state["nu"])
    flat_p = tdef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"step": step, "mu": new_m, "nu": new_v}, metrics
