"""Crash-safe serving snapshots: minimal host-side truth, atomic on disk.

A :class:`ServerSnapshot` captures everything a fresh process needs to
resume serving **bit-identically** after a host crash — and nothing more:

* the committed :class:`~repro.parallel.placement.PlacementTable` (routing
  truth) and the balancer's load EMA / dead set / straggler slowdowns,
* the pending-migration ledger (plan entries only — partial weight slices
  died with the crashed process's HBM and are re-copied from slice zero),
* the scheduler's request book: per-request prompt + emitted prefix +
  scalar lifecycle fields, queue order, live-slot occupancy, counters,
* pool-pressure hostage page count.

Deliberately **not** snapshotted:

* expert weights and KV pages — device state. Weights are re-placed from
  the params checkpoint per the saved table (``Server.restore_snapshot``);
  KV is recomputed from prompt + emitted prefix on re-admission, the same
  recompute contract preemption already relies on. A recompute prefill's
  last-position logits emit exactly the token the crashed decode would
  have produced next, so the concatenated pre/post-crash streams equal an
  uninterrupted run's.
* sampler RNG — decoding is greedy argmax; there is no sampler state. (A
  future stochastic sampler must add its per-request RNG cursor here.)
* jit caches, events, bench counters — observability, not truth.

Persistence rides :func:`repro.runtime.checkpoint.save`: numeric leaves go
in the atomic ``.npz``, JSON-able structure in the atomic ``.meta``
sidecar, so a crash *during* snapshotting leaves the previous snapshot
intact (and ``CheckpointManager.steps`` skips the torn half-write).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.runtime import faults as F
from repro.runtime.checkpoint import load_meta, save
from repro.runtime.serve import Server

SNAPSHOT_VERSION = 1


@dataclasses.dataclass
class ServerSnapshot:
    """End-of-tick serving state (see module docstring for scope)."""

    step_no: int
    serve_cfg: dict
    sched_cfg: dict
    # server counters
    t: int
    last_mig: int
    migrations: int
    # placement + balancer (None on dense / balancer-less servers)
    table: dict | None
    load_ema: np.ndarray | None
    slowdown: np.ndarray | None
    dead: list[int]
    pending_migrations: list[dict]
    # scheduler request book
    next_rid: int
    n_preempted: int
    hostage_pages: int
    requests: list[dict]
    prompts: dict[int, np.ndarray]
    emitted: dict[int, np.ndarray]
    queue_rids: list[int]
    live_rids: list[int | None]


def snapshot_scheduler(sched) -> ServerSnapshot:
    """Capture a scheduler (and its server) at a tick boundary."""
    srv = sched.server
    table = None
    load_ema = slowdown = None
    dead: list[int] = []
    pending: list[dict] = []
    if srv.table is not None:
        table = {
            "slot_of": srv.table.slot_of.copy(),
            "n_replicas": srv.table.n_replicas.copy(),
            "n_slots": srv.table.n_slots,
            "slots_per_device": srv.table.slots_per_device,
        }
        load_ema = np.asarray(srv.state.load_ema).copy()
        slowdown = (
            None
            if srv.state.slowdown is None
            else np.asarray(srv.state.slowdown).copy()
        )
        dead = sorted(int(d) for d in srv.state.dead)
        if srv.driver is not None:
            pending = srv.driver.export_in_flight()
    requests = []
    prompts: dict[int, np.ndarray] = {}
    emitted: dict[int, np.ndarray] = {}
    for r in sched.requests:
        requests.append(
            {
                "rid": int(r.rid),
                "max_new_tokens": int(r.max_new_tokens),
                "eos_id": None if r.eos_id is None else int(r.eos_id),
                "arrival": int(r.arrival),
                "state": r.state,
                "preemptions": int(r.preemptions),
                "error": r.error,
                # Chunked-admission progress + serving stats. `prefill_pos`
                # is informational only: the chunk KV died with the crashed
                # process, so restore requeues the request and re-prefills
                # from chunk zero regardless.
                "prefill_pos": int(r.prefill_pos),
                "admitted_step": r.admitted_step,
                "first_token_step": r.first_token_step,
                "last_token_step": r.last_token_step,
                "max_stall": int(r.max_stall),
            }
        )
        prompts[r.rid] = np.asarray(r.prompt, np.int32).copy()
        emitted[r.rid] = np.asarray(r.tokens_out, np.int32)
    return ServerSnapshot(
        step_no=int(sched.step_no),
        serve_cfg=dataclasses.asdict(srv.scfg),
        sched_cfg=dataclasses.asdict(sched.cfg),
        t=int(srv.t),
        last_mig=int(srv.last_mig),
        migrations=int(srv.migrations),
        table=table,
        load_ema=load_ema,
        slowdown=slowdown,
        dead=dead,
        pending_migrations=pending,
        next_rid=int(sched._rid),
        n_preempted=int(sched.n_preempted),
        hostage_pages=len(sched._hostage),
        requests=requests,
        prompts=prompts,
        emitted=emitted,
        queue_rids=[int(r.rid) for r in sched.queue],
        live_rids=[None if r is None else int(r.rid) for r in sched.slots],
    )


def save_snapshot(path: str, snap: ServerSnapshot) -> None:
    """Persist atomically: arrays in the ``.npz``, structure in ``.meta``."""
    tree: dict[str, np.ndarray] = {}
    if snap.table is not None:
        tree["table/slot_of"] = snap.table["slot_of"]
        tree["table/n_replicas"] = snap.table["n_replicas"]
        tree["balancer/load_ema"] = snap.load_ema
        if snap.slowdown is not None:
            tree["balancer/slowdown"] = snap.slowdown
    for rid, p in snap.prompts.items():
        tree[f"prompt/{rid}"] = p
    for rid, e in snap.emitted.items():
        tree[f"emitted/{rid}"] = e
    meta = {
        "version": SNAPSHOT_VERSION,
        "step_no": snap.step_no,
        "serve_cfg": snap.serve_cfg,
        "sched_cfg": snap.sched_cfg,
        "t": snap.t,
        "last_mig": snap.last_mig,
        "migrations": snap.migrations,
        "table": None
        if snap.table is None
        else {
            "n_slots": snap.table["n_slots"],
            "slots_per_device": snap.table["slots_per_device"],
        },
        "dead": snap.dead,
        "pending_migrations": snap.pending_migrations,
        "next_rid": snap.next_rid,
        "n_preempted": snap.n_preempted,
        "hostage_pages": snap.hostage_pages,
        "requests": snap.requests,
        "queue_rids": snap.queue_rids,
        "live_rids": snap.live_rids,
    }
    save(path, tree, step=snap.step_no, extra={"snapshot": meta})


def load_snapshot(path: str) -> ServerSnapshot:
    arrays = dict(np.load(path))
    meta = load_meta(path)["snapshot"]
    table = None
    load_ema = slowdown = None
    if meta["table"] is not None:
        table = {
            "slot_of": arrays["table/slot_of"],
            "n_replicas": arrays["table/n_replicas"],
            "n_slots": int(meta["table"]["n_slots"]),
            "slots_per_device": int(meta["table"]["slots_per_device"]),
        }
        load_ema = arrays["balancer/load_ema"]
        slowdown = arrays.get("balancer/slowdown")
    rids = [int(r["rid"]) for r in meta["requests"]]
    return ServerSnapshot(
        step_no=int(meta["step_no"]),
        serve_cfg=dict(meta["serve_cfg"]),
        sched_cfg=dict(meta["sched_cfg"]),
        t=int(meta["t"]),
        last_mig=int(meta["last_mig"]),
        migrations=int(meta["migrations"]),
        table=table,
        load_ema=load_ema,
        slowdown=slowdown,
        dead=[int(d) for d in meta["dead"]],
        pending_migrations=list(meta["pending_migrations"]),
        next_rid=int(meta["next_rid"]),
        n_preempted=int(meta["n_preempted"]),
        hostage_pages=int(meta["hostage_pages"]),
        requests=[dict(r) for r in meta["requests"]],
        prompts={rid: arrays[f"prompt/{rid}"] for rid in rids},
        emitted={rid: arrays[f"emitted/{rid}"] for rid in rids},
        queue_rids=[int(r) for r in meta["queue_rids"]],
        live_rids=[None if r is None else int(r) for r in meta["live_rids"]],
    )


def restore_scheduler(
    snap: ServerSnapshot | str,
    cfg,
    ctx,
    params,
    distance=None,
    faults=None,
):
    """Rebuild a live scheduler on a fresh process from a snapshot.

    ``params`` is the *logical* params checkpoint (un-expanded expert
    rows), exactly what a fresh ``Server`` takes — expansion follows the
    snapshot's committed table. Requests that were DECODING at the crash
    lost their KV with the dead process; they re-enter at the queue front
    (slot order) in state PREEMPTED for the standard recompute, without
    charging the crash against their preemption budget. ``faults`` (the
    original plan) is filtered of ``crash_restart`` entries at or before
    the snapshot step, so the crash does not recur on replay.
    """
    from repro.runtime.scheduler import (
        PREEMPTED,
        Request,
        RequestScheduler,
        SchedulerConfig,
    )

    if isinstance(snap, str):
        snap = load_snapshot(snap)
    if faults is not None:
        faults = F.FaultPlan(
            [
                f
                for f in faults
                if not (f.kind == F.CRASH_RESTART and f.step <= snap.step_no)
            ]
        )
    srv = Server.restore_snapshot(snap, cfg, ctx, params, distance=distance)
    sched = RequestScheduler(
        srv, SchedulerConfig(**snap.sched_cfg), faults=faults
    )
    by_rid: dict[int, Request] = {}
    for rec in snap.requests:
        rid = int(rec["rid"])
        req = Request(
            rid=rid,
            prompt=np.asarray(snap.prompts[rid], np.int32),
            max_new_tokens=int(rec["max_new_tokens"]),
            eos_id=rec["eos_id"],
            arrival=int(rec["arrival"]),
            state=rec["state"],
            tokens_out=[int(x) for x in snap.emitted[rid]],
            preemptions=int(rec["preemptions"]),
            error=rec["error"],
            # prefill_pos deliberately left 0: requeued requests restart
            # their chunk state machine (KV died with the process). The
            # stats fields survive so ttft/stall numbers span the crash.
            # (.get: snapshots from before chunked admission lack them.)
            admitted_step=rec.get("admitted_step"),
            first_token_step=rec.get("first_token_step"),
            last_token_step=rec.get("last_token_step"),
            max_stall=int(rec.get("max_stall", 0)),
        )
        by_rid[rid] = req
        sched.requests.append(req)
    front = [by_rid[rid] for rid in snap.live_rids if rid is not None]
    for req in front:
        req.state = PREEMPTED
        req.slot = None
    for req in front + [by_rid[rid] for rid in snap.queue_rids]:
        sched.queue.append(req)
    sched.step_no = snap.step_no
    sched._rid = snap.next_rid
    sched.n_preempted = snap.n_preempted
    if snap.hostage_pages:
        sched._hostage = srv.page_pool.alloc(
            min(snap.hostage_pages, srv.page_pool.n_free)
        )
    sched.last_snapshot = snap
    return sched
