"""Cross-pod communication compression (int8 wire format).

The ``pod`` axis rides slow DCI links (the analogue of the paper's
cross-wafer connectors), so cross-pod traffic is the byte budget that
matters at 1000+ node scale. Two facilities:

* :func:`compressed_pod_mean` — average a pytree across pods with int8
  stochastic-rounding wire format (4x fewer DCI bytes than bf16). Used by
  the training loop for DiLoCo-style periodic cross-pod parameter
  synchronization: pods run locally for K steps, then reconcile. This
  replaces per-step cross-pod gradient all-reduce — both a bandwidth
  optimization and a straggler/fault isolation boundary (a slow pod delays
  a sync point, not every step).
* :func:`_quant` / :func:`_pod_psum_int8` — the underlying unbiased int8
  reduce-scatter/all-gather building blocks.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.compat import shard_map


def _quant(x: jax.Array, key: jax.Array):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    y = x / scale
    noise = jax.random.uniform(key, y.shape) - 0.5
    q = jnp.clip(jnp.round(y + noise), -127, 127).astype(jnp.int8)
    return q, scale


def _pod_psum_int8(x: jax.Array, axis: str, n_pods: int, key: jax.Array):
    """Unbiased int8-wire psum over ``axis`` for one fp32 tensor."""
    pad = (-x.size) % n_pods
    flat = jnp.pad(x.reshape(-1), (0, pad)).reshape(n_pods, -1)
    q, scale = _quant(flat, key)
    # Reduce-scatter: exchange int8 chunks; chunk i lands on pod i.
    recv = jax.lax.all_to_all(q, axis, split_axis=0, concat_axis=0, tiled=False)
    scales = jax.lax.all_gather(scale, axis)                    # (n_pods,)
    part = jnp.sum(recv.astype(jnp.float32) * scales[:, None], axis=0)
    # All-gather the summed chunk, int8 again.
    q2, scale2 = _quant(part[None], key)
    got = jax.lax.all_gather(q2[0], axis)                       # (n_pods, chunk)
    scales2 = jax.lax.all_gather(scale2, axis)
    full = (got.astype(jnp.float32) * scales2[:, None]).reshape(-1)
    return full[: x.size].reshape(x.shape)


def compressed_pod_mean(tree, mesh: jax.sharding.Mesh, seed: int = 0):
    """Average a pytree over the ``pod`` mesh axis, int8 on the wire.

    Leaves are treated as pod-replicated within each pod's sub-mesh (the
    usual layout: params sharded over "model"/"data", replicated over
    "pod"); the partial shard_map manualizes only the pod axis.
    """
    n_pods = mesh.shape["pod"]
    if n_pods == 1:
        return tree
    leaves, tdef = jax.tree.flatten(tree)

    def body(*flat):
        key = jax.random.PRNGKey(seed)
        out = []
        for i, g in enumerate(flat):
            s = _pod_psum_int8(
                g.astype(jnp.float32), "pod", n_pods, jax.random.fold_in(key, i)
            )
            out.append((s / n_pods).astype(g.dtype))
        return tuple(out)

    specs = tuple(P(*(None,) * l.ndim) for l in leaves)
    out = shard_map(
        body,
        mesh=mesh,
        in_specs=specs,
        out_specs=specs,
        axis_names={"pod"},
        check_vma=False,
    )(*leaves)
    return tdef.unflatten(list(out))
