"""Parallel context threaded through model code.

Carries the mesh axis conventions and implementation switches. When ``mesh``
is ``None`` (CPU smoke tests) every sharding helper is a no-op and reference
implementations are used, so the same model code runs everywhere.

Axis conventions (matching ``repro.launch.mesh``):

* ``data`` (and optionally ``pod``) — batch / DP / the paper's FTD-exterior
  dimension,
* ``model`` — TP / EP: attention heads, FFN hidden, vocab, experts.
"""

from __future__ import annotations

import dataclasses

import jax
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParallelCtx:
    mesh: jax.sharding.Mesh | None = None
    batch_axes: tuple[str, ...] = ("data",)
    model_axis: str = "model"
    moe_impl: str = "auto"           # auto | dense | ep | esp
    remat: bool = False
    capacity_factor: float = 2.0     # MoE dispatch capacity
    # decode: shard the KV sequence dim over the model axis (flash-decode
    # style sequence parallelism) instead of replicating the cache.
    seq_parallel_kv: bool = True
    # Cost-probe mode (launch.dryrun): fully unroll layer scans and use the
    # dense attention path so XLA's cost analysis sees every FLOP (it counts
    # a while-loop body only once).
    full_unroll: bool = False
    force_dense_attn: bool = False
    # Megatron-style sequence parallelism for the residual stream: block
    # outputs reduce-scatter to seq-sharded form; the next projection's
    # all-gather is the paper's "retained AG" (§Perf iterations 4-5).
    seq_parallel_acts: bool = False
    # Pallas kernel dispatch (repro.kernels.registry): "auto" enables the
    # compiled kernels on TPU only; True forces them everywhere (interpret
    # mode off-TPU — exact but slow, for tests); False keeps the einsum
    # reference paths.
    use_kernels: str | bool = "auto"
    # EP dispatch pipelining: split each device's expert groups into this
    # many chunks and pipeline the all_to_all legs against the fused FFN
    # (chunk i's combine and chunk i+1's dispatch in flight while chunk i
    # computes). 1 = the single-shot path. Must divide the per-device
    # expert-group count (collectives.validate_ep_chunks).
    ep_chunks: int = 1

    @property
    def seq_spec(self):
        return self.model_axis if self.seq_parallel_acts else None

    @property
    def batch_spec(self):
        if not self.batch_axes:
            return None  # batch too small to shard (e.g. long-context B=1)
        return self.batch_axes if len(self.batch_axes) > 1 else self.batch_axes[0]

    def shard(self, x, *spec):
        """``with_sharding_constraint`` under a mesh; identity otherwise.

        Axes that do not divide the corresponding dimension are dropped
        (replicated) instead of erroring — this keeps one model codebase
        valid across GQA head counts, tiny batches and arbitrary meshes.
        """
        if self.mesh is None:
            return x
        clean = []
        for dim, sp in zip(x.shape, spec):
            if sp is None:
                clean.append(None)
                continue
            axes = sp if isinstance(sp, tuple) else (sp,)
            n = 1
            for a in axes:
                n *= self.mesh.shape[a]
            clean.append(sp if dim % n == 0 else None)
        return jax.lax.with_sharding_constraint(x, P(*clean))

    @property
    def kernels_on(self) -> bool:
        from repro.kernels.registry import kernels_enabled

        return kernels_enabled(self.use_kernels)

    @property
    def n_model(self) -> int:
        if self.mesh is None:
            return 1
        return self.mesh.shape[self.model_axis]

    @property
    def n_batch(self) -> int:
        if self.mesh is None:
            return 1
        n = 1
        for a in self.batch_axes:
            n *= self.mesh.shape[a]
        return n


NO_MESH = ParallelCtx()
