"""jax version-compat shims for the parallel layer.

``shard_map`` has moved twice upstream (``jax.experimental.shard_map`` ->
``jax.shard_map``) and renamed/replaced kwargs along the way
(``check_rep`` -> ``check_vma``; partial manualization went from the
``auto=`` complement set to ``axis_names=``). Model code imports the
wrapper below and always writes the *newest* spelling; the wrapper
translates for whatever jax is installed.
"""

from __future__ import annotations

import inspect

try:  # jax >= 0.6 exposes shard_map at the top level
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
except ImportError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

_PARAMS = frozenset(inspect.signature(_shard_map).parameters)


def _tree_leaves(specs):
    import jax

    return jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)
    )


def shard_map(
    f,
    *,
    mesh,
    in_specs,
    out_specs,
    check_vma: bool | None = None,
    axis_names: set | None = None,
):
    kw = {}
    if check_vma is not None:
        kw["check_vma" if "check_vma" in _PARAMS else "check_rep"] = check_vma
    if axis_names is not None:
        if "axis_names" in _PARAMS:
            kw["axis_names"] = set(axis_names)
        else:
            # Old jax: partial manualization (the ``auto=`` complement) hits
            # an XLA SPMD-partitioner check-failure on 0.4.x, so fall back
            # to FULL manualization. Equivalent as long as the in/out specs
            # don't shard over the would-be-auto axes — which holds for the
            # repo's only partial user (grad_compress: all-replicated specs,
            # collectives over "pod" only).
            for spec in (*_tree_leaves(in_specs), *_tree_leaves(out_specs)):
                for el in spec:
                    axes = el if isinstance(el, tuple) else (el,)
                    assert all(a is None or a in axis_names for a in axes), (
                        "compat shard_map: partial manualization with specs "
                        f"over auto axes unsupported on old jax ({spec})"
                    )
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
