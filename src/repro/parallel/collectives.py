"""Expert-parallel dispatch/combine and sequence-parallel decode attention.

``bucket_dispatch`` / ``bucket_combine`` are the static-shape, differentiable
building blocks: token copies are sorted into fixed-capacity buckets (one
per physical expert slot), moved with ``jax.lax.all_to_all`` across the EP
axis under ``shard_map``, computed, and combined back with router weights.
Capacity overflow drops copies (standard capacity-factor semantics).

Physical expert *slots* (= native experts + shadow replicas) are first-class:
the routing table ``slot_of[e, r]`` and replica counts ``n_replicas[e]`` are
traced int32 inputs, so the NI-Balancer can re-place experts between serving
steps without recompilation.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels import registry
from repro.parallel.compat import shard_map
from repro.parallel.ctx import ParallelCtx
from repro.parallel.placement import PlacementTable
from repro.parallel.sharding import placement_specs


def bucket_capacity(n_tok: int, k: int, capacity_factor: float, n_buckets: int) -> int:
    """Per-bucket capacity for ``n_tok`` tokens x ``k`` copies over
    ``n_buckets`` buckets. **Ceiling** division: floor truncation silently
    under-allocates (e.g. 100 copies over 3 buckets at factor 1.0 floored to
    33 drops a copy even under perfectly balanced routing). Floored at 8 so
    tiny smoke shapes keep a usable bucket."""
    return max(math.ceil(n_tok * k * capacity_factor / n_buckets), 8)


# ---------------------------------------------------------------------------
# bucket dispatch (pure jnp, static shapes, differentiable in x / weights)
# ---------------------------------------------------------------------------

def dispatch_metadata(
    bucket_ids: jax.Array,  # (n, k) target bucket per token copy
    n_buckets: int,
    capacity: int,
):
    """Metadata-only dispatch: the sort/position math of ``bucket_dispatch``
    without writing the padded ``(n_buckets, capacity, d)`` buffers.

    Returns ``(row_ids, offsets, counts, slots, keep)``:

    * ``row_ids`` (n*k,) — source token index per *compacted* position: the
      flat array ``x[row_ids]`` holds bucket 0's copies, then bucket 1's, …
      (within a bucket, earlier tokens first — the same deterministic order
      ``bucket_dispatch`` packs). Copies past capacity and out-of-range
      bucket ids (e.g. the decode ownership sentinel) sort to each bucket's
      tail / past every real bucket and are simply never addressed by
      ``offsets``/``counts``.
    * ``offsets`` (n_buckets,) int32 — bucket g's first compacted row.
    * ``counts`` (n_buckets,) int32 — bucket g's *kept* copies
      (== ``kept_counts``): rows ``offsets[g] .. offsets[g]+counts[g]`` of
      the compacted array are exactly bucket g's surviving tokens.
    * ``slots`` (n, k) / ``keep`` (n, k) — identical to ``bucket_dispatch``
      (within-bucket position, capacity-survival mask) for the combine.

    This is the operand layout the fused gather kernels
    (``kernels.gmm.ragged.gmm_gather``) consume via scalar prefetch.
    """
    n, k = bucket_ids.shape
    flat_b = bucket_ids.reshape(-1)                       # (n*k,)

    order = jnp.argsort(flat_b, stable=True)
    b_sorted = flat_b[order]
    # Out-of-range ids are dropped by bincount, so valid-bucket counts and
    # offsets are sentinel-proof (sentinels sort past every real bucket).
    counts_all = jnp.bincount(flat_b, length=n_buckets)
    offsets = jnp.concatenate(
        [jnp.zeros(1, counts_all.dtype), jnp.cumsum(counts_all)[:-1]]
    )
    idx_sorted = jnp.arange(n * k) - offsets[b_sorted]

    # Undo the sort to index by (token, k).
    slots = jnp.zeros(n * k, dtype=jnp.int32).at[order].set(
        idx_sorted.astype(jnp.int32)
    )
    keep = (slots < capacity) & (flat_b < n_buckets)  # drop out-of-range ids too
    row_ids = (order // k).astype(jnp.int32)          # copy j came from token j//k
    counts = jnp.minimum(counts_all, capacity).astype(jnp.int32)
    return row_ids, offsets.astype(jnp.int32), counts, slots.reshape(n, k), keep.reshape(n, k)


def bucket_dispatch(
    x: jax.Array,          # (n, d) token activations
    bucket_ids: jax.Array, # (n, k) target bucket per token copy
    n_buckets: int,
    capacity: int,
):
    """Pack token copies into (n_buckets, capacity, d) buffers.

    Returns ``(buffers, slots, keep)`` where ``slots[n, k]`` is the
    within-bucket position of each copy and ``keep[n, k]`` masks copies that
    fit under capacity. Deterministic: earlier tokens win bucket slots.

    This is the materialized fallback; the fused kernel path uses
    ``dispatch_metadata`` + the gather kernels and never writes the buffers.
    """
    n, k = bucket_ids.shape
    d = x.shape[-1]
    flat_b = bucket_ids.reshape(-1)                       # (n*k,)
    flat_src = jnp.repeat(jnp.arange(n), k)               # (n*k,)
    _, _, _, slots, keep = dispatch_metadata(bucket_ids, n_buckets, capacity)

    # Scatter kept copies; overflow goes to a sacrificial extra bucket row.
    flat_keep = keep.reshape(-1)
    flat_slots = slots.reshape(-1)
    slot_b = jnp.where(flat_keep, flat_b, n_buckets)
    slot_i = jnp.minimum(flat_slots, capacity - 1)
    buffers = jnp.zeros((n_buckets + 1, capacity, d), dtype=x.dtype)
    buffers = buffers.at[slot_b, slot_i].set(x[flat_src], mode="drop")
    return buffers[:n_buckets], slots, keep


def bucket_combine(
    y: jax.Array,            # (n_buckets, capacity, d) expert outputs
    bucket_ids: jax.Array,   # (n, k)
    slots: jax.Array,        # (n, k)
    keep: jax.Array,         # (n, k)
    weights: jax.Array,      # (n, k) router weights
) -> jax.Array:
    n, k = bucket_ids.shape
    vals = y[bucket_ids.reshape(-1), jnp.minimum(slots, y.shape[1] - 1).reshape(-1)]
    vals = vals.reshape(n, k, -1)
    w = (weights * keep).astype(vals.dtype)
    return jnp.einsum("nkd,nk->nd", vals, w)


def combine_from_rows(
    y: jax.Array,        # (R, d) flat compact expert outputs
    rows: jax.Array,     # (n, k) flat output row per copy (junk when dropped)
    keep: jax.Array,     # (n, k) capacity-survival mask
    weights: jax.Array,  # (n, k) router weights
) -> jax.Array:
    """Metadata-driven combine for the compact FFN output: gather each kept
    copy's row from the flat array and weighted-sum per token — the
    ``(n_buckets, capacity, d)`` receive buffer of ``bucket_combine`` never
    exists. Rows between live segments carry uninitialized garbage (the
    scatter epilogue never writes them), so dropped copies must select zero
    *before* any arithmetic: a ``where``, not a ``0 *`` weighting —
    ``0 * NaN`` would poison the token."""
    n, k = rows.shape
    safe = jnp.clip(rows.reshape(-1), 0, y.shape[0] - 1)
    vals = y[safe].reshape(n, k, -1)
    vals = jnp.where(keep[..., None], vals, jnp.zeros_like(vals))
    w = (weights * keep).astype(vals.dtype)
    return jnp.einsum("nkd,nk->nd", vals, w)


def scatter_counts(bucket_ids: jax.Array, n_buckets: int) -> jax.Array:
    """Per-bucket token counts (n, k) -> (n_buckets,); feeds the balancer."""
    return jnp.bincount(bucket_ids.reshape(-1), length=n_buckets)


def kept_counts(
    bucket_ids: jax.Array, keep: jax.Array, n_buckets: int
) -> jax.Array:
    """Per-bucket *kept* copy counts (capacity drops excluded), int32.

    ``bucket_dispatch`` packs kept copies into slots ``0..count-1`` of their
    bucket, so these counts are exactly the ``group_sizes`` the ragged GMM
    kernels consume. Implemented as a scatter-add (vmap-safe, unlike
    ``jnp.bincount``); out-of-range ids land in a sacrificial row.
    """
    b = jnp.where(keep, bucket_ids, n_buckets)
    return (
        jnp.zeros((n_buckets + 1,), jnp.int32)
        .at[b.reshape(-1)]
        .add(1, mode="drop")[:n_buckets]
    )


# ---------------------------------------------------------------------------
# replica routing
# ---------------------------------------------------------------------------

def choose_slots(
    expert_ids: jax.Array,   # (n, k) logical expert per copy
    slot_of: jax.Array,      # (E, R_max) physical slot table
    n_replicas: jax.Array,   # (E,) live replica count per expert
    sentinel: int | None = None,
) -> jax.Array:
    """Pick a physical slot per copy, round-robin over live replicas.

    ``sentinel`` handles out-of-range expert ids (>= E — the routing mask
    for empty serving slots): their copies map to ``sentinel`` (pick one
    past every real bucket) so dispatch drops them, instead of the default
    clip-gather silently stealing a live expert's slot and capacity."""
    n, k = expert_ids.shape
    e = slot_of.shape[0]
    safe = jnp.minimum(expert_ids, e - 1)
    copy_idx = (jnp.arange(n * k) % 997).reshape(n, k)  # cheap spread
    r = copy_idx % n_replicas[safe]
    slots = slot_of[safe, r]
    if sentinel is not None:
        slots = jnp.where(expert_ids < e, slots, sentinel)
    return slots


def uniform_placement(n_experts: int, n_slots: int, r_max: int = 4):
    """Initial placement: expert e -> slot e (native homes), one replica.

    Thin wrapper over :meth:`PlacementTable.uniform` kept for callers that
    want the bare ``(slot_of, n_replicas)`` device arrays without holding a
    table; the serving path holds the table itself."""
    return PlacementTable.uniform(n_experts, n_slots, r_max=r_max).device_view()


def tiled_placement(n_experts: int, n_rows: int, n_slots: int, r_max: int = 4):
    """Placement consistent with ``jnp.tile``-expanded slot weights.

    When ``moe_ep`` pads a non-divisible expert count up to ``n_slots``
    physical slots by tiling the weight rows, slot ``s`` holds weight row
    ``s % n_rows`` — i.e. expert ``s % n_rows`` for the identity rows
    (``row j == expert j`` for ``j < n_experts``, which is what ``moe_init``
    produces). The matching placement therefore gives expert ``e`` a replica
    at *every* slot ``s < n_slots`` with ``s % n_rows == e`` — so routed
    tokens provably land on slots holding their expert's weights, and the
    wrap-around shadow slots carry real traffic instead of sitting idle
    while still being counted in the capacity denominator.

    Thin wrapper over :meth:`PlacementTable.tiled` (which grows ``r_max`` so
    every wrap-around replica fits the table)."""
    table = PlacementTable.tiled(n_experts, n_rows, n_slots, r_max=r_max)
    return table.device_view()


# ---------------------------------------------------------------------------
# EP all-to-all under shard_map
# ---------------------------------------------------------------------------

def validate_ep_token_split(
    b: int, s: int, n_batch: int, ep: int, decode: bool
) -> None:
    """Up-front shape validation for ``ep_moe_shardmap``.

    The shard_map splits batch over the batch axes and (prefill) sequence
    over the EP axis; a non-dividing shape either dies inside shard_map
    with an opaque spec error or — worse — silently floor-truncates
    ``n_tok = b*s // (n_batch * ep)`` and under-sizes ``bucket_capacity``
    (the same failure class as the PR 2 capacity-floor bug). Fail loudly,
    naming the offending shapes."""
    if n_batch and b % n_batch:
        raise ValueError(
            f"ep_moe_shardmap: batch={b} does not divide the {n_batch}-way "
            f"batch axis (seq={s}, ep={ep}, decode={decode}) — pad the "
            f"batch or reshape the mesh"
        )
    if not decode and s % ep:
        raise ValueError(
            f"ep_moe_shardmap prefill splits the sequence over the EP "
            f"axis: seq={s} does not divide ep={ep} (batch={b}, "
            f"n_batch={n_batch}); b*s//(n_batch*ep) would floor-truncate "
            f"the per-device token count and under-size bucket_capacity — "
            f"pad the sequence to a multiple of {ep}"
        )


def validate_ep_chunks(ep_chunks, n_groups: int | None = None, where: str = "") -> int:
    """Validate the EP dispatch chunk count with a named error.

    ``ep_chunks`` must be a positive int; when ``n_groups`` (the expert-group
    count the chunking splits — ``slots_per_device`` on the mesh path, the
    total slot count on the local path, ``n_experts`` for ESP) is known it
    must divide it, or per-chunk buckets would be ragged across chunks and
    the shard_map/jit shapes would differ per chunk. Failing here names the
    offending values instead of dying inside shard_map with an opaque spec
    error. ``ep_chunks=1`` is always valid and means the single-shot path.
    Returns the validated count."""
    at = f" ({where})" if where else ""
    if not isinstance(ep_chunks, int) or isinstance(ep_chunks, bool) or ep_chunks < 1:
        raise ValueError(
            f"ep_chunks={ep_chunks!r}{at} must be a positive int "
            f"(1 = single-shot dispatch, K > 1 pipelines the all_to_all "
            f"legs in K expert-group chunks)"
        )
    if n_groups is not None and n_groups % ep_chunks:
        raise ValueError(
            f"ep_chunks={ep_chunks}{at} does not divide the expert-group "
            f"count {n_groups} — every chunk must carry the same number of "
            f"expert groups so the exchange buffers stay statically shaped; "
            f"pick a divisor of {n_groups} (or 1 for the single-shot path)"
        )
    return ep_chunks


def ep_moe_shardmap(
    x: jax.Array,                 # (B, S, d) — seq will be split over model axis
    expert_ids: jax.Array,        # (B, S, k)
    weights: jax.Array,           # (B, S, k)
    slot_weights: dict,           # expert slot params, leading dim = total slots
    slot_of: jax.Array,           # (E, R_max)
    n_replicas: jax.Array,        # (E,)
    ctx: ParallelCtx,
    capacity_factor: float,
    slots_per_device: int,
    decode: bool = False,
):
    """Expert-parallel MoE: dispatch -> all_to_all -> GMM -> all_to_all -> combine.

    ``slot_weights`` holds (n_total_slots, d, f) matrices sharded over the
    model axis (slot dim). Inside the per-device block each device sees its
    ``slots_per_device`` local experts and exchanges fixed-capacity buckets
    with every peer on the EP (= model) axis.

    Train/prefill mode splits the *sequence* over the EP axis (each TP rank
    dispatches a distinct token slice — the paper's retained-AG semantics).
    Decode mode (``s == 1``) keeps tokens replicated over the EP axis; each
    rank owns tokens with ``idx % ep == rank`` and a final psum restores
    replication.
    """
    mesh = ctx.mesh
    axis = ctx.model_axis
    ep = ctx.n_model
    total_slots = ep * slots_per_device
    use_kernels = ctx.kernels_on

    b, s, d = x.shape
    k = expert_ids.shape[-1]
    f = slot_weights["w_gate"].shape[-1]
    validate_ep_token_split(b, s, ctx.n_batch, ep, decode)
    if decode:
        n_tok = b // ctx.n_batch                   # distinct tokens per EP group
    else:
        n_tok = b * s // (ctx.n_batch * ep)        # tokens per device, seq split
    cap = bucket_capacity(n_tok, k, capacity_factor, total_slots)
    # Fused dispatch-gather path: token rows ship rank-compacted (packed
    # back-to-back per destination rank inside the statically-sized
    # exchange buffer — all_to_all needs equal splits, so wire bytes are
    # unchanged) and the gather GMM reads the received rows via per-bucket
    # offsets. The *combine* leg mirrors it: the scatter epilogue
    # (compact_out) writes the down-projection back at the same offsets,
    # the return all_to_all ships that compact buffer, and
    # combine_from_rows gathers through the dest/posr/keep metadata — no
    # (spd, ep, cap, d) transpose/repack and no padded FFN input *or*
    # output buffer is ever materialized on either leg. Padded
    # bucket_dispatch/bucket_combine remain the fallback when the kernels
    # are off or shapes don't tile for the compiled kernel.
    fused = use_kernels and registry.can_gmm_gather(
        cap, d, f, registry.default_interpret()
    )
    spd = slots_per_device
    # EP dispatch pipelining (ctx.ep_chunks = K): the fused branch splits
    # each device's spd expert groups into K chunks of spc groups and
    # pipelines the per-chunk all_to_all legs against the per-chunk FFN.
    # Validated up front with a named error; the padded fallback branch
    # stays single-shot (its buffers are already the slow path).
    kc = validate_ep_chunks(
        getattr(ctx, "ep_chunks", 1), where="ep_moe_shardmap"
    )
    if kc > 1:
        validate_ep_chunks(kc, spd, where="ep_moe_shardmap slots_per_device")
    if not fused:
        kc = 1
    spc = spd // kc

    def dispatch_fused(xt, slots):
        """Per-chunk rank-compacted send buffers + per-bucket metadata (no
        padding between a chunk's buckets; bucket order within a rank
        preserved). ``dest``/``posr`` — each copy's destination rank and
        row inside that rank's compacted *chunk* block — also address the
        copy's row in the returned compact FFN output (the scatter epilogue
        writes results at the same offsets the prologue gathered from), so
        the combine gathers through them directly. The chunk split is
        metadata-only: a bucket's fill and internal order are per-bucket
        properties of the one global ``dispatch_metadata`` call, so slicing
        buckets by chunk changes nothing about any bucket's rows — no
        padded buffer reappears on either leg."""
        n = xt.shape[0]
        _, _, kept, pos, keep = dispatch_metadata(slots, total_slots, cap)
        # Within-segment row offset of each bucket: exclusive cumsum
        # restarting at every (rank, chunk) boundary. kc == 1 degenerates
        # to the whole-rank cumsum of the single-shot path.
        kept_ck = kept.reshape(ep, kc, spc)
        wro = jnp.cumsum(kept_ck, axis=2) - kept_ck           # (ep, kc, spc)
        flat_b = slots.reshape(-1)
        safe_b = jnp.minimum(flat_b, total_slots - 1)
        dest = flat_b // spd                                  # >= ep for sentinels
        chunk_of = (safe_b % spd) // spc                      # owning chunk
        posr = wro.reshape(-1)[safe_b] + pos.reshape(-1)
        posr = jnp.where(keep.reshape(-1), posr, spc * cap)   # overflow -> drop
        src = xt[jnp.repeat(jnp.arange(n), k)]
        sends = []
        for c in range(kc):
            # Copies owned by other chunks scatter out of bounds and drop —
            # each kept copy lands in exactly one chunk's buffer.
            posr_c = jnp.where(chunk_of == c, posr, spc * cap)
            send = jnp.zeros((ep, spc * cap, d), dtype=xt.dtype)
            sends.append(send.at[dest, posr_c].set(src, mode="drop"))
        return sends, kept_ck, keep, chunk_of, dest, posr

    def body(x_blk, eid_blk, w_blk, wg, wu, wd, slot_of_, n_rep_):
        # x_blk: (B_loc, S_loc, d) — this device's token slice.
        bl, sl, _ = x_blk.shape
        xt = x_blk.reshape(bl * sl, d)
        eid = eid_blk.reshape(bl * sl, k)
        w = w_blk.reshape(bl * sl, k)

        # Physical slot per copy; masked tokens (expert id E sentinel from
        # moe_apply's token_mask) overflow out of every bucket.
        slots = choose_slots(eid, slot_of_, n_rep_, sentinel=total_slots + 1)
        if decode:
            # Tokens are replicated across the EP axis: rank r owns
            # idx % ep == r; unowned copies overflow out of every bucket.
            rank = jax.lax.axis_index(axis)
            owned = (jnp.arange(bl * sl) % ep) == rank
            slots = jnp.where(owned[:, None], slots, total_slots + 1)

        if fused:
            sends, kept_ck, keep, chunk_of, dest, posr = dispatch_fused(xt, slots)

            def exchange(c):
                recv = jax.lax.all_to_all(
                    sends[c], axis, split_axis=0, concat_axis=0, tiled=False
                )
                cnt = jax.lax.all_to_all(
                    kept_ck[:, c], axis, split_axis=0, concat_axis=0, tiled=False
                )
                return recv, cnt

            def chunk_ffn(recv, cnt, c):
                # recv[r'] = my chunk's spc buckets' rows from source rank
                # r', bucket-compacted; cnt[r', s] = that segment's fill.
                roff = jnp.cumsum(cnt, axis=1) - cnt          # (ep, spc)
                # Group gi = s*ep + r' (weight row = gi // ep, as the
                # padded layout) -> flat row offset r'*spc*cap + roff.
                base = jnp.arange(ep, dtype=jnp.int32)[:, None] * (spc * cap)
                offsets_g = (roff + base).transpose(1, 0).reshape(-1)
                counts_g = cnt.transpose(1, 0).reshape(-1)
                # compact_out: the scatter epilogue writes the down-
                # projection back at offsets_g, so the flat (ep*spc*cap, d)
                # result IS the return exchange buffer — segment r' goes
                # straight back to source rank r', still bucket-compacted
                # in *my* bucket order. fused=True: one kernel when
                # can_gmm_fused accepts the shapes; the registry falls back
                # to the gather+scatter pair (same layout contract) per
                # chunk when it doesn't.
                ws = slice(c * spc, (c + 1) * spc)
                y = registry.expert_ffn_from_rows(
                    recv.reshape(ep * spc * cap, d),
                    wg[ws],
                    wu[ws],
                    wd[ws],
                    offsets_g,
                    counts_g,
                    capacity=cap,
                    groups_per_weight=ep,
                    enabled=True,
                    compact_out=True,
                    fused=True,
                )
                return jax.lax.all_to_all(
                    y.reshape(ep, spc * cap, d), axis,
                    split_axis=0, concat_axis=0, tiled=False,
                )

            # Software pipeline over the chunks (trace-unrolled): chunk
            # c+1's dispatch all_to_all is issued *before* chunk c's FFN,
            # and chunk c's combine all_to_all right after it — neither
            # depends on the other's data, so async collectives run the
            # in-flight legs while gmm_fused_ffn executes. Double-buffer
            # contract: at most two receive buffers are live at once (the
            # chunk being computed and the one in flight). kc == 1 is the
            # original single-shot dispatch -> FFN -> combine sequence.
            recv = [None] * kc
            recv[0] = exchange(0)
            backs = []
            for c in range(kc):
                if c + 1 < kc:
                    recv[c + 1] = exchange(c + 1)
                backs.append(chunk_ffn(*recv[c], c))
                recv[c] = None                    # retire chunk c's buffer
            # ONE combine over the concatenated chunk outputs: each copy's
            # row is its chunk's block base + dest*(spc*cap) + posr — the
            # exact coordinates dispatch_fused scattered it to on the way
            # out. A single gather + einsum keeps the per-token k-copy
            # reduction order identical to the single-shot path (bit-
            # exactness); per-chunk partial combines would re-order it.
            back = jnp.concatenate(backs, axis=0)
            rows = chunk_of * (ep * spc * cap) + dest * (spc * cap) + posr
            out = combine_from_rows(
                back.reshape(kc * ep * spc * cap, d),
                rows.reshape(bl * sl, k), keep, w,
            )
        else:
            bufs, pos, keep = bucket_dispatch(xt, slots, total_slots, cap)
            # How full each outgoing bucket actually is — rides the same
            # all_to_all so every device knows its received buckets'
            # raggedness.
            counts = kept_counts(slots, keep, total_slots)
            # (total_slots, cap, d) -> exchange so each device gets its slots.
            bufs = bufs.reshape(ep, spd, cap, d)
            recv = jax.lax.all_to_all(
                bufs, axis, split_axis=0, concat_axis=0, tiled=False
            )
            cnt = jax.lax.all_to_all(
                counts.reshape(ep, spd), axis,
                split_axis=0, concat_axis=0, tiled=False,
            )
            # recv: (ep, spd, cap, d) — axis 0 now = source rank.
            recv = recv.transpose(1, 0, 2, 3)              # (spd, ep, cap, d)
            cnt = cnt.transpose(1, 0)                      # (spd, ep)

            # Local expert compute: bucket (slot e, source r) uses weight
            # row e; the ragged GMM kernels skip capacity rows past each
            # bucket's count, so FFN FLOPs track tokens actually routed
            # (fallback: folded einsums over the same layout).
            y = registry.expert_ffn(
                recv.reshape(spd * ep, cap, d),
                wg,
                wu,
                wd,
                group_sizes=cnt.reshape(-1),
                groups_per_weight=ep,
                enabled=use_kernels,
            )
            y = y.reshape(spd, ep, cap, d).transpose(1, 0, 2, 3)
            back = jax.lax.all_to_all(
                y, axis, split_axis=0, concat_axis=0, tiled=False
            )
            back = back.reshape(total_slots, cap, d)
            out = bucket_combine(back, slots, pos, keep, w)
        if decode:
            out = jax.lax.psum(out, axis)  # gather owners' results everywhere
        return out.reshape(bl, sl, d)

    bspec = ctx.batch_spec
    seq_spec = None if decode else axis
    return shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(bspec, seq_spec, None),      # x: sequence split over model axis
            P(bspec, seq_spec, None),
            P(bspec, seq_spec, None),
            P(axis, None, None),           # slot weights: slot dim over model
            P(axis, None, None),
            P(axis, None, None),
            *placement_specs(),            # routing tables replicated
        ),
        out_specs=P(bspec, seq_spec, None),
        check_vma=False,
    )(
        x,
        expert_ids,
        weights,
        slot_weights["w_gate"],
        slot_weights["w_up"],
        slot_weights["w_down"],
        slot_of,
        n_replicas,
    )


def ep_moe_local(
    x: jax.Array,            # (B, S, d)
    expert_ids: jax.Array,   # (B, S, k) — may carry the E sentinel (masked)
    weights: jax.Array,      # (B, S, k)
    slot_weights: dict,      # expert slot params, leading dim = total slots
    slot_of: jax.Array,      # (E, R_max)
    n_replicas: jax.Array,   # (E,)
    ctx: ParallelCtx,
    capacity_factor: float,
    total_slots: int,
):
    """Single-process EP dispatch (no mesh): the same slot-table routing,
    fixed-capacity bucketing and ragged grouped FFN as ``ep_moe_shardmap``,
    minus the all_to_all — every slot is local, so the exchange is the
    identity. This is what lets the NI-Balancer run for real on one
    process (``ServeConfig.virtual_ep``): replica routing, migrations and
    evacuations move actual weight rows between slot rows; only the
    inter-device hop is notional."""
    b, s, d = x.shape
    k = expert_ids.shape[-1]
    n = b * s
    xt = x.reshape(n, d)
    eid = expert_ids.reshape(n, k)
    w = weights.reshape(n, k)
    cap = bucket_capacity(n, k, capacity_factor, total_slots)
    slots = choose_slots(eid, slot_of, n_replicas, sentinel=total_slots + 1)
    bufs, pos, keep = bucket_dispatch(xt, slots, total_slots, cap)
    counts = kept_counts(slots, keep, total_slots)
    # ep_chunks: the local path has no all_to_all to hide, but it is the
    # substrate the virtual-EP serving/chaos tests run on — chunking the
    # grouped FFN the same way keeps the chunked program on the hot path
    # there (per-bucket results are independent of how groups are batched,
    # so the concatenated output is bit-identical to the single call).
    kc = validate_ep_chunks(getattr(ctx, "ep_chunks", 1), where="ep_moe_local")
    if kc > 1:
        validate_ep_chunks(kc, total_slots, where="ep_moe_local total_slots")
    spt = total_slots // kc
    ys = [
        registry.expert_ffn(
            bufs[c * spt : (c + 1) * spt],
            slot_weights["w_gate"][c * spt : (c + 1) * spt],
            slot_weights["w_up"][c * spt : (c + 1) * spt],
            slot_weights["w_down"][c * spt : (c + 1) * spt],
            group_sizes=counts[c * spt : (c + 1) * spt],
            enabled=ctx.kernels_on,
        )
        for c in range(kc)
    ]
    y = ys[0] if kc == 1 else jnp.concatenate(ys, axis=0)
    out = bucket_combine(y, slots, pos, keep, w)
    return out.reshape(b, s, d)


# ---------------------------------------------------------------------------
# ESP expert FFN (kernel path)
# ---------------------------------------------------------------------------

def esp_expert_ffn(
    bufs: jax.Array,     # (G, E, cap, d) — per-group expert buckets
    counts: jax.Array,   # (G, E) kept-token count per bucket
    wg: jax.Array,       # (E, d, f)
    wu: jax.Array,       # (E, d, f)
    wd: jax.Array,       # (E, f, d)
    ctx: ParallelCtx,
) -> jax.Array:
    """Count-aware expert FFN for the ESP path (experts' hidden dim sharded
    over the model axis, bucket groups over the batch axes).

    Under a mesh the Pallas call must be device-local, so the compute runs
    under shard_map: each device takes its f-slice of every expert, runs the
    ragged GMM kernels over its bucket groups, and the partial down-
    projection sums reduce-scatter onto the d dim (the einsum path's GSPMD
    layout, §Perf iteration 3). Output is (G, E, cap, d), d sharded over
    the model axis. Caller gates on divisibility (see ``moe_esp``).
    """
    g, e, cap, d = bufs.shape

    def compute(xb, cb, wgb, wub, wdb):
        gl = xb.shape[0]
        # (gl, E, cap, d) -> (E*gl, cap, d): expert-major flatten so weight
        # row = group // gl (the ragged kernels' divisor mapping).
        xg = xb.transpose(1, 0, 2, 3).reshape(e * gl, cap, -1)
        y = registry.expert_ffn(
            xg,
            wgb,
            wub,
            wdb,
            group_sizes=cb.transpose(1, 0).reshape(-1),
            groups_per_weight=gl,
            enabled=True,
        )
        return y.reshape(e, gl, cap, -1).transpose(1, 0, 2, 3)

    if ctx.mesh is None:
        return compute(bufs, counts, wg, wu, wd)

    axis = ctx.model_axis
    bspec = ctx.batch_spec

    def body(xb, cb, wgb, wub, wdb):
        y = compute(xb, cb, wgb, wub, wdb)
        # Partial sums over the f-shards: reduce-scatter onto d.
        return jax.lax.psum_scatter(y, axis, scatter_dimension=3, tiled=True)

    return shard_map(
        body,
        mesh=ctx.mesh,
        in_specs=(
            P(bspec, None, None, None),
            P(bspec, None),
            P(None, None, axis),
            P(None, None, axis),
            P(None, axis, None),
        ),
        out_specs=P(bspec, None, None, axis),
        check_vma=False,
    )(bufs, counts, wg, wu, wd)


# ---------------------------------------------------------------------------
# sequence-parallel flash-decode merge
# ---------------------------------------------------------------------------

def seq_parallel_decode_kernel_eligible(
    t: int, nh: int, nkv: int, hd: int, ctx: ParallelCtx
) -> bool:
    """Can each shard's partials come from the flash-decode kernel? The
    kernel emits unnormalized ``(acc, m, l)`` (``return_partials``), so the
    cross-shard LSE merge rides the psum as-is — decode with
    ``seq_parallel_kv=True`` takes the kernel path."""
    if not ctx.kernels_on or ctx.force_dense_attn:
        return False
    t_local = t // ctx.n_model
    return registry.can_flash_decode(
        t_local, nh, nkv, hd, registry.default_interpret()
    )


def seq_parallel_decode_attend(
    q: jax.Array,        # (B, 1, H, hd) — replicated over model axis
    k_cache: jax.Array,  # (B, L, K, hd) — L sharded over model axis
    v_cache: jax.Array,
    mask: jax.Array,     # (L,) validity, sharded like the cache
    ctx: ParallelCtx,
) -> jax.Array:
    """Flash-decode across the model axis: each shard attends over its KV
    chunk, partial results LSE-merge with a psum.

    Kernel path (when eligible): per-shard partials come straight from
    ``flash_decode(..., return_partials=True)`` — unnormalized ``(acc, m,
    l)`` — and ``registry.merge_decode_partials`` does the cross-shard
    merge, so no per-shard normalization round-trip. Fallback: the einsum
    partials below (identical math, unfused)."""
    mesh = ctx.mesh
    axis = ctx.model_axis
    use_kernel = seq_parallel_decode_kernel_eligible(
        k_cache.shape[1], q.shape[2], k_cache.shape[2], q.shape[3], ctx
    )

    def kernel_body(q_blk, k_blk, v_blk, m_blk):
        b, t_local = q_blk.shape[0], k_blk.shape[1]
        valid = jnp.broadcast_to(m_blk[None, :], (b, t_local))
        acc, m, l = registry.decode_attend_partials(q_blk[:, 0], k_blk, v_blk, valid)
        out = registry.merge_decode_partials(acc, m, l, axis)
        return out[:, None].astype(q_blk.dtype)

    def body(q_blk, k_blk, v_blk, m_blk):
        b, _, nh, hd = q_blk.shape
        nk = k_blk.shape[2]
        g = nh // nk
        qg = q_blk.reshape(b, 1, nk, g, hd)
        s = jnp.einsum("bskgd,btkd->bkgst", qg, k_blk).astype(jnp.float32)
        s = s / jnp.sqrt(hd).astype(jnp.float32)
        s = jnp.where(m_blk[None, None, None, None, :], s, -1e30)
        m = jnp.max(s, axis=-1, keepdims=True)
        # Guard fully-masked chunks.
        m_safe = jnp.maximum(m, -1e29)
        e = jnp.exp(s - m_safe)
        num = jnp.einsum("bkgst,btkd->bskgd", e.astype(v_blk.dtype), v_blk)
        den = jnp.sum(e, axis=-1)[..., None]              # (b,k,g,1,1)->align
        den = den.transpose(0, 3, 1, 2, 4)                # (b,1,k,g,1)
        # Global LSE merge across shards.
        m_b = m.transpose(0, 3, 1, 2, 4)                  # (b,1,k,g,1)
        m_max = jax.lax.pmax(m_b, axis)
        scale = jnp.exp(m_b - m_max)
        num = jax.lax.psum(num * scale.astype(num.dtype), axis)
        den = jax.lax.psum(den * scale, axis)
        out = num / jnp.maximum(den, 1e-30).astype(num.dtype)
        return out.reshape(b, 1, nh, hd)

    bspec = ctx.batch_spec
    return shard_map(
        kernel_body if use_kernel else body,
        mesh=mesh,
        in_specs=(
            P(bspec, None, None, None),
            P(bspec, axis, None, None),
            P(bspec, axis, None, None),
            P(axis),
        ),
        out_specs=P(bspec, None, None, None),
        check_vma=False,
    )(q, k_cache, v_cache, mask)
