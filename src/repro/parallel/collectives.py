"""Expert-parallel dispatch/combine and sequence-parallel decode attention.

``bucket_dispatch`` / ``bucket_combine`` are the static-shape, differentiable
building blocks: token copies are sorted into fixed-capacity buckets (one
per physical expert slot), moved with ``jax.lax.all_to_all`` across the EP
axis under ``shard_map``, computed, and combined back with router weights.
Capacity overflow drops copies (standard capacity-factor semantics).

Physical expert *slots* (= native experts + shadow replicas) are first-class:
the routing table ``slot_of[e, r]`` and replica counts ``n_replicas[e]`` are
traced int32 inputs, so the NI-Balancer can re-place experts between serving
steps without recompilation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels import registry
from repro.parallel.compat import shard_map
from repro.parallel.ctx import ParallelCtx


# ---------------------------------------------------------------------------
# bucket dispatch (pure jnp, static shapes, differentiable in x / weights)
# ---------------------------------------------------------------------------

def bucket_dispatch(
    x: jax.Array,          # (n, d) token activations
    bucket_ids: jax.Array, # (n, k) target bucket per token copy
    n_buckets: int,
    capacity: int,
):
    """Pack token copies into (n_buckets, capacity, d) buffers.

    Returns ``(buffers, slots, keep)`` where ``slots[n, k]`` is the
    within-bucket position of each copy and ``keep[n, k]`` masks copies that
    fit under capacity. Deterministic: earlier tokens win bucket slots.
    """
    n, k = bucket_ids.shape
    d = x.shape[-1]
    flat_b = bucket_ids.reshape(-1)                       # (n*k,)
    flat_src = jnp.repeat(jnp.arange(n), k)               # (n*k,)

    order = jnp.argsort(flat_b, stable=True)
    b_sorted = flat_b[order]
    counts = jnp.bincount(flat_b, length=n_buckets)
    offsets = jnp.concatenate([jnp.zeros(1, counts.dtype), jnp.cumsum(counts)[:-1]])
    idx_sorted = jnp.arange(n * k) - offsets[b_sorted]

    # Undo the sort to index by (token, k).
    slots = jnp.zeros(n * k, dtype=jnp.int32).at[order].set(idx_sorted.astype(jnp.int32))
    keep = (slots < capacity) & (flat_b < n_buckets)  # drop out-of-range ids too

    # Scatter kept copies; overflow goes to a sacrificial extra bucket row.
    slot_b = jnp.where(keep, flat_b, n_buckets)
    slot_i = jnp.minimum(slots, capacity - 1)
    buffers = jnp.zeros((n_buckets + 1, capacity, d), dtype=x.dtype)
    buffers = buffers.at[slot_b, slot_i].set(x[flat_src], mode="drop")
    return buffers[:n_buckets], slots.reshape(n, k), keep.reshape(n, k)


def bucket_combine(
    y: jax.Array,            # (n_buckets, capacity, d) expert outputs
    bucket_ids: jax.Array,   # (n, k)
    slots: jax.Array,        # (n, k)
    keep: jax.Array,         # (n, k)
    weights: jax.Array,      # (n, k) router weights
) -> jax.Array:
    n, k = bucket_ids.shape
    vals = y[bucket_ids.reshape(-1), jnp.minimum(slots, y.shape[1] - 1).reshape(-1)]
    vals = vals.reshape(n, k, -1)
    w = (weights * keep).astype(vals.dtype)
    return jnp.einsum("nkd,nk->nd", vals, w)


def scatter_counts(bucket_ids: jax.Array, n_buckets: int) -> jax.Array:
    """Per-bucket token counts (n, k) -> (n_buckets,); feeds the balancer."""
    return jnp.bincount(bucket_ids.reshape(-1), length=n_buckets)


def kept_counts(
    bucket_ids: jax.Array, keep: jax.Array, n_buckets: int
) -> jax.Array:
    """Per-bucket *kept* copy counts (capacity drops excluded), int32.

    ``bucket_dispatch`` packs kept copies into slots ``0..count-1`` of their
    bucket, so these counts are exactly the ``group_sizes`` the ragged GMM
    kernels consume. Implemented as a scatter-add (vmap-safe, unlike
    ``jnp.bincount``); out-of-range ids land in a sacrificial row.
    """
    b = jnp.where(keep, bucket_ids, n_buckets)
    return (
        jnp.zeros((n_buckets + 1,), jnp.int32)
        .at[b.reshape(-1)]
        .add(1, mode="drop")[:n_buckets]
    )


# ---------------------------------------------------------------------------
# replica routing
# ---------------------------------------------------------------------------

def choose_slots(
    expert_ids: jax.Array,   # (n, k) logical expert per copy
    slot_of: jax.Array,      # (E, R_max) physical slot table
    n_replicas: jax.Array,   # (E,) live replica count per expert
) -> jax.Array:
    """Pick a physical slot per copy, round-robin over live replicas."""
    n, k = expert_ids.shape
    copy_idx = (jnp.arange(n * k) % 997).reshape(n, k)  # cheap spread
    r = copy_idx % n_replicas[expert_ids]
    return slot_of[expert_ids, r]


def uniform_placement(n_experts: int, n_slots: int, r_max: int = 4):
    """Initial placement: expert e -> slot e (native homes), one replica."""
    import numpy as np

    slot_of = np.zeros((n_experts, r_max), dtype=np.int32)
    slot_of[:, 0] = np.arange(n_experts) % n_slots
    # Unused replica columns point at the native slot (harmless).
    for r in range(1, r_max):
        slot_of[:, r] = slot_of[:, 0]
    n_replicas = np.ones(n_experts, dtype=np.int32)
    return jnp.asarray(slot_of), jnp.asarray(n_replicas)


# ---------------------------------------------------------------------------
# EP all-to-all under shard_map
# ---------------------------------------------------------------------------

def ep_moe_shardmap(
    x: jax.Array,                 # (B, S, d) — seq will be split over model axis
    expert_ids: jax.Array,        # (B, S, k)
    weights: jax.Array,           # (B, S, k)
    slot_weights: dict,           # expert slot params, leading dim = total slots
    slot_of: jax.Array,           # (E, R_max)
    n_replicas: jax.Array,        # (E,)
    ctx: ParallelCtx,
    capacity_factor: float,
    slots_per_device: int,
    decode: bool = False,
):
    """Expert-parallel MoE: dispatch -> all_to_all -> GMM -> all_to_all -> combine.

    ``slot_weights`` holds (n_total_slots, d, f) matrices sharded over the
    model axis (slot dim). Inside the per-device block each device sees its
    ``slots_per_device`` local experts and exchanges fixed-capacity buckets
    with every peer on the EP (= model) axis.

    Train/prefill mode splits the *sequence* over the EP axis (each TP rank
    dispatches a distinct token slice — the paper's retained-AG semantics).
    Decode mode (``s == 1``) keeps tokens replicated over the EP axis; each
    rank owns tokens with ``idx % ep == rank`` and a final psum restores
    replication.
    """
    mesh = ctx.mesh
    axis = ctx.model_axis
    ep = ctx.n_model
    total_slots = ep * slots_per_device
    use_kernels = ctx.kernels_on

    b, s, d = x.shape
    k = expert_ids.shape[-1]
    if decode:
        n_tok = max(b // ctx.n_batch, 1)           # distinct tokens per EP group
    else:
        n_tok = b * s // (ctx.n_batch * ep)        # tokens per device, seq split
    cap = max(int(n_tok * k * capacity_factor / total_slots), 8)

    def body(x_blk, eid_blk, w_blk, wg, wu, wd, slot_of_, n_rep_):
        # x_blk: (B_loc, S_loc, d) — this device's token slice.
        bl, sl, _ = x_blk.shape
        xt = x_blk.reshape(bl * sl, d)
        eid = eid_blk.reshape(bl * sl, k)
        w = w_blk.reshape(bl * sl, k)

        slots = choose_slots(eid, slot_of_, n_rep_)           # physical slot
        if decode:
            # Tokens are replicated across the EP axis: rank r owns
            # idx % ep == r; unowned copies overflow out of every bucket.
            rank = jax.lax.axis_index(axis)
            owned = (jnp.arange(bl * sl) % ep) == rank
            slots = jnp.where(owned[:, None], slots, total_slots + 1)
        bufs, pos, keep = bucket_dispatch(xt, slots, total_slots, cap)
        # How full each outgoing bucket actually is — rides the same
        # all_to_all so every device knows its received buckets' raggedness.
        counts = kept_counts(slots, keep, total_slots)
        # (total_slots, cap, d) -> exchange so each device gets its slots.
        bufs = bufs.reshape(ep, slots_per_device, cap, d)
        recv = jax.lax.all_to_all(bufs, axis, split_axis=0, concat_axis=0, tiled=False)
        cnt = jax.lax.all_to_all(
            counts.reshape(ep, slots_per_device), axis,
            split_axis=0, concat_axis=0, tiled=False,
        )
        # recv: (ep, slots_per_device, cap, d) — axis 0 now = source rank.
        recv = recv.transpose(1, 0, 2, 3)              # (spd, ep, cap, d)
        cnt = cnt.transpose(1, 0)                      # (spd, ep)

        # Local expert compute: bucket (slot e, source r) uses weight row e;
        # the ragged GMM kernels skip capacity rows past each bucket's
        # count, so FFN FLOPs track tokens actually routed (fallback:
        # folded einsums over the same layout).
        y = registry.expert_ffn(
            recv.reshape(slots_per_device * ep, cap, d),
            wg,
            wu,
            wd,
            group_sizes=cnt.reshape(-1),
            groups_per_weight=ep,
            enabled=use_kernels,
        )
        y = y.reshape(slots_per_device, ep, cap, d).transpose(1, 0, 2, 3)
        back = jax.lax.all_to_all(y, axis, split_axis=0, concat_axis=0, tiled=False)
        back = back.reshape(total_slots, cap, d)
        out = bucket_combine(back, slots, pos, keep, w)
        if decode:
            out = jax.lax.psum(out, axis)  # gather owners' results everywhere
        return out.reshape(bl, sl, d)

    bspec = ctx.batch_spec
    seq_spec = None if decode else axis
    return shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(bspec, seq_spec, None),      # x: sequence split over model axis
            P(bspec, seq_spec, None),
            P(bspec, seq_spec, None),
            P(axis, None, None),           # slot weights: slot dim over model
            P(axis, None, None),
            P(axis, None, None),
            P(None, None),                 # routing tables replicated
            P(None),
        ),
        out_specs=P(bspec, seq_spec, None),
        check_vma=False,
    )(
        x,
        expert_ids,
        weights,
        slot_weights["w_gate"],
        slot_weights["w_up"],
        slot_weights["w_down"],
        slot_of,
        n_replicas,
    )


# ---------------------------------------------------------------------------
# ESP expert FFN (kernel path)
# ---------------------------------------------------------------------------

def esp_expert_ffn(
    bufs: jax.Array,     # (G, E, cap, d) — per-group expert buckets
    counts: jax.Array,   # (G, E) kept-token count per bucket
    wg: jax.Array,       # (E, d, f)
    wu: jax.Array,       # (E, d, f)
    wd: jax.Array,       # (E, f, d)
    ctx: ParallelCtx,
) -> jax.Array:
    """Count-aware expert FFN for the ESP path (experts' hidden dim sharded
    over the model axis, bucket groups over the batch axes).

    Under a mesh the Pallas call must be device-local, so the compute runs
    under shard_map: each device takes its f-slice of every expert, runs the
    ragged GMM kernels over its bucket groups, and the partial down-
    projection sums reduce-scatter onto the d dim (the einsum path's GSPMD
    layout, §Perf iteration 3). Output is (G, E, cap, d), d sharded over
    the model axis. Caller gates on divisibility (see ``moe_esp``).
    """
    g, e, cap, d = bufs.shape

    def compute(xb, cb, wgb, wub, wdb):
        gl = xb.shape[0]
        # (gl, E, cap, d) -> (E*gl, cap, d): expert-major flatten so weight
        # row = group // gl (the ragged kernels' divisor mapping).
        xg = xb.transpose(1, 0, 2, 3).reshape(e * gl, cap, -1)
        y = registry.expert_ffn(
            xg,
            wgb,
            wub,
            wdb,
            group_sizes=cb.transpose(1, 0).reshape(-1),
            groups_per_weight=gl,
            enabled=True,
        )
        return y.reshape(e, gl, cap, -1).transpose(1, 0, 2, 3)

    if ctx.mesh is None:
        return compute(bufs, counts, wg, wu, wd)

    axis = ctx.model_axis
    bspec = ctx.batch_spec

    def body(xb, cb, wgb, wub, wdb):
        y = compute(xb, cb, wgb, wub, wdb)
        # Partial sums over the f-shards: reduce-scatter onto d.
        return jax.lax.psum_scatter(y, axis, scatter_dimension=3, tiled=True)

    return shard_map(
        body,
        mesh=ctx.mesh,
        in_specs=(
            P(bspec, None, None, None),
            P(bspec, None),
            P(None, None, axis),
            P(None, None, axis),
            P(None, axis, None),
        ),
        out_specs=P(bspec, None, None, axis),
        check_vma=False,
    )(bufs, counts, wg, wu, wd)


# ---------------------------------------------------------------------------
# sequence-parallel flash-decode merge
# ---------------------------------------------------------------------------

def seq_parallel_decode_attend(
    q: jax.Array,        # (B, 1, H, hd) — replicated over model axis
    k_cache: jax.Array,  # (B, L, K, hd) — L sharded over model axis
    v_cache: jax.Array,
    mask: jax.Array,     # (L,) validity, sharded like the cache
    ctx: ParallelCtx,
) -> jax.Array:
    """Flash-decode across the model axis: each shard attends over its KV
    chunk with a local log-sum-exp, partial results merge with a psum."""
    mesh = ctx.mesh
    axis = ctx.model_axis

    def body(q_blk, k_blk, v_blk, m_blk):
        b, _, nh, hd = q_blk.shape
        nk = k_blk.shape[2]
        g = nh // nk
        qg = q_blk.reshape(b, 1, nk, g, hd)
        s = jnp.einsum("bskgd,btkd->bkgst", qg, k_blk).astype(jnp.float32)
        s = s / jnp.sqrt(hd).astype(jnp.float32)
        s = jnp.where(m_blk[None, None, None, None, :], s, -1e30)
        m = jnp.max(s, axis=-1, keepdims=True)
        # Guard fully-masked chunks.
        m_safe = jnp.maximum(m, -1e29)
        e = jnp.exp(s - m_safe)
        num = jnp.einsum("bkgst,btkd->bskgd", e.astype(v_blk.dtype), v_blk)
        den = jnp.sum(e, axis=-1)[..., None]              # (b,k,g,1,1)->align
        den = den.transpose(0, 3, 1, 2, 4)                # (b,1,k,g,1)
        # Global LSE merge across shards.
        m_b = m.transpose(0, 3, 1, 2, 4)                  # (b,1,k,g,1)
        m_max = jax.lax.pmax(m_b, axis)
        scale = jnp.exp(m_b - m_max)
        num = jax.lax.psum(num * scale.astype(num.dtype), axis)
        den = jax.lax.psum(den * scale, axis)
        out = num / jnp.maximum(den, 1e-30).astype(num.dtype)
        return out.reshape(b, 1, nh, hd)

    bspec = ctx.batch_spec
    return shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(bspec, None, None, None),
            P(bspec, axis, None, None),
            P(bspec, axis, None, None),
            P(axis),
        ),
        out_specs=P(bspec, None, None, None),
        check_vma=False,
    )(q, k_cache, v_cache, mask)
