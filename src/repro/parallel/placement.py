"""The one placement table, from balancer to device.

Before this module existed the expert placement lived in three divergent
representations: the balancer's ``replicas`` device lists (core), the
Server's host ``slot_of``/``n_replicas`` tables plus free-slot / dead-device
bookkeeping (runtime), and the ``uniform_placement``/``tiled_placement``
routing tables consumed by ``ep_moe_shardmap``/``ep_moe_local`` (parallel).
Every migration had to mutate all three in lock-step or the placements
diverged. :class:`PlacementTable` is the single substrate they all read.

Two views, one commit point:

* **routing view** (:meth:`device_view`) — the *committed* ``(slot_of,
  n_replicas)`` arrays handed to the jitted decode step. They change only
  inside :meth:`commit` / :meth:`drop_device` / :meth:`remove_replica`,
  which the serving loop calls exclusively at decode-step boundaries: that
  is the atomic swap. A replica being copied slice-by-slice is *pending*
  and invisible here, so no token ever routes to a half-copied slot.
* **planning view** (:meth:`replica_devices`, :meth:`slots_used`,
  :meth:`free_slot`) — committed **plus pending** replicas, so the
  balancer does not re-plan a migration that is already in flight and the
  free-slot allocator does not hand the same slot to two migrations.

The table is host-side numpy; :meth:`device_view` materialises (and
caches) the jnp mirror lazily, so core-layer users never touch jax.

Bookkeeping that used to be per-migration Python loops on the decode path
(``Server._free_slot``'s O(experts x replicas) scan, the
``_drop_device_slots`` while-loop compaction) is vectorised numpy here:
:meth:`used_slots` / :meth:`free_slot` / :meth:`drop_device`.

Conventions (shared with ``collectives.choose_slots``):

* ``slot_of`` is ``(n_experts, r_max)`` int32; row ``e``'s live entries are
  ``slot_of[e, :n_replicas[e]]``; the inert tail columns point at a live
  replica (column 0) so a clamped gather can never fabricate a slot.
* slot ``s`` lives on device ``s // slots_per_device``.
"""

from __future__ import annotations

import numpy as np

__all__ = ["PlacementError", "PlacementTable"]


class PlacementError(ValueError):
    """A placement mutation that would corrupt the table (commit without a
    reservation, reserving a used slot, over-cap replica, ...)."""


class PlacementTable:
    def __init__(
        self,
        n_experts: int,
        n_slots: int,
        slots_per_device: int,
        slot_of: np.ndarray,
        n_replicas: np.ndarray,
    ):
        if n_slots % slots_per_device:
            raise PlacementError(
                f"n_slots={n_slots} not a multiple of "
                f"slots_per_device={slots_per_device}"
            )
        self.n_experts = int(n_experts)
        self.n_slots = int(n_slots)
        self.slots_per_device = int(slots_per_device)
        self.n_devices = self.n_slots // self.slots_per_device
        self.slot_of = np.array(slot_of, dtype=np.int32)
        self.n_replicas = np.array(n_replicas, dtype=np.int32)
        if self.slot_of.shape[0] != self.n_experts:
            raise PlacementError(
                f"slot_of rows {self.slot_of.shape[0]} != "
                f"n_experts {self.n_experts}"
            )
        # In-flight (reserved but uncommitted) replicas: expert -> slot.
        # Part of the planning view, invisible to the routing view.
        self._pending: list[tuple[int, int]] = []
        # Monotonic commit counter; bumps whenever the routing view changes.
        self.version = 0
        self._device_view = None

    # -- constructors --------------------------------------------------------

    @classmethod
    def uniform(
        cls, n_experts: int, n_slots: int,
        slots_per_device: int | None = None, r_max: int = 4,
    ) -> "PlacementTable":
        """Expert e -> slot e (native homes), one replica each."""
        slot_of = np.zeros((n_experts, r_max), dtype=np.int32)
        slot_of[:] = (np.arange(n_experts) % n_slots)[:, None]
        n_replicas = np.ones(n_experts, dtype=np.int32)
        return cls(n_experts, n_slots, slots_per_device or n_slots,
                   slot_of, n_replicas)

    @classmethod
    def tiled(
        cls, n_experts: int, n_rows: int, n_slots: int,
        slots_per_device: int | None = None, r_max: int = 4,
    ) -> "PlacementTable":
        """Placement consistent with ``jnp.tile``-expanded slot weights:
        slot ``s`` holds weight row ``s % n_rows``, so expert ``e`` gets a
        replica at every slot with ``s % n_rows == e`` (wrap-around shadow
        slots carry real traffic). ``r_max`` grows to fit every replica."""
        if not (n_experts <= n_rows <= n_slots):
            raise PlacementError(
                f"need n_experts <= n_rows <= n_slots, got "
                f"({n_experts}, {n_rows}, {n_slots})"
            )
        r_max = max(r_max, -(-n_slots // n_rows))
        slot_of = np.zeros((n_experts, r_max), dtype=np.int32)
        n_replicas = np.zeros(n_experts, dtype=np.int32)
        for e in range(n_experts):
            reps = list(range(e, n_slots, n_rows))
            n_replicas[e] = len(reps)
            for r in range(r_max):
                slot_of[e, r] = reps[min(r, len(reps) - 1)]
        return cls(n_experts, n_slots, slots_per_device or n_slots,
                   slot_of, n_replicas)

    @classmethod
    def round_robin(
        cls, n_experts: int, n_devices: int, slots_per_device: int,
        r_max: int | None = None,
    ) -> "PlacementTable":
        """Expert e -> device ``e % n_devices`` (the balancer's historical
        initial layout), first-fit slot within the device."""
        if n_experts > n_devices * slots_per_device:
            raise PlacementError(
                f"{n_experts} experts need more than "
                f"{n_devices}x{slots_per_device} slots"
            )
        r_max = r_max or max(4, n_devices)
        slot_of = np.zeros((n_experts, r_max), dtype=np.int32)
        e = np.arange(n_experts)
        slot_of[:] = ((e % n_devices) * slots_per_device + e // n_devices)[
            :, None
        ]
        n_replicas = np.ones(n_experts, dtype=np.int32)
        return cls(n_experts, n_devices * slots_per_device,
                   slots_per_device, slot_of, n_replicas)

    # -- routing view (committed only) ---------------------------------------

    @property
    def r_max(self) -> int:
        return self.slot_of.shape[1]

    def device_view(self):
        """The committed ``(slot_of, n_replicas)`` as jnp arrays — the pair
        traced through the jitted decode step. Cached; regenerated only
        when a commit/drop bumps :attr:`version`, so between commits the
        decode step sees the identical arrays (the atomic-swap contract)."""
        if self._device_view is None:
            import jax.numpy as jnp

            self._device_view = (
                jnp.asarray(self.slot_of), jnp.asarray(self.n_replicas)
            )
        return self._device_view

    def _bump(self) -> None:
        self.version += 1
        self._device_view = None

    def device_of(self, slot: int) -> int:
        return int(slot) // self.slots_per_device

    def owner_of_slots(self) -> np.ndarray:
        """Expert committed to each physical slot, ``-1`` for free slots —
        the mapping a restore needs to re-place expert weight rows from a
        logical-expert checkpoint into slot-expanded buffers."""
        owner = np.full(self.n_slots, -1, dtype=np.int64)
        live = np.arange(self.r_max)[None, :] < self.n_replicas[:, None]
        experts = np.broadcast_to(
            np.arange(self.n_experts)[:, None], self.slot_of.shape
        )
        owner[self.slot_of[live]] = experts[live]
        return owner

    def committed_devices(self) -> set[int]:
        """Devices referenced by any committed replica — the set a token
        can physically route to this tick."""
        live = np.arange(self.r_max)[None, :] < self.n_replicas[:, None]
        return {int(d) for d in (self.slot_of[live] // self.slots_per_device)}

    def committed_slots(self, e: int) -> list[int]:
        return [int(s) for s in self.slot_of[e, : self.n_replicas[e]]]

    def slot_on_device(self, e: int, device: int) -> int | None:
        """The committed slot of expert ``e`` on ``device``, if any."""
        for s in self.committed_slots(e):
            if self.device_of(s) == device:
                return s
        return None

    # -- planning view (committed + pending) ---------------------------------

    @property
    def pending(self) -> tuple[tuple[int, int], ...]:
        return tuple(self._pending)

    def used_slots(self, include_pending: bool = True) -> np.ndarray:
        """Boolean occupancy over all slots (vectorised: one fancy-index
        scatter instead of the old O(experts x replicas) Python scan)."""
        used = np.zeros(self.n_slots, dtype=bool)
        live = np.arange(self.r_max)[None, :] < self.n_replicas[:, None]
        used[self.slot_of[live]] = True
        if include_pending:
            for _, s in self._pending:
                used[s] = True
        return used

    def free_slot(self, device: int, include_pending: bool = True) -> int | None:
        """First free slot on ``device``, or None. Reserved (pending) slots
        count as used so two in-flight migrations can't collide."""
        lo = device * self.slots_per_device
        free = ~self.used_slots(include_pending)[lo : lo + self.slots_per_device]
        idx = np.flatnonzero(free)
        return int(lo + idx[0]) if idx.size else None

    def replica_devices(self, e: int, include_pending: bool = True) -> list[int]:
        devs = [self.device_of(s) for s in self.committed_slots(e)]
        if include_pending:
            devs += [self.device_of(s) for ex, s in self._pending if ex == e]
        return devs

    def all_replica_devices(self, include_pending: bool = True) -> list[list[int]]:
        """Per-expert device lists — the balancer's ``replicas`` planning
        view (committed + in-flight, so plans never duplicate)."""
        return [
            self.replica_devices(e, include_pending)
            for e in range(self.n_experts)
        ]

    def slots_used(self, include_pending: bool = True) -> np.ndarray:
        """Occupied-slot count per device (vectorised)."""
        return (
            self.used_slots(include_pending)
            .reshape(self.n_devices, self.slots_per_device)
            .sum(axis=1)
        )

    def n_pending(self, e: int) -> int:
        return sum(1 for ex, _ in self._pending if ex == e)

    # -- pending lifecycle: reserve -> (slices land) -> commit ----------------

    def try_reserve(self, e: int, device: int) -> int | None:
        """Reserve a destination slot on ``device`` for a new replica of
        expert ``e``. Returns the slot, or None when the migration cannot be
        placed (no free slot, device already hosts the expert, or the
        expert is at its replica-column cap — committing then would leak a
        slot or overwrite a live column, the historical bugs)."""
        if device in self.replica_devices(e):
            return None
        if int(self.n_replicas[e]) + self.n_pending(e) >= self.r_max:
            return None
        slot = self.free_slot(device)
        if slot is None:
            return None
        self._pending.append((e, slot))
        return slot

    def release_pending(self, e: int, slot: int) -> None:
        """Abort an in-flight migration: the reserved slot goes back to the
        free pool, the routing view never knew it existed."""
        try:
            self._pending.remove((e, slot))
        except ValueError:
            raise PlacementError(
                f"release of ({e}, {slot}) which is not pending"
            ) from None

    def commit(self, e: int, slot: int) -> None:
        """Atomic swap: publish a fully-copied replica to the routing view.
        Must only be called at a decode-step boundary, after the last
        weight slice landed."""
        self.release_pending(e, slot)   # raises if never reserved
        r = int(self.n_replicas[e])
        if r >= self.r_max:
            raise PlacementError(
                f"expert {e} at replica cap {self.r_max}; reservation "
                f"accounting is broken"
            )
        self.slot_of[e, r] = slot
        self.n_replicas[e] = r + 1
        self._bump()

    def apply(self, e: int, device: int) -> int | None:
        """Reserve + commit in one step — the instantaneous path (balancer
        simulation, evacuation fast-forward). Returns the slot or None."""
        slot = self.try_reserve(e, device)
        if slot is not None:
            self.commit(e, slot)
        return slot

    # -- removal -------------------------------------------------------------

    def remove_replica(self, e: int, r: int) -> int:
        """Drop committed replica column ``r`` of expert ``e`` (swap-with-
        last); returns the freed slot."""
        n = int(self.n_replicas[e])
        if not (0 <= r < n):
            raise PlacementError(f"expert {e} has no replica column {r}")
        if n == 1:
            raise PlacementError(f"cannot remove expert {e}'s only replica")
        freed = int(self.slot_of[e, r])
        self.slot_of[e, r] = self.slot_of[e, n - 1]
        self.n_replicas[e] = n - 1
        self.slot_of[e, n - 1 :] = self.slot_of[e, 0]
        self._bump()
        return freed

    def drop_device(self, device: int) -> int:
        """Remove every committed replica on ``device`` wherever the expert
        has another replica (an expert whose *only* copy sits there keeps
        it — evacuation must have failed, and routing to a dead slot beats
        routing to garbage). Inert tail columns are repointed at a live
        replica so no table entry — live or tail — targets the device.

        Vectorised replacement for the old per-expert while-loop: one
        stable argsort partitions each row into kept/dropped entries.
        Returns the number of experts that dropped a replica."""
        live = np.arange(self.r_max)[None, :] < self.n_replicas[:, None]
        on_dead = live & (self.slot_of // self.slots_per_device == device)
        keep = live & ~on_dead
        sole = ~keep.any(axis=1)          # only-copy-was-there experts
        keep[sole] = live[sole]
        # Stable partition: kept entries first, original order preserved.
        order = np.argsort(~keep, axis=1, kind="stable")
        slot_of = np.take_along_axis(self.slot_of, order, axis=1)
        n_rep = keep.sum(axis=1).astype(np.int32)
        tail = np.arange(self.r_max)[None, :] >= n_rep[:, None]
        self.slot_of = np.where(tail, slot_of[:, :1], slot_of).astype(np.int32)
        self.n_replicas = n_rep
        self._bump()
        return int((on_dead.any(axis=1) & ~sole).sum())

    # -- invariants -----------------------------------------------------------

    def check(self) -> None:
        """Internal-consistency assertions (tests call this every tick)."""
        if (self.n_replicas < 1).any() or (self.n_replicas > self.r_max).any():
            raise PlacementError(f"n_replicas out of range: {self.n_replicas}")
        live = np.arange(self.r_max)[None, :] < self.n_replicas[:, None]
        slots = self.slot_of[live]
        if slots.size and (slots.min() < 0 or slots.max() >= self.n_slots):
            raise PlacementError("committed slot out of range")
        flat = [int(s) for s in slots]
        if len(flat) != len(set(flat)):
            raise PlacementError("two replicas share a physical slot")
        committed = set(flat)
        for e, s in self._pending:
            if s in committed:
                raise PlacementError(
                    f"pending slot {s} (expert {e}) is already committed"
                )
