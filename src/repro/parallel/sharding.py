"""Sharding policy: PartitionSpecs for every param/cache/input leaf.

One rule table drives all ten architectures. Conventions:

* ``model`` axis carries TP/EP: attention head projections, FFN hidden,
  vocab (embed rows / lm_head cols), expert slot rows (EP regime) or expert
  hidden dims (ESP regime), Mamba inner channels.
* batch axes (``data`` or ``("pod","data")``) carry tokens; a dimension is
  only sharded when it divides evenly (``_ok``), otherwise it degrades to
  replication — this is what makes restore-onto-any-mesh and odd global
  batches (long_500k's batch=1) work without special cases.
* xLSTM blocks keep weights replicated (attention-free 350M model: the
  weights are small enough that model-axis collectives would cost more than
  they save — DP-only is the right layout).

``state_specs`` covers the train state (params + AdamW moments mirror the
param layout), ``cache_specs`` mirrors ``transformer.init_cache``.
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.parallel.ctx import ParallelCtx


def _ok(dim: int, n: int) -> bool:
    return n > 1 and dim % n == 0


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def placement_specs() -> tuple[P, P]:
    """PartitionSpecs for the routing tables ``(slot_of, n_replicas)`` that
    ``PlacementTable.device_view`` feeds into the EP dispatch: replicated on
    every shard. Replication is what makes the placement-table commit an
    *atomic* swap — all ranks route by the same committed arrays within one
    step, and a commit between steps replaces the pair everywhere at once
    (the tables are tiny; the expensive state, the slot weights, never moves
    at swap time — it moved slice-by-slice beforehand)."""
    return P(None, None), P(None)


def chunk_specs() -> dict:
    """PartitionSpecs for the decode step's prefill-chunk operand
    (``transformer.decode_step(chunk=...)``): replicated everywhere. The
    chunk is batch-1 host-built metadata — ``tokens (1, C)``, ``table
    (NB,)``, scalar ``start``/``length`` — too small to shard and read by
    every rank's attention gather; replication mirrors ``placement_specs``
    (the other per-tick host-fed operand) so the fused step's layout is
    stable across idle, decode-only and decode+chunk ticks."""
    return {
        "tokens": P(None, None),
        "table": P(None),
        "start": P(),
        "length": P(),
    }


def param_spec(path: str, shape: tuple[int, ...], cfg: ModelConfig, n_model: int) -> P:
    """PartitionSpec for one parameter leaf (leading stacked-layer dims are
    never sharded)."""
    none = P(*(None,) * len(shape))
    if n_model <= 1 or cfg.block_pattern == "xlstm":
        return none
    name = path.split("/")[-1]

    def last(axis_from_end=1):
        if not _ok(shape[-axis_from_end], n_model):
            return none
        spec = [None] * len(shape)
        spec[len(shape) - axis_from_end] = "model"
        return P(*spec)

    if name == "embed":
        return P("model", None) if _ok(shape[0], n_model) else none
    if name == "lm_head":
        return last(1)
    if name in ("wq", "wk", "wv", "bq", "bk", "bv"):
        return last(1)
    if name == "wo":
        return last(2)
    if "moe" in path:
        if name == "router":
            return none
        # EP regime: shard expert/slot rows; ESP regime: shard hidden dim.
        slot_dim = len(shape) - 3          # (..., S, d, f) or (..., S, f, d)
        if _ok(shape[slot_dim], n_model):
            spec = [None] * len(shape)
            spec[slot_dim] = "model"
            return P(*spec)
        if name in ("w_gate", "w_up"):
            return last(1)
        if name == "w_down":
            return last(2)
        return none
    if name in ("w_gate", "w_up"):         # dense SwiGLU
        return last(1)
    if name == "w_down":
        return last(2)
    # Mamba2
    if name in ("w_z", "w_xbc", "conv_w", "conv_b"):
        return last(1)
    if name == "w_out" and "mamba" in path:
        return last(2)
    if name in ("norm_w", "a_log", "dt_bias", "d_skip"):
        return last(1) if name == "norm_w" else none
    return none


def params_specs(cfg: ModelConfig, params_shapes, ctx: ParallelCtx):
    """Pytree of PartitionSpec matching a params(-shaped) tree."""
    n_model = ctx.n_model
    flat, tdef = jax.tree_util.tree_flatten_with_path(params_shapes)
    specs = [
        param_spec(_path_str(p), tuple(leaf.shape), cfg, n_model)
        for p, leaf in flat
    ]
    return jax.tree_util.tree_unflatten(tdef, specs)


def state_specs(cfg: ModelConfig, state_shapes, ctx: ParallelCtx):
    """Train state: params + fp32 moments share the param layout."""
    return {
        "params": params_specs(cfg, state_shapes["params"], ctx),
        "opt": {
            "step": P(),
            "mu": params_specs(cfg, state_shapes["opt"]["mu"], ctx),
            "nu": params_specs(cfg, state_shapes["opt"]["nu"], ctx),
        },
    }


def batch_spec_for(global_batch: int, ctx: ParallelCtx):
    n = ctx.n_batch
    if n > 1 and global_batch % n == 0:
        return ctx.batch_spec
    return None


def cache_specs(cfg: ModelConfig, cache_shapes, ctx: ParallelCtx, batch: int):
    """PartitionSpecs mirroring ``transformer.init_cache`` exactly."""
    bs = batch_spec_for(batch, ctx)
    m = ctx.model_axis
    n_model = ctx.n_model

    def kv_spec(shape):
        # (L?, B, S, K, hd): shard S over model (flash-decode seq-parallel)
        # when divisible, else KV heads, else replicate.
        spec = [None] * len(shape)
        spec[-4] = bs
        if ctx.seq_parallel_kv and _ok(shape[-3], n_model):
            spec[-3] = m
        elif _ok(shape[-2], n_model):
            spec[-2] = m
        return P(*spec)

    def bdim_spec(shape, b_from_end, model_from_end=None):
        spec = [None] * len(shape)
        spec[len(shape) - b_from_end] = bs
        if model_from_end and _ok(shape[-model_from_end], n_model):
            spec[len(shape) - model_from_end] = m
        return P(*spec)

    def pool_spec(shape):
        # (L?, P, bs, K, hd): pages are dynamically owned (allocator), so
        # the page dim can't shard by request — replicate over batch axes
        # and put kv-heads on the model axis when divisible.
        spec = [None] * len(shape)
        if _ok(shape[-2], n_model):
            spec[-2] = m
        return P(*spec)

    pat = cfg.block_pattern
    specs: dict = {"pos": P()}
    if pat in ("attn", "encdec"):
        layer_shapes = cache_shapes["layers"]
        if "pool_k" in layer_shapes:   # paged KV cache (see attention.py)
            specs["layers"] = {
                "pool_k": pool_spec(layer_shapes["pool_k"].shape),
                "pool_v": pool_spec(layer_shapes["pool_v"].shape),
                "tables": bdim_spec(layer_shapes["tables"].shape, 2),
                "lengths": bdim_spec(layer_shapes["lengths"].shape, 1),
            }
        else:
            specs["layers"] = {
                "k": kv_spec(layer_shapes["k"].shape),
                "v": kv_spec(layer_shapes["v"].shape),
            }
        if pat == "encdec":
            specs["cross_kv"] = tuple(
                kv_spec(x.shape) for x in cache_shapes["cross_kv"]
            )
    elif pat == "zamba":
        def mamba_state_spec(tree):
            return {
                # conv: (..., B, CW, channels) — channels on model axis
                "conv": bdim_spec(tree["conv"].shape, 3, 1),
                # ssm: (..., B, H, hd, N) — heads on model axis
                "ssm": bdim_spec(tree["ssm"].shape, 4, 3),
            }
        specs["units_ssm"] = mamba_state_spec(cache_shapes["units_ssm"])
        specs["trailing_ssm"] = mamba_state_spec(cache_shapes["trailing_ssm"])
        specs["shared_kv"] = {
            "k": kv_spec(cache_shapes["shared_kv"]["k"].shape),
            "v": kv_spec(cache_shapes["shared_kv"]["v"].shape),
        }
    elif pat == "xlstm":
        specs["m"] = {
            "C": bdim_spec(cache_shapes["m"]["C"].shape, 4),
            "n": bdim_spec(cache_shapes["m"]["n"].shape, 3),
            "m": bdim_spec(cache_shapes["m"]["m"].shape, 2),
        }
        specs["s"] = {
            k: bdim_spec(cache_shapes["s"][k].shape, 3)
            for k in ("c", "n", "m", "h")
        }
    return specs


def to_shardings(mesh, specs):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )
